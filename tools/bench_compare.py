"""Benchmark-regression gate: diff a fresh BENCH json against committed
baselines on *ratio* metrics only.

Absolute timings (``us_per_call``, ``rounds_per_s``) are a property of
the machine that ran the benchmark — CI runners vary by multiples, so
gating on them would only measure the weather.  Ratios measured *within*
one run cancel the machine out: the flatten-once layout win
(``fused_vs_perstep_parity`` — both drivers pay the same interpret-mode
emulation cost) and the wire-codec byte reductions (``x_bf16`` — pure
payload arithmetic, exact on any host).  Those are the rows this tool
gates, each with its own tolerance:

=============================  =====================  =====================
row pattern                    derived key            tolerance
=============================  =====================  =====================
``kernel_path/speedup_p*``     fused_vs_perstep_      fresh ≥ 0.5 × baseline
                               parity                 (timing ratio: noisy
                                                      on shared runners)
``wire_codecs/*``              x_bf16                 |Δ|/baseline ≤ 2%
                                                      (deterministic bytes)
``elastic/claim_survivors``    survivors_bounded      fresh ≥ baseline
                                                      (0/1 flag: chaos run
                                                      stays bounded)
``elastic/claim_bytes``        bytes_saved_frac       |Δ|/baseline ≤ 2%
                                                      (dead-edge accounting
                                                      arithmetic)
``round_engine/claim_          overlap_local_parity   fresh ≥ 0.5 × baseline
overlap_hiding``                                      (timing ratio: the
                                                      overlapped round runs
                                                      at ≈ the local-compute
                                                      rate at p ≥ 4)
``noniid/claim_p4_overlap``    mt_overlap_survives_   fresh ≥ baseline
                               p4                     (0/1 flag: staleness-
                                                      refreshed MT stays
                                                      bounded at p = 4 where
                                                      synchronous MT
                                                      diverges)
``pretrain/claim_inter_        inter_reduction_f32,   |Δ|/baseline ≤ 2%
reduction``                    inter_reduction_bf16   (byte-accounting
                                                      arithmetic: two-level
                                                      vs flat-ring wires)
``pretrain/claim_inter_        reduction_ok           fresh ≥ baseline
reduction``                                           (0/1 flag: both inter
                                                      reductions ≥ 2×)
``pretrain/claim_equal_loss``  hier_loss_ok           fresh ≥ baseline
                                                      (0/1 flag: two-level
                                                      LM run's final loss ≤
                                                      1.05 × flat ring's)
``embedding/claim_bytes_       bytes_scale_with_      fresh ≥ baseline
scale``                        touched                (0/1 flag: bytes
                                                      monotone in rows
                                                      touched AND flat in
                                                      table size, ≥ 4× under
                                                      dense f32 at 1% touch)
``embedding/claim_bytes_       sparse_vs_dense_x      |Δ|/baseline ≤ 2%
scale``                                               (byte-accounting
                                                      arithmetic: dense f32
                                                      wire / sparse wire)
=============================  =====================  =====================

A gated (row, key) present in a baseline but missing from the fresh run
**fails** — a silently dropped benchmark must not read as green.  Rows
only in the fresh run are ignored (new benchmarks land before their
baseline).  Usage::

    python tools/bench_compare.py --fresh benchmarks/BENCH_fresh.json \
        --baseline benchmarks/BENCH_kernel_path.json \
        --baseline benchmarks/BENCH_wire_codecs.json

Exit code 0 = gate green, 1 = regression (or missing gated row), 2 = bad
invocation.  ``--spec name_regex:derived_key:min_frac=F`` /
``:rel_tol=F`` appends custom gates.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import sys

# (row-name glob, derived key, kind, threshold)
#   min_frac: fresh >= threshold * baseline      (one-sided, ratios-of-times)
#   rel_tol:  |fresh - baseline| <= threshold * |baseline|   (deterministic)
DEFAULT_GATES = [
    ("kernel_path/speedup_p*", "fused_vs_perstep_parity", "min_frac", 0.5),
    ("wire_codecs/*", "x_bf16", "rel_tol", 0.02),
    ("elastic/claim_survivors", "survivors_bounded", "min_frac", 1.0),
    ("elastic/claim_bytes", "bytes_saved_frac", "rel_tol", 0.02),
    ("round_engine/claim_overlap_hiding", "overlap_local_parity",
     "min_frac", 0.5),
    ("noniid/claim_p4_overlap", "mt_overlap_survives_p4", "min_frac", 1.0),
    ("pretrain/claim_inter_reduction", "inter_reduction_f32",
     "rel_tol", 0.02),
    ("pretrain/claim_inter_reduction", "inter_reduction_bf16",
     "rel_tol", 0.02),
    ("pretrain/claim_inter_reduction", "reduction_ok", "min_frac", 1.0),
    ("pretrain/claim_equal_loss", "hier_loss_ok", "min_frac", 1.0),
    ("embedding/claim_bytes_scale", "bytes_scale_with_touched",
     "min_frac", 1.0),
    ("embedding/claim_bytes_scale", "sparse_vs_dense_x", "rel_tol", 0.02),
]


def _load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        derived = row.get("derived", {})
        for k, v in derived.items():
            if isinstance(v, (int, float)):
                out[(row["name"], k)] = float(v)
    return out


class SpecError(ValueError):
    pass


def _parse_spec(spec: str):
    try:
        pattern, key, rule = spec.split(":", 2)
        kind, val = rule.split("=", 1)
        assert kind in ("min_frac", "rel_tol")
        return (pattern, key, kind, float(val))
    except (ValueError, AssertionError):
        raise SpecError(
            f"bad --spec {spec!r} (want glob:derived_key:min_frac=F "
            f"or glob:derived_key:rel_tol=F)")


def compare(fresh: dict, baseline: dict, gates) -> list:
    """Returns a list of (name, key, baseline, fresh, verdict, detail);
    verdict ∈ {'ok', 'FAIL', 'MISSING'}."""
    report = []
    for (name, key), base_v in sorted(baseline.items()):
        for (pattern, gkey, kind, thr) in gates:
            if gkey != key or not fnmatch.fnmatch(name, pattern):
                continue
            fresh_v = fresh.get((name, key))
            if fresh_v is None:
                report.append((name, key, base_v, None, "MISSING",
                               "gated row absent from fresh run"))
                continue
            if kind == "min_frac":
                ok = fresh_v >= thr * base_v
                detail = (f"fresh/baseline = {fresh_v / base_v:.2f} "
                          f"(floor {thr:.2f})")
            else:
                rel = (abs(fresh_v - base_v) / abs(base_v)
                       if base_v else abs(fresh_v))
                ok = rel <= thr
                detail = f"|Δ|/baseline = {rel:.4f} (tol {thr:.2f})"
            report.append((name, key, base_v, fresh_v,
                           "ok" if ok else "FAIL", detail))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate fresh benchmark ratios against committed "
                    "baselines (never absolute timings).")
    ap.add_argument("--fresh", required=True,
                    help="BENCH json produced by this run")
    ap.add_argument("--baseline", action="append", required=True,
                    help="committed BENCH json (repeatable)")
    ap.add_argument("--spec", action="append", default=[],
                    help="extra gate: glob:derived_key:min_frac=F | "
                         "glob:derived_key:rel_tol=F")
    args = ap.parse_args(argv)

    try:
        gates = DEFAULT_GATES + [_parse_spec(s) for s in args.spec]
    except SpecError as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 2
    fresh = _load_rows(args.fresh)
    baseline = {}
    for path in args.baseline:
        baseline.update(_load_rows(path))

    report = compare(fresh, baseline, gates)
    if not report:
        print("bench_compare: no gated rows matched — refusing to pass "
              "an empty gate", file=sys.stderr)
        return 2

    width = max(len(n) for (n, *_ ) in report) + 2
    print(f"{'row':<{width}}{'key':<26}{'baseline':>10}{'fresh':>10}"
          f"  verdict")
    bad = 0
    for (name, key, base_v, fresh_v, verdict, detail) in report:
        fv = "—" if fresh_v is None else f"{fresh_v:.3f}"
        print(f"{name:<{width}}{key:<26}{base_v:>10.3f}{fv:>10}"
              f"  {verdict}  ({detail})")
        bad += verdict != "ok"
    if bad:
        print(f"\nbench_compare: {bad} gated metric(s) regressed or "
              "missing", file=sys.stderr)
        return 1
    print(f"\nbench_compare: {len(report)} gated metric(s) green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
