"""Heterogeneity-robust momentum variants riding the fused round.

The source paper's Assumption 4 bounds per-worker gradients uniformly —
exactly the assumption non-IID (Dirichlet-skewed) workloads violate, and
where PD-SGDM's plain local momentum drifts toward per-worker optima.
Two first-class optimizers remove (MT) or dampen (QG) that dependence
while keeping the paper's periodic structure (p local steps, one gossip):

* **MT-DSGDm** — Momentum Tracking [Takezawa et al. '22, arXiv:2209.15505],
  adapted to periodic gossip.  Each worker carries a gradient-tracking
  correction ``c`` whose worker-mean equals the worker-mean of the latest
  gradients (the tracking invariant), feeds *c* — not the raw local
  gradient — into the momentum recursion, and gossips ``(x, c)`` pairs at
  every communication round::

      ĝ⁽ᵏ⁾ₜ = ∇F(x⁽ᵏ⁾ₜ; ξ) + λ x⁽ᵏ⁾ₜ            (wd folded, PyTorch semantics)
      c⁽ᵏ⁾ₜ = c⁽ᵏ⁾ₜ₋₁ + ĝ⁽ᵏ⁾ₜ − ĝ⁽ᵏ⁾ₜ₋₁          (local tracking update)
      m⁽ᵏ⁾ₜ = μ m⁽ᵏ⁾ₜ₋₁ + c⁽ᵏ⁾ₜ
      x⁽ᵏ⁾ₜ₊½ = x⁽ᵏ⁾ₜ − η m⁽ᵏ⁾ₜ
      if mod(t+1, p) == 0:                        (gossip: TWO tensors)
          x⁽ᵏ⁾ ← Σⱼ w_kj x⁽ʲ⁾₊½ ;   c⁽ᵏ⁾ ← Σⱼ w_kj Q(c⁽ʲ⁾)

  With ``c₀ = ĝ₋₁ = 0`` the first step gives ``c = ĝ``, and both the
  local update and the (doubly-stochastic) mixing preserve
  ``mean_k c⁽ᵏ⁾ = mean_k ĝ⁽ᵏ⁾`` — the correction every worker descends
  along tracks the *global* gradient direction regardless of how skewed
  its local data is.  ``Q`` is optional compressed tracking: any wire
  codec (sign / top-k / rand-k / QSGD, ``repro.core.wire``) applied to
  the correction wire — every worker ships the codec payload and mixes
  the *quantized* corrections (its own included, so dense and sharded
  agree bitwise); ``Q = identity`` (no compressor) is the default.
  ``bytes_per_comm_round`` charges the true 2-tensor payload: full-
  precision x plus the exact codec bytes of c.

* **QG-DSGDm** — quasi-global momentum [Lin et al. '21, arXiv:2102.04761],
  adapted to periodic gossip.  The momentum buffer is frozen inside a
  round and updated once per gossip from the *globally mixed* round
  displacement — local gradient noise and heterogeneity never enter it
  directly::

      x⁽ᵏ⁾ₜ₊½ = x⁽ᵏ⁾ₜ − η (ĝ⁽ᵏ⁾ₜ + μ m⁽ᵏ⁾)       (m frozen within the round)
      at a gossip round r:
          x⁽ᵏ⁾ ← Σⱼ w_kj x⁽ʲ⁾₊½
          m⁽ᵏ⁾ ← μ m⁽ᵏ⁾ + (1−μ) (x⁽ᵏ⁾_prev − x⁽ᵏ⁾) / (η p)
          x⁽ᵏ⁾_prev ← x⁽ᵏ⁾

  One extra state tree (``xprev``, the post-gossip params of the previous
  round), zero extra communication — the wire stays one tensor.

Both run through the canonical fused round on both backends and on the
flatten-once (rows, 1024) kernel layout: the tracking update is a Pallas
AXPY (``gossip_mix_mat``), the momentum step is the momentum kernel, and
MT's dual gossip mixes matrix-to-matrix (compressed tracking uses the
codec's rows kernels when ``block == 1024``).  State trees (``c``,
``g_prev``, ``xprev``) are checkpointed exactly like CPD-SGDM's ``xhat``.

Backend support mirrors CPD-SGDM's gating: compressed tracking on the
sharded backend needs a static shift-structured topology (the payload
exchange is per-neighbour ``ppermute``); full-precision MT and QG compose
with time-varying schedules on both backends (the dual mix rides the same
per-round ``lax.switch`` programs as x).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Compressor
from repro.core.gossip import (CommBackend, DenseComm, ShardedComm,
                               worker_mask_like)
from repro.core.pdsgdm import PDSGDM, PDSGDMConfig
from repro.core.wire import make_codec, wire_key

__all__ = ["MTDSGDMConfig", "MTDSGDm", "QGDSGDMConfig", "QGDSGDm"]

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class MTDSGDMConfig(PDSGDMConfig):
    """MT-DSGDm shares PD-SGDM's knobs; the tracking wire is shaped by the
    compressor handed to the optimizer (None = full-precision c)."""


@dataclasses.dataclass(frozen=True)
class QGDSGDMConfig(PDSGDMConfig):
    """QG-DSGDm shares PD-SGDM's knobs (``nesterov`` is rejected: the
    buffer is not a gradient accumulator, there is nothing to look ahead
    along)."""


class MTDSGDm(PDSGDM):
    """Momentum Tracking, periodic form.  Gossips ``(x, c)`` pairs."""

    def __init__(self, config: MTDSGDMConfig, comm: CommBackend,
                 compressor: Optional[Compressor] = None):
        super().__init__(config, comm)
        self.compressor = compressor
        self.codec = make_codec(compressor) if compressor is not None else None
        if self.codec is not None and config.overlap:
            raise ValueError(
                "MT-DSGDm compressed tracking does not compose with "
                "overlap=True: the in-flight correction payload would need "
                "a second codec wire per round.  Drop the compressor "
                "(full-precision c overlaps on both backends) or run "
                "synchronous rounds.")
        if self.codec is not None and isinstance(comm, ShardedComm):
            if comm.topology.name == "hierarchical":
                raise ValueError(
                    "MT-DSGDm compressed tracking does not compose with the "
                    "sharded hierarchical backend: the correction wire would "
                    "need its own codec lane through the two-level round.  "
                    "Use the hierarchical inter_codec for x compression, or "
                    "run compressed tracking on a flat topology.")
            if comm.topology.name == "complete":
                raise ValueError(
                    "MT-DSGDm compressed tracking on the sharded backend "
                    "needs a shift-structured topology (ring/torus/"
                    "exponential); 'complete' has no per-neighbour wire.")
            if comm.period > 1:
                raise ValueError(
                    "MT-DSGDm compressed tracking requires a static "
                    "topology on the sharded backend: the correction "
                    "payload is exchanged per fixed neighbour.  Time-"
                    "varying schedules run compressed tracking on the "
                    "dense backend, or drop the compressor (full-precision "
                    "c composes with schedules on both backends).")

    # -- state ---------------------------------------------------------------
    def init(self, params):
        state = super().init(params)
        zeros = lambda t: tmap(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), t)
        # c₀ = ĝ₋₁ = 0: the first local step sets c = ĝ₀, establishing the
        # tracking invariant mean(c) = mean(ĝ) from step 0 onward.
        state["c"] = zeros(params)
        state["g_prev"] = zeros(params)
        return state

    # -- local step (tracking + momentum) -------------------------------------
    def local_step(self, state, params, grads):
        cfg = self.config
        lr = cfg.lr(state["step"]).astype(jnp.float32)
        mu = jnp.float32(cfg.mu)
        wd = jnp.float32(cfg.weight_decay)

        # ĝ = g + λx (decay folded before tracking, so c tracks the
        # regularized gradient the momentum actually consumes)
        g32 = tmap(lambda g, x: g.astype(jnp.float32)
                   + wd * x.astype(jnp.float32), grads, params)
        c_new = tmap(lambda c, g, gp: c + g - gp,
                     state["c"], g32, state["g_prev"])

        if cfg.use_kernel:
            from repro.kernels import ops as kops
            new_params, new_m = kops.momentum_update_tree(
                params, state["m"], c_new, mu=cfg.mu, lr=lr,
                weight_decay=0.0, nesterov=cfg.nesterov,
                interpret=cfg.kernel_interpret)
        else:
            def upd(x, m, c):
                m_new = mu * m + c
                d = (c + mu * m_new) if cfg.nesterov else m_new
                x_new = x.astype(jnp.float32) - lr * d
                return x_new.astype(x.dtype), m_new

            xs, treedef = jax.tree_util.tree_flatten(params)
            ms = treedef.flatten_up_to(state["m"])
            cs = treedef.flatten_up_to(c_new)
            pairs = [upd(x, m, c) for x, m, c in zip(xs, ms, cs)]
            new_params = treedef.unflatten([x for x, _ in pairs])
            new_m = treedef.unflatten([m for _, m in pairs])

        new_state = dict(state)
        new_state["m"] = new_m
        new_state["c"] = c_new
        new_state["g_prev"] = g32
        new_state["step"] = state["step"] + 1
        return new_params, new_state

    # -- overlapped rounds: staleness-refreshed tracking ------------------------
    # The divergence mechanism at large p is correction aging: c is only
    # re-synchronized at round boundaries, so late in a long round every
    # worker descends along a correction that is up to p steps stale.
    # Overlap turns the one-round-stale mix into a cure instead: the stale
    # tracking delta dc = W̃·c̃ − c̃ (formed at round start from the
    # in-flight payload, no data dependence on this round's compute) is
    # dripped into c as dc/p after *every* local step, so the correction is
    # refreshed mid-round instead of frozen — restoring stability at p ≥ 4.
    # Each drip preserves the tracking invariant: under doubly-stochastic
    # W̃, mean_k(dc⁽ᵏ⁾) = 0, so mean(c) = mean(ĝ) holds at every step.
    overlap_delta_keys: tuple = ("dx", "dc")
    overlap_refreshes: bool = True

    def _delayed_mix_init(self, params):
        mix = super()._delayed_mix_init(params)
        # c₀ = 0 → the first in-flight correction payload is zero too
        mix["buf_c"] = tmap(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), params)
        return mix

    def overlap_begin(self, state):
        mix = state["mix"]
        r = self.round_index(state)
        gate = (mix["phase"] > 0).astype(jnp.float32)
        mixed_x = self.comm.stale_mix(mix["buf"], r=r)
        mixed_c = self.comm.stale_mix(mix["buf_c"], r=r)
        return {
            "dx": tmap(lambda mb, b: (mb - b) * gate, mixed_x, mix["buf"]),
            "dc": tmap(lambda mc, c: (mc - c) * gate, mixed_c,
                       mix["buf_c"]),
        }

    def overlap_step_refresh(self, state, delta):
        inv_p = jnp.float32(1.0 / self.config.p)
        new_state = dict(state)
        new_state["c"] = tmap(lambda c, d: c + inv_p * d,
                              state["c"], delta["dc"])
        return new_state

    def _snapshot_mix(self, state, params):
        mix = super()._snapshot_mix(state, params)
        mix["buf_c"] = state["c"]
        return mix

    # -- communication: gossip (x, c) ------------------------------------------
    def _quantized_c(self, c, r):
        """Q(c) per worker through the wire codec (pack∘unpack), with the
        shared (leaf, round) keys — identical draws on both backends."""
        leaves, treedef = jax.tree_util.tree_flatten(c)
        out = []
        for i, leaf in enumerate(leaves):
            key = wire_key(r, i)
            if isinstance(self.comm, DenseComm):
                shape = leaf.shape[1:]
                n = int(np.prod(shape, dtype=np.int64)) if shape else 1
                q = jax.vmap(lambda x: self.codec.unpack(
                    self.codec.pack(x, key), n, shape, jnp.float32,
                    key=key))(leaf)
            else:
                q = self.codec.unpack(self.codec.pack(leaf, key), leaf.size,
                                      leaf.shape, jnp.float32, key=key)
            out.append(q)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _mix_c_sharded(self, c, r):
        """Compressed-tracking mix on the production backend: each worker
        quantizes its own c, ships the codec's wire payload to every
        neighbour (one ppermute per payload array), and mixes the decoded
        corrections — self term quantized too, matching the dense sim."""
        leaves, treedef = jax.tree_util.tree_flatten(c)
        payloads, keys, mixed = [], [], []
        w0 = jnp.float32(self.comm.self_weight())
        for i, leaf in enumerate(leaves):
            key = wire_key(r, i)
            payload = self.codec.pack(leaf, key)
            q = self.codec.unpack(payload, leaf.size, leaf.shape,
                                  jnp.float32, key=key)
            payloads.append(payload)
            keys.append(key)
            mixed.append(w0 * q)
        for (ax, sh, w) in self.comm.nonself_shifts():
            for j, (leaf, payload, key) in enumerate(
                    zip(leaves, payloads, keys)):
                recv = self.comm.receive_payload(self.codec.wire(payload),
                                                 ax, sh)
                q_r = self.codec.unpack(recv, leaf.size, leaf.shape,
                                        jnp.float32, key=key)
                mixed[j] = mixed[j] + jnp.float32(w) * q_r
        return jax.tree_util.tree_unflatten(treedef, mixed)

    def _mix_c_sharded_elastic(self, c, r):
        """Compressed-tracking mix under elastic membership: one statically
        masked branch per round of the joint cycle, selected by
        ``lax.switch`` — mirroring the masked mixing programs in
        :meth:`~repro.core.gossip.ShardedComm.mix`."""
        Lc = self.comm.round_cycle
        if Lc == 1:
            return self._mix_c_sharded_masked(0, c, r)
        idx = jnp.mod(jnp.asarray(r, jnp.int32), Lc)
        branches = [partial(self._mix_c_sharded_masked, l)
                    for l in range(Lc)]
        return jax.lax.switch(idx, branches, c, r)

    def _mix_c_sharded_masked(self, l, c, r):
        """One compressed correction mix with only round ``l``'s active
        workers exchanging: payload ppermutes pruned to edges with both
        endpoints active, per-receiver coefficients from the shift entries
        (lost neighbour mass to the quantized self term), and an inactive
        worker's c left *raw* — a straggler skips the exchange entirely,
        it does not quantize in place."""
        comm = self.comm
        act = comm.active_at(l)
        if act.all():
            return self._mix_c_sharded(c, r)
        top = comm.topology_at(l)
        n = top.n_workers
        idx = jax.lax.axis_index(comm.axis_names[0])
        ks = np.arange(n)

        off = np.zeros(n)
        edges = []   # (ax, sh, coeff (n,), source_ok (n,))
        for (ax, sh, w) in comm.nonself_shifts():
            if sh % n == 0:   # self-aliased: weight folds into the diag
                continue
            src = (ks + sh) % n
            coeff = np.where(act & act[src], w, 0.0)
            off += coeff
            # an edge ships iff BOTH endpoints are active; for a fixed
            # shift that is a predicate on the source alone
            source_ok = act & act[(ks - sh) % n]
            edges.append((ax, sh, coeff.astype(np.float32), source_ok))
        diag = jnp.asarray((1.0 - off).astype(np.float32))[idx]
        active_self = jnp.asarray(act)[idx]

        leaves, treedef = jax.tree_util.tree_flatten(c)
        mixed = []
        for i, leaf in enumerate(leaves):
            key = wire_key(r, i)
            payload = self.codec.pack(leaf, key)
            q = self.codec.unpack(payload, leaf.size, leaf.shape,
                                  jnp.float32, key=key)
            acc = diag * q
            for (ax, sh, coeff, source_ok) in edges:
                recv = {nm: comm._receive_from_committed(v, ax, sh,
                                                         source_ok)
                        for nm, v in self.codec.wire(payload).items()}
                q_r = self.codec.unpack(recv, leaf.size, leaf.shape,
                                        jnp.float32, key=key)
                acc = acc + jnp.asarray(coeff)[idx] * q_r
            mixed.append(jnp.where(active_self, acc, leaf))
        return jax.tree_util.tree_unflatten(treedef, mixed)

    def comm_round(self, state, params):
        r = self.round_index(state)
        params_new = self.comm.mix(params, r=r)
        new_state = dict(state)
        if self.codec is None:
            new_state["c"] = self.comm.mix(state["c"], r=r)
        elif isinstance(self.comm, ShardedComm):
            if self.comm.membership is not None:
                new_state["c"] = self._mix_c_sharded_elastic(state["c"], r)
            else:
                new_state["c"] = self._mix_c_sharded(state["c"], r)
        else:
            mixed = self.comm.mix(self._quantized_c(state["c"], r), r=r)
            if self.comm.membership is not None:
                # a straggler's masked row is e_k, which would quantize its
                # c in place without any exchange — pin the raw c instead
                am = self.comm.active_mask(r)
                mixed = tmap(
                    lambda mc, cc: jnp.where(worker_mask_like(am, mc),
                                             mc, cc),
                    mixed, state["c"])
            new_state["c"] = mixed
        return params_new, new_state

    # -- kernel round (flatten-once matrix domain) ------------------------------
    def _kernel_wire(self) -> bool:
        from repro.kernels import ops as kops
        return (self.codec is not None and self.codec.rows_supported
                and self.codec.block == kops.LANE)

    @property
    def kernel_comm_supported(self) -> bool:
        """Full-precision c mixes like x (always matrix-capable — the
        matrix gossip delegates to the membership-aware ``comm.mix`` when
        needed); compressed tracking needs the codec's rows kernels *and*
        full membership (under churn the round falls back to the tree
        comm at the boundary, where the masked correction wire lives)."""
        return self.codec is None or (self._kernel_wire()
                                      and self.comm.membership is None)

    def mat_state(self, plan, state) -> dict:
        mats = super().mat_state(plan, state)
        mats["c"] = plan.flatten(state["c"])
        mats["g_prev"] = plan.flatten(state["g_prev"])
        if self.config.overlap:
            mats["mix_buf_c"] = plan.flatten(state["mix"]["buf_c"])
        return mats

    def unmat_state(self, plan, mats, state, step) -> dict:
        new_state = super().unmat_state(plan, mats, state, step)
        new_state["c"] = plan.unflatten(mats["c"], dtype=jnp.float32)
        new_state["g_prev"] = plan.unflatten(mats["g_prev"],
                                             dtype=jnp.float32)
        if self.config.overlap:
            new_state["mix"] = {
                **new_state["mix"],
                "buf_c": plan.unflatten(mats["mix_buf_c"],
                                        dtype=jnp.float32),
            }
        return new_state

    def overlap_begin_mat(self, mats, r, gate, *, plan=None):
        delta = super().overlap_begin_mat(mats, r, gate, plan=plan)
        buf_c = mats["mix_buf_c"]
        mixed_c = self._stale_gossip_mat(buf_c, r, plan=plan)
        delta["dc"] = (mixed_c - buf_c) * gate
        return delta

    def overlap_refresh_mat(self, mats, delta):
        """Drip the stale tracking delta (fused AXPY with the static 1/p
        weight — the drip count per round is the static period)."""
        from repro.kernels import ops as kops
        c_new = kops.gossip_mix_mat((mats["c"], delta["dc"]),
                                    (1.0, 1.0 / self.config.p),
                                    interpret=self.config.kernel_interpret)
        return {**mats, "c": c_new}

    def overlap_apply_mat(self, x_mat, mats, delta, r):
        x_new, mats = super().overlap_apply_mat(x_mat, mats, delta, r)
        return x_new, {**mats, "mix_buf_c": mats["c"]}

    def local_step_mat(self, x_mat, mats, g_mat, step):
        """Tracking update as a fused Pallas AXPY, then the momentum
        kernel — the extra tracking matrix rides the same flatten-once
        layout as params and momentum."""
        from repro.kernels import ops as kops
        cfg = self.config
        interp = cfg.kernel_interpret
        if cfg.weight_decay:
            g32 = kops.gossip_mix_mat((g_mat, x_mat),
                                      (1.0, cfg.weight_decay),
                                      interpret=interp)
        else:
            g32 = g_mat
        c_new = kops.gossip_mix_mat((mats["c"], g32, mats["g_prev"]),
                                    (1.0, 1.0, -1.0), interpret=interp)
        x_new, m_new = kops.momentum_update_mat(
            x_mat, mats["m"], c_new, mu=cfg.mu,
            lr=cfg.lr(step).astype(jnp.float32), weight_decay=0.0,
            nesterov=cfg.nesterov, interpret=interp)
        return x_new, {**mats, "m": m_new, "c": c_new, "g_prev": g32}

    def comm_round_mat(self, x_mat, mats, counts, r, *, plan=None):
        """Dual gossip on the kernel layout: x and c mix matrix-to-matrix;
        compressed tracking packs c with the codec's rows kernels and
        ships the payload trimmed to its wire extent by ``rows_wire``
        (alignment padding never crosses the wire; sparse payloads are
        already compact), exactly like CPD-SGDM's drift wire."""
        x_new = self._gossip_mat(x_mat, r, plan=plan)
        c = mats["c"]
        if self.codec is None:
            c_new = self._gossip_mat(c, r, plan=plan)
        else:
            interp = self.config.kernel_interpret
            payload = self.codec.rows_pack(c, counts=counts,
                                           interpret=interp, plan=plan)
            q_self = self.codec.rows_unpack(payload, interpret=interp,
                                            plan=plan)
            if isinstance(self.comm, ShardedComm):
                assert plan is not None, (
                    "MT-DSGDm matrix comm needs the KernelPlan")
                wire = self.codec.rows_wire(payload, plan)
                c_new = jnp.float32(self.comm.self_weight()) * q_self
                for (ax, sh, w) in self.comm.nonself_shifts():
                    recv = self.codec.rows_unwire(
                        {name: self.comm._receive_from(arr, ax, sh)
                         for name, arr in wire.items()}, plan)
                    c_new = c_new + jnp.float32(w) * self.codec.rows_unpack(
                        recv, interpret=interp, plan=plan)
            else:
                c_new = self._gossip_mat(q_self, r)
        return x_new, {**mats, "c": c_new}

    # -- comm-cost model --------------------------------------------------------
    def bytes_per_comm_round(self, params, r: int = 0) -> int:
        """The true 2-tensor payload: full-precision x (leaf dtypes) plus
        the correction wire — exact codec bytes when compressed, f32
        otherwise — both × the round's edge multiplier (the topology
        degree; under elastic membership the active-edge count averaged
        over workers, dead edges shipping zero bytes)."""
        from repro.core.gossip import gossip_bytes_per_round
        top = self.comm.topology_at(r)
        if top.name == "hierarchical" and self.comm.membership is None:
            # x and the uncompressed c ship through identical two-level
            # rounds (compressed tracking + hierarchical is rejected at
            # construction) — hier_bytes_per_level below doubles per level
            return self.hier_bytes_per_level(params, r=r)["inter"]
        deg = top.degree
        epw = self.comm.edges_per_worker(r)
        if self._kernel_wire_active():
            x_bytes = deg * self._mat_wire_bytes(params)
        else:
            x_bytes = gossip_bytes_per_round(params, self.comm, r=r)
        leaves = jax.tree_util.tree_leaves(params)
        if self.codec is not None:
            c_payload = sum(
                self.codec.wire_bytes(int(np.prod(l.shape, dtype=np.int64)))
                for l in leaves)
        elif self._kernel_wire_active():
            # uncompressed c ships on the same used_rows kernel wire as x
            c_payload = self._mat_wire_bytes(params)
        else:
            item = min(4, getattr(self.comm, "wire_itemsize", 4))
            c_payload = sum(int(np.prod(l.shape, dtype=np.int64)) * item
                            for l in leaves)
        return x_bytes + epw * c_payload

    def hier_bytes_per_level(self, params, r: int = 0) -> dict:
        """MT gossips the ``(x, c)`` pair: every level of the two-level
        round runs twice per exchange, so each accounted entry doubles."""
        levels = super().hier_bytes_per_level(params, r=r)
        return {k: 2 * v for k, v in levels.items()}


class QGDSGDm(PDSGDM):
    """Quasi-global momentum, periodic form.  Gossips x only."""

    def __init__(self, config: QGDSGDMConfig, comm: CommBackend):
        if config.nesterov:
            raise ValueError(
                "QG-DSGDm has no nesterov variant: the quasi-global buffer "
                "is a displacement average, not a gradient accumulator")
        super().__init__(config, comm)

    # -- state ---------------------------------------------------------------
    def init(self, params):
        state = super().init(params)
        # the previous round's post-gossip params (f32 master copy): the
        # buffer update differences against it at every communication round
        state["xprev"] = tmap(lambda x: x.astype(jnp.float32), params)
        return state

    # -- local step: momentum-corrected gradient, frozen buffer ----------------
    def local_step(self, state, params, grads):
        cfg = self.config
        lr = cfg.lr(state["step"]).astype(jnp.float32)
        mu = jnp.float32(cfg.mu)
        wd = jnp.float32(cfg.weight_decay)

        if cfg.use_kernel:
            from repro.kernels import ops as kops
            # the momentum kernel's x update is exactly x − η(μm + ĝ);
            # its m update is discarded (the buffer only moves at gossip)
            new_params, _ = kops.momentum_update_tree(
                params, state["m"], grads, mu=cfg.mu, lr=lr,
                weight_decay=cfg.weight_decay, nesterov=False,
                interpret=cfg.kernel_interpret)
        else:
            def upd(x, m, g):
                g32 = g.astype(jnp.float32) + wd * x.astype(jnp.float32)
                d = mu * m + g32
                return (x.astype(jnp.float32) - lr * d).astype(x.dtype)

            xs, treedef = jax.tree_util.tree_flatten(params)
            ms = treedef.flatten_up_to(state["m"])
            gs = treedef.flatten_up_to(grads)
            new_params = treedef.unflatten(
                [upd(x, m, g) for x, m, g in zip(xs, ms, gs)])

        new_state = dict(state)
        new_state["step"] = state["step"] + 1
        return new_params, new_state

    def _round_lr(self, r):
        """η at the round's last local step (t = (r+1)·p − 1): the
        normalizer of the displacement → direction conversion."""
        cfg = self.config
        step_last = (jnp.asarray(r) + 1) * cfg.p - 1
        return cfg.lr(step_last).astype(jnp.float32)

    # -- communication: mix, then fold the global displacement into m ----------
    # Elastic membership composes without extra gating: a straggler's
    # masked row is e_k, so `mixed` is its own x and d_hat degrades to the
    # worker's local round displacement — the buffer keeps moving on local
    # progress instead of stalling.  Dead workers are warm-started from a
    # live donor at revival, so a stale (m, xprev) never re-enters.
    def comm_round(self, state, params):
        cfg = self.config
        mu = jnp.float32(cfg.mu)
        r = self.round_index(state)
        mixed = self.comm.mix(params, r=r)
        inv = jnp.float32(1.0) / (self._round_lr(r) * jnp.float32(cfg.p))
        d_hat = tmap(lambda xp, xm: (xp - xm.astype(jnp.float32)) * inv,
                     state["xprev"], mixed)
        new_state = dict(state)
        new_state["m"] = tmap(
            lambda m, d: mu * m + (jnp.float32(1.0) - mu) * d,
            state["m"], d_hat)
        new_state["xprev"] = tmap(lambda x: x.astype(jnp.float32), mixed)
        return mixed, new_state

    # -- overlapped rounds ------------------------------------------------------
    # The stale correction lands on the drifted params at round end; the
    # quasi-global buffer then folds the realized round displacement
    # (xprev − x_new)/(ηp) exactly as in the synchronous form — on round 0
    # (gate 0, nothing in flight) d_hat degrades to the local round
    # displacement, mirroring the elastic-straggler semantics above.
    def overlap_apply(self, state, params, delta):
        cfg = self.config
        mu = jnp.float32(cfg.mu)
        r = self.round_index(state)
        x32 = tmap(lambda x, d: x.astype(jnp.float32) + d,
                   params, delta["dx"])
        inv = jnp.float32(1.0) / (self._round_lr(r) * jnp.float32(cfg.p))
        new_state = dict(state)
        new_state["m"] = tmap(
            lambda m, xp, xn: mu * m + (jnp.float32(1.0) - mu)
            * (xp - xn) * inv,
            state["m"], state["xprev"], x32)
        new_state["xprev"] = x32
        params_new = tmap(lambda x32_, x: x32_.astype(x.dtype), x32, params)
        new_state["mix"] = self._snapshot_mix(new_state, params_new)
        return params_new, new_state

    # -- kernel round ----------------------------------------------------------
    def mat_state(self, plan, state) -> dict:
        mats = super().mat_state(plan, state)
        mats["xprev"] = plan.flatten(state["xprev"])
        return mats

    def unmat_state(self, plan, mats, state, step) -> dict:
        new_state = super().unmat_state(plan, mats, state, step)
        new_state["xprev"] = plan.unflatten(mats["xprev"],
                                            dtype=jnp.float32)
        return new_state

    def local_step_mat(self, x_mat, mats, g_mat, step):
        from repro.kernels import ops as kops
        cfg = self.config
        x_new, _ = kops.momentum_update_mat(
            x_mat, mats["m"], g_mat, mu=cfg.mu,
            lr=cfg.lr(step).astype(jnp.float32),
            weight_decay=cfg.weight_decay, nesterov=False,
            interpret=cfg.kernel_interpret)
        return x_new, mats

    def comm_round_mat(self, x_mat, mats, counts, r, *, plan=None):
        cfg = self.config
        mu = jnp.float32(cfg.mu)
        x_new = self._gossip_mat(x_mat, r, plan=plan)
        inv = jnp.float32(1.0) / (self._round_lr(r) * jnp.float32(cfg.p))
        d_hat = (mats["xprev"] - x_new) * inv
        m_new = mu * mats["m"] + (jnp.float32(1.0) - mu) * d_hat
        return x_new, {**mats, "m": m_new, "xprev": x_new}

    def overlap_apply_mat(self, x_mat, mats, delta, r):
        from repro.kernels import ops as kops
        cfg = self.config
        mu = jnp.float32(cfg.mu)
        x_new = kops.delayed_mix_mat(x_mat, delta["dx"],
                                     interpret=cfg.kernel_interpret)
        inv = jnp.float32(1.0) / (self._round_lr(r) * jnp.float32(cfg.p))
        d_hat = (mats["xprev"] - x_new) * inv
        m_new = mu * mats["m"] + (jnp.float32(1.0) - mu) * d_hat
        return x_new, {**mats, "m": m_new, "xprev": x_new,
                       "mix_buf": x_new}
