"""Mamba-2 SSD (state-space duality) mixer — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: within a chunk the output is
a masked (decay-weighted) attention-like quadratic form; across chunks a
linear recurrence carries the (heads, headdim, d_state) SSM state.  Chunking
makes the op O(s·Q) with MXU-friendly matmuls instead of an O(s) sequential
scan.  Decode is the O(1) recurrent step on the cached state.

Layout notes (TPU adaptation): head/p/n dims are kept as explicit trailing
dims (multiples of 64/128) so every einsum maps onto the MXU; the chunk scan
is a ``lax.scan`` whose carry is the SSM state (small), so XLA keeps the big
intra-chunk tensors out of the loop carry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["Mamba2Cfg", "mamba2_init", "mamba2_apply", "mamba2_decode",
           "init_mamba_cache"]


@dataclasses.dataclass(frozen=True)
class Mamba2Cfg:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_kernel: int = 4
    n_groups: int = 1
    bcast_groups: bool = False  # broadcast (not gather) group->head expand

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_proj_dim(self) -> int:
        # z, xBC, dt
        return self.d_inner + self.conv_dim + self.n_heads


def mamba2_init(key, cfg: Mamba2Cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h = cfg.n_heads
    return {
        "in_proj": dense_init(k1, cfg.d_model, cfg.in_proj_dim, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, cfg.conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": dense_init(k3, cfg.d_inner, cfg.d_model, dtype),
    }


def _split_zxbcdt(cfg: Mamba2Cfg, zxbcdt):
    z, xBC, dt = jnp.split(
        zxbcdt, [cfg.d_inner, cfg.d_inner + cfg.conv_dim], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d.  xBC: (b, s, c); w: (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)
                       ).astype(xBC.dtype)


def _split_xbc(cfg: Mamba2Cfg, xBC, bsz, s):
    gn = cfg.n_groups * cfg.d_state
    x, B, C = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + gn], axis=-1)
    x = x.reshape(bsz, s, cfg.n_heads, cfg.headdim)
    B = B.reshape(bsz, s, cfg.n_groups, cfg.d_state)
    C = C.reshape(bsz, s, cfg.n_groups, cfg.d_state)
    # groups -> heads
    rep = cfg.n_heads // cfg.n_groups
    if cfg.bcast_groups:
        # broadcast+reshape lowers to an HLO broadcast; jnp.repeat lowers to
        # a gather, which the SPMD partitioner resolves with a full
        # all-reduce of the expanded (b,s,h,n) tensor per layer (§Perf).
        B = jnp.broadcast_to(B[:, :, :, None, :],
                             (bsz, s, cfg.n_groups, rep, cfg.d_state)
                             ).reshape(bsz, s, cfg.n_heads, cfg.d_state)
        C = jnp.broadcast_to(C[:, :, :, None, :],
                             (bsz, s, cfg.n_groups, rep, cfg.d_state)
                             ).reshape(bsz, s, cfg.n_heads, cfg.d_state)
    else:
        B = jnp.repeat(B, rep, axis=2)
        C = jnp.repeat(C, rep, axis=2)
    return x, B, C


def mamba2_apply(params, u, cfg: Mamba2Cfg, return_state: bool = False):
    """u: (b, s, d_model) -> (b, s, d_model) [, decode cache].  Chunked SSD."""
    bsz, s, _ = u.shape
    Q = min(cfg.chunk, s)
    assert s % Q == 0, f"seq {s} % chunk {Q} != 0"
    nc = s // Q
    h, p, n = cfg.n_heads, cfg.headdim, cfg.d_state

    zxbcdt = dense(params["in_proj"], u)
    z, xBC_raw, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    x, B, C = _split_xbc(cfg, xBC, bsz, s)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])       # (b,s,h)
    A = -jnp.exp(params["A_log"])                                  # (h,)
    dA = dt * A[None, None, :]                                     # (b,s,h) ≤ 0

    # chunked views
    xc = x.reshape(bsz, nc, Q, h, p).astype(jnp.float32)
    Bc = B.reshape(bsz, nc, Q, h, n).astype(jnp.float32)
    Cc = C.reshape(bsz, nc, Q, h, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, Q, h)
    dAc = dA.reshape(bsz, nc, Q, h)
    cum = jnp.cumsum(dAc, axis=2)                                  # (b,nc,Q,h)

    # ---- intra-chunk (quadratic, attention-like with decay mask)
    # L[q, j] = exp(cum_q - cum_j) for j <= q
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (b,nc,Q,Q,h)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask *before* exp: exp of the (positive) upper-triangular part would
    # overflow and poison gradients through the where.
    rel = jnp.where(mask[None, None, :, :, None], rel, -1e9)
    L = jnp.exp(rel)
    att = jnp.einsum("bcqhn,bcjhn->bcqjh", Cc, Bc) * L
    y_intra = jnp.einsum("bcqjh,bcjh,bcjhp->bcqhp", att, dtc, xc)

    # ---- chunk states:  S_c = Σ_j exp(cum_Q - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                # (b,nc,Q,h)
    states = jnp.einsum("bcjh,bcjh,bcjhn,bcjhp->bchnp",
                        decay_to_end, dtc, Bc, xc)                 # (b,nc,h,n,p)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # (b,nc,h)

    def scan_fn(S, inp):
        st, dec = inp            # (b,h,n,p), (b,h)
        S_new = S * dec[:, :, None, None] + st
        return S_new, S          # emit state *entering* the chunk

    S0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    S_final, S_in = jax.lax.scan(
        scan_fn, S0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                                # (b,nc,h,n,p)

    # ---- inter-chunk:  y_q += exp(cum_q) C_q · S_in
    y_inter = jnp.einsum("bcqh,bcqhn,bchnp->bcqhp",
                         jnp.exp(cum), Cc, S_in)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, s, cfg.d_inner).astype(u.dtype)

    # gated RMSNorm then output projection
    y = rmsnorm(params["norm"],
                (y.astype(jnp.float32)
                 * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype))
    out = dense(params["out_proj"], y)
    if not return_state:
        return out
    # decode cache: final SSM state + last (k-1) raw conv inputs
    kk = cfg.conv_kernel - 1
    conv_tail = xBC_raw[:, s - kk:, :] if s >= kk else jnp.pad(
        xBC_raw, ((0, 0), (kk - s, 0), (0, 0)))
    return out, {"ssm": S_final, "conv": conv_tail}


# ---------------------------------------------------------------------------- decode
def init_mamba_cache(cfg: Mamba2Cfg, batch: int, dtype):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.headdim),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.conv_dim), dtype),
    }


def mamba2_decode(params, u, cache, cfg: Mamba2Cfg):
    """One token.  u: (b, 1, d_model)."""
    bsz = u.shape[0]
    h, p, n = cfg.n_heads, cfg.headdim, cfg.d_state

    zxbcdt = dense(params["in_proj"], u)
    z, xBC_new, dt_raw = _split_zxbcdt(cfg, zxbcdt)

    # rolling conv state
    conv_in = jnp.concatenate([cache["conv"], xBC_new], axis=1)  # (b, k, c)
    w = params["conv_w"]
    out = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                     w.astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(out)[:, None, :].astype(u.dtype)
    new_conv = conv_in[:, 1:, :]

    x, B, C = _split_xbc(cfg, xBC, bsz, 1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])[:, 0]   # (b,h)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                                    # (b,h)

    x0 = x[:, 0].astype(jnp.float32)      # (b,h,p)
    B0 = B[:, 0].astype(jnp.float32)      # (b,h,n)
    C0 = C[:, 0].astype(jnp.float32)
    S = cache["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, B0, x0)
    y = jnp.einsum("bhn,bhnp->bhp", C0, S)
    y = y + params["D"][None, :, None] * x0
    y = y.reshape(bsz, 1, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"],
                (y.astype(jnp.float32)
                 * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype))
    return dense(params["out_proj"], y), {"ssm": S, "conv": new_conv}
