"""QSGD quantization kernels: blockwise s-level quantize + bit-pack on the
flatten-once (rows, 1024) layout.

  * ``qsgd_quant_kernel``   — x (rows, 1024) f32 → packed levels
                              (rows, 1024·bits/8) uint8 + norms (rows, 1) f32.
  * ``qsgd_dequant_kernel`` — inverse: Q(x) = (u − s)/s · norm.

One *row* is one quantization block: ``norm = max |x|`` over the row, then
``u = round(x / norm · s) + s`` ∈ [0, 2s] packed ``8/bits`` elements per
byte with ``bits = qsgd_bits(levels)`` ∈ {2, 4, 8} (same weighted-sum
in-register bit-gather as the sign kernel — lane shifts within a vreg, no
HBM round-trip).  Deterministic nearest rounding keeps the operator a
δ-contraction; the jnp oracle is ``repro.core.wire.qsgd_rows``.

Padding contract: the ``KernelPlan`` zero-pads tail rows, and 0 quantizes
to the center level u = s which dequantizes back to exactly 0, so no
counts operand is needed (unlike sign, whose *scale* depends on the true
length).  All-padding rows carry norm 0 and dequantize to 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# the bit-width rule is owned by the wire codec (one source of truth for
# the kernel, the jnp oracle, and the byte accounting)
from repro.core.wire import qsgd_bits as _bits
from repro.kernels import LANE, default_interpret

__all__ = ["qsgd_quant_pallas", "qsgd_dequant_pallas", "LANE", "BLOCK_ROWS"]

BLOCK_ROWS = 256


def _quant_kernel(x_ref, packed_ref, norm_ref, *, levels, bits):
    x = x_ref[...]                                    # (BR, 1024) f32
    br = x.shape[0]
    vpb = 8 // bits
    s = jnp.float32(levels)
    norm = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    norm_ref[...] = norm
    # scale-first, single elementwise multiply — mirrors the jnp oracle so
    # no lowering can reassociate the div/mul chain (see wire.qsgd_rows)
    qscale = s / jnp.maximum(norm, 1e-30)
    u = (jnp.round(x * qscale) + s).astype(jnp.uint8)
    grouped = u.reshape(br, LANE // vpb, vpb)
    weights = (jnp.uint8(1) << (jnp.uint8(bits)
                                * jnp.arange(vpb, dtype=jnp.uint8)))
    packed_ref[...] = jnp.sum(grouped * weights, axis=-1).astype(jnp.uint8)


def _dequant_kernel(packed_ref, norm_ref, out_ref, *, levels, bits):
    pk = packed_ref[...]                              # (BR, 1024·bits/8) u8
    br = pk.shape[0]
    vpb = 8 // bits
    mask = jnp.uint8((1 << bits) - 1)
    shifts = jnp.uint8(bits) * jnp.arange(vpb, dtype=jnp.uint8)
    u = (pk[:, :, None] >> shifts) & mask
    s = jnp.float32(levels)
    # mirrors wire.qsgd_rows_unpack's bit-determinism contract: reciprocal
    # constant (no constant division), scale formed first (single
    # multiply), and the norm>0 select (empty rows → exact +0)
    inv_s = jnp.float32(np.float32(1.0) / np.float32(levels))
    norm = norm_ref[...]
    scale = inv_s * norm
    vals = (u.reshape(br, LANE).astype(jnp.float32) - s) * scale
    out_ref[...] = jnp.where(norm > 0, vals, jnp.float32(0.0))


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def qsgd_quant_pallas(x, *, levels: int, interpret: bool | None = None):
    """x: (rows, 1024) f32 → (packed (rows, 1024·bits/8) u8,
    norms (rows, 1) f32)."""
    if interpret is None:
        interpret = default_interpret()
    rows, lane = x.shape
    assert lane == LANE and rows % BLOCK_ROWS == 0, (rows, lane)
    bits = _bits(levels)
    packed_w = LANE * bits // 8
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_quant_kernel, levels=levels, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, packed_w), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, packed_w), jnp.uint8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def qsgd_dequant_pallas(packed, norms, *, levels: int,
                        interpret: bool | None = None):
    """(rows, 1024·bits/8) u8 + (rows, 1) f32 → Q(x) (rows, 1024) f32."""
    if interpret is None:
        interpret = default_interpret()
    rows = packed.shape[0]
    bits = _bits(levels)
    assert packed.shape[1] == LANE * bits // 8 and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_dequant_kernel, levels=levels, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE * bits // 8),
                               lambda i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)],
        interpret=interpret,
    )(packed, norms.reshape(rows, 1).astype(jnp.float32))[0]
