"""Config schema: model architecture, parallelism, optimizer, input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.kernels import LANE    # import-light (no jax)

__all__ = ["LayerSpec", "ModelCfg", "ParallelCfg", "OptimCfg", "RunCfg"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating block pattern."""
    mixer: str = "attn"      # "attn" | "mla" | "mamba"
    ffn: str = "dense"       # "dense" | "moe" | "dense+moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    arch_type: str                  # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparametric
    qkv_bias: bool = False
    window: Optional[int] = None    # sliding-window attention
    rope_theta: float = 10000.0
    gated_mlp: bool = True
    tie_embeddings: bool = False
    # --- block pattern (repeated n_layers / len(pattern) times)
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # --- MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_groups: int = 1             # per-group dispatch (see moe.MoECfg)
    # --- MLA (minicpm3)
    use_mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # --- SSM (mamba2 / jamba)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # lower the group->head B/C expansion as broadcast instead of
    # gather/repeat (perf iteration; semantically identical)
    ssm_bcast_groups: bool = False
    # --- input modality
    input_mode: str = "tokens"      # tokens | embeds | vlm
    n_patches: int = 1024           # vlm patch-prefix length  # lint: allow
    # --- dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # --- citation for the assigned-architecture pool
    source: str = ""

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    def params_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        for spec in self.pattern:
            n = self.n_repeats
            if spec.mixer == "attn":
                total += n * d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += n * self.n_heads * hd * d
            elif spec.mixer == "mla":
                qk = self.qk_nope_dim + self.qk_rope_dim
                total += n * (d * self.q_lora_rank
                              + self.q_lora_rank * self.n_heads * qk
                              + d * self.kv_lora_rank + d * self.qk_rope_dim
                              + self.kv_lora_rank * self.n_heads
                              * (self.qk_nope_dim + self.v_head_dim)
                              + self.n_heads * self.v_head_dim * d)
            elif spec.mixer == "mamba":
                di = self.ssm_expand * d
                conv = di + 2 * self.ssm_state
                total += n * (d * (2 * di + 2 * self.ssm_state
                                   + di // self.ssm_headdim)
                              + 4 * conv + di * d)
            if spec.ffn in ("dense", "dense+moe"):
                total += n * d * f * (3 if self.gated_mlp else 2)
            if spec.ffn in ("moe", "dense+moe"):
                total += n * (d * self.n_experts
                              + self.n_experts * d * f
                              * (3 if self.gated_mlp else 2))
        return total

    def active_params_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.params_count()
        dense_cfg = dataclasses.replace(
            self, n_experts=max(self.top_k, 1),
            pattern=self.pattern)
        return dense_cfg.params_count()


@dataclasses.dataclass(frozen=True)
class ParallelCfg:
    """How an arch maps onto the mesh.

    profile "A": decentralized worker per ("pod","data") index, TP on model.
    profile "B": worker per pod; FSDP over data + TP over model inside.
    """
    profile: str = "A"
    topology: str = "ring"          # gossip graph between workers
    # Hierarchical two-level gossip: group the worker axis into nodes of
    # `node_size` (0 = flat gossip).  Each round averages exactly inside
    # every node (fast intra links) and gossips node means between node
    # leaders over `topology` on the slow links (ring/exponential/
    # complete inter graph).  On a ("pod","data") two-axis worker layout
    # node_size must equal the inner-axis size (the pod boundary is the
    # node boundary).
    node_size: int = 0
    # compress the hierarchical inter-node wire with a keyless WireCodec
    # ("none" | identity | sign | topk | qsgd); flat gossip ignores it
    inter_codec: str = "none"
    # time-varying gossip: "static" keeps `topology`; otherwise one of
    # one_peer_exp | alt_axes | random_matching | hier_one_peer
    # (see core.topology.make_schedule; hier_one_peer needs node_size > 0)
    topology_schedule: str = "static"
    schedule_rounds: int = 0        # random_matching cycle length (0 = max(2, ⌈log₂K⌉))
    schedule_seed: int = 0          # random_matching matchings are seeded
    remat: str = "full"             # none | full
    fsdp_min_size: int = 2 ** 16    # don't shard tiny leaves
    # --- perf-iteration levers (defaults = paper-faithful baseline) ---
    inner: str = "tp"               # profile A inner parallelism: tp | dp
    attn_ctx_shard: bool = False    # context-parallel attention core
    moe_token_shard: bool = False   # constrain MoE token/expert sharding


@dataclasses.dataclass(frozen=True)
class OptimCfg:
    # pd_sgdm | cpd_sgdm | mt_dsgdm | qg_dsgdm | c_sgdm | d_sgd | ...
    name: str = "pd_sgdm"
    eta: float = 0.1
    mu: float = 0.9
    p: int = 4
    gamma: float = 0.4
    weight_decay: float = 1e-4
    # mt_dsgdm only: ship the gradient-tracking correction c through the
    # named wire codec below (compressed tracking) instead of full
    # precision.  Off by default — MT's correction wire is f32 unless
    # explicitly opted in (`--track-compressed` in launch.train).
    track_compressed: bool = False
    # --- wire codec (cpd_sgdm / choco): which δ-contraction ships, and its
    # shape knobs.  Every named compressor has a first-class wire format
    # (repro.core.wire): sign → packed bits + scales, topk → (idx, val)
    # slots, randk → values only (indices key-derived), qsgd → uintN
    # levels + norms, sparse → (row index, row values) pairs of the
    # touched rows only (compose the inner value codec with sparse+sign /
    # sparse+qsgd).  Irrelevant knobs are ignored per operator.
    compressor: str = "sign"        # identity | sign | topk | randk | qsgd
    #                               # | sparse | sparse+sign | sparse+qsgd
    compressor_block: int = LANE    # sign/topk/qsgd/sparse row width
    compressor_fraction: float = 0.01   # topk / randk kept fraction
    compressor_levels: int = 7      # qsgd levels (7 -> 4-bit wire)
    compressor_rows: int = 64       # sparse: shipped-row budget per leaf
    # dtype of the uncompressed gossip payload (PD/MT/QG x wire and MT's
    # uncompressed c wire): "float32" | "bfloat16".  bf16 halves the
    # bytes on every wire the backend ships; the self term and the mixing
    # accumulation stay f32 (`bytes_per_comm_round` charges 2 B/elem).
    wire_dtype: str = "float32"
    # Pallas execution path: run the fused round on the flatten-once
    # (rows, 1024) kernel layout — momentum scan, gossip mix and CPD's
    # packed sign wire in one layout, flattened once per round.  The
    # recommended configuration on TPU (`--use-kernel` in launch.train);
    # off by default here because this container only has the interpret-
    # mode correctness harness.
    use_kernel: bool = False
    # force Pallas interpret mode on/off; None = auto (interpret off-TPU)
    kernel_interpret: Optional[bool] = None
    # Communication-hiding overlapped rounds (`--overlap` in launch.train):
    # the gossip payload of round r is exchanged during round r+1's local
    # scan and mixed one round late (one-round-stale delayed mixing), so
    # the interconnect transfer hides behind compute.  The in-flight
    # payload rides the optimizer state (DelayedMixState) and is
    # checkpointed — resume mid-overlap is bit-identical.  Unsupported
    # combos (CPD-SGDM on the sharded backend / with use_kernel, MT-DSGDm
    # compressed tracking, every-step baselines) raise at construction.
    overlap: bool = False


@dataclasses.dataclass(frozen=True)
class RunCfg:
    model: ModelCfg
    parallel: ParallelCfg = ParallelCfg()
    optim: OptimCfg = OptimCfg()
