import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# The lines above MUST run before jax imports: the sharded HLO checks need
# 8 forced host devices (4 workers × TP2 debug mesh / 8 workers × TP1),
# and jax locks the device count at first init.  Run this module in its
# own process (python -m repro.analysis.run), never import it from tests.

import argparse      # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
from jax.experimental import enable_x64  # noqa: E402

"""Static-analysis driver: the round contract, checked across the grid.

    python -m repro.analysis.run               # fast grid (CI push)
    python -m repro.analysis.run --grid full   # optimizer × codec ×
                                               # schedule sweep (nightly)

Phases (nothing trains; jaxpr tracing + AOT compiles only):

1. dense jaxpr grid      — optimizer × {tree, kernel} on DenseComm:
                           one p-scan, zero collectives, zero callbacks,
                           zero f64 (traced under x64), flatten-once carry
2. sharded jaxpr + HLO   — build_train on the debug mesh per optimizer ×
                           codec: gossip at the boundary only, expected
                           ppermute counts, switch branches ≡ schedule
                           period, donation aliased, collective allowlist,
                           collective-permute bytes ≡ bytes_per_comm_round
3. retrace guard         — full schedule sweep + mid-cycle resume must
                           compile the fused round exactly once

Exit 0 = contract holds, 1 = violations (printed per combo).
"""


def _dense_grid(full: bool):
    from repro.core import make_compressor
    # (optimizer, codec, use_kernel, overlap)
    grid = [
        ("pd_sgdm", None, False, False),
        ("pd_sgdm", None, True, False),
        ("cpd_sgdm", "sign", True, False),
        ("cpd_sgdm", "qsgd", False, False),
        ("cpd_sgdm", "sparse", True, False),
        ("mt_dsgdm", None, False, False),
        ("pd_sgdm", None, False, True),
        ("mt_dsgdm", None, True, True),
    ]
    if full:
        grid += [
            ("cpd_sgdm", "sign", False, False),
            ("cpd_sgdm", "qsgd", True, False),
            ("cpd_sgdm", "topk", False, False),
            ("cpd_sgdm", "randk", False, False),
            ("cpd_sgdm", "identity", False, False),
            ("cpd_sgdm", "sparse+sign", False, False),
            ("qg_dsgdm", None, False, False),
            ("mt_dsgdm", None, True, False),
            ("pd_sgdm", None, True, True),
            ("mt_dsgdm", None, False, True),
            ("qg_dsgdm", None, True, True),
            ("cpd_sgdm", "sign", False, True),
        ]
    return grid


def phase_dense(full: bool) -> list:
    from repro.analysis import jaxpr_check as jc
    from repro.core import make_compressor, make_optimizer
    from repro.core.gossip import DenseComm
    from repro.core.topology import make_schedule, ring

    K = 8
    params = jc.toy_params(K)
    failures = []
    for name, comp, kernel, overlap in _dense_grid(full):
        compressor = make_compressor(comp) if comp else None
        opt = make_optimizer(name, DenseComm(ring(K)), eta=0.05, mu=0.9,
                             p=3, compressor=compressor, use_kernel=kernel,
                             kernel_interpret=True, overlap=overlap)
        kern = kernel and opt.kernel_comm_supported
        label = (f"dense/{name}/{comp or 'none'}/"
                 f"{'kernel' if kern else 'tree'}"
                 + ("/overlap" if overlap else ""))
        v = jc.check_round_contract(opt, params, kernel=kern, overlap=overlap)
        _report(label, v, failures)

    # scheduled dense rounds (stacked-W indexing; still zero collectives)
    for sched_name in (["one_peer_exp"] if not full else
                       ["one_peer_exp", "random_matching"]):
        sched = make_schedule(sched_name, (K,))
        opt = make_optimizer("pd_sgdm", DenseComm(sched), eta=0.05, mu=0.9,
                             p=2)
        v = jc.check_round_contract(opt, params)
        _report(f"dense/pd_sgdm/{sched_name}", v, failures)

    # hierarchical two-level rounds: dense simulation factors the round
    # through node means (W = R ⊗ C) — still one p-scan, zero collectives
    from repro.core.topology import hierarchical, hierarchical_schedule
    hier_grid = [("pd_sgdm", False, False), ("pd_sgdm", True, False)]
    if full:
        hier_grid += [("mt_dsgdm", False, False), ("pd_sgdm", False, True),
                      ("mt_dsgdm", True, True)]
    for name, kernel, overlap in hier_grid:
        opt = make_optimizer(name, DenseComm(hierarchical(2, 4)), eta=0.05,
                             mu=0.9, p=3, use_kernel=kernel,
                             kernel_interpret=True, overlap=overlap)
        kern = kernel and opt.kernel_comm_supported
        v = jc.check_round_contract(opt, params, kernel=kern, overlap=overlap)
        _report(f"dense/{name}/hier-m4/{'kernel' if kern else 'tree'}"
                + ("/overlap" if overlap else ""), v, failures)
    opt = make_optimizer("pd_sgdm", DenseComm(hierarchical_schedule(4, 2)),
                         eta=0.05, mu=0.9, p=2)
    v = jc.check_round_contract(opt, params)
    _report("dense/pd_sgdm/hier_one_peer", v, failures)

    # elastic membership: the masked matrices must honour the liveness
    # contract every round (check_membership_mask runs inside the
    # aggregate when the backend carries a membership schedule)
    from repro.testing import chaos_script, membership_for
    ms = membership_for(K, 6, chaos_script(K, 6, seed=7))
    for name, comp, overlap in (
            [("pd_sgdm", None, False), ("pd_sgdm", None, True)] if not full
            else [("pd_sgdm", None, False), ("cpd_sgdm", "sign", False),
                  ("mt_dsgdm", None, False), ("pd_sgdm", None, True),
                  ("mt_dsgdm", None, True)]):
        compressor = make_compressor(comp) if comp else None
        opt = make_optimizer(name, DenseComm(ring(K), membership=ms),
                             eta=0.05, mu=0.9, p=3, compressor=compressor,
                             overlap=overlap)
        v = jc.check_round_contract(opt, params, overlap=overlap)
        _report(f"dense/{name}/{comp or 'none'}/membership"
                + ("/overlap" if overlap else ""), v, failures)
    # elastic hierarchical rounds are dense-only (masked factored matrix)
    opt = make_optimizer("pd_sgdm", DenseComm(hierarchical(2, 4),
                                              membership=ms),
                         eta=0.05, mu=0.9, p=3)
    v = jc.check_round_contract(opt, params)
    _report("dense/pd_sgdm/hier-m4/membership", v, failures)
    return failures


def _sharded_grid(full: bool):
    # (optimizer, codec, use_kernel, topology_schedule, overlap)
    grid = [
        ("pd_sgdm", "sign", False, "static", False),
        ("pd_sgdm", "sign", True, "static", False),
        ("cpd_sgdm", "sign", False, "static", False),
        ("cpd_sgdm", "sparse", True, "static", False),
        ("pd_sgdm", "sign", False, "one_peer_exp", False),
        ("pd_sgdm", "sign", False, "static", True),
        ("pd_sgdm", "sign", True, "static", True),
    ]
    if full:
        grid += [
            ("cpd_sgdm", "sign", True, "static", False),
            ("cpd_sgdm", "qsgd", False, "static", False),
            ("cpd_sgdm", "topk", False, "static", False),
            ("cpd_sgdm", "randk", False, "static", False),
            ("cpd_sgdm", "sparse+qsgd", False, "static", False),
            ("mt_dsgdm", "sign", False, "static", False),
            ("pd_sgdm", "sign", False, "random_matching", False),
            ("pd_sgdm", "sign", True, "one_peer_exp", False),
            ("mt_dsgdm", "sign", False, "static", True),
            ("mt_dsgdm", "sign", True, "static", True),
            ("qg_dsgdm", "sign", False, "static", True),
            ("pd_sgdm", "sign", False, "one_peer_exp", True),
            ("cpd_sgdm", "sign", False, "static", True),   # must skip
        ]
    return grid


def _build_pack(opt_name, codec, use_kernel, schedule, overlap=False,
                node_size=0, wire_dtype="float32", inter_codec="none"):
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    run = RunCfg(model=mcfg,
                 parallel=ParallelCfg(profile="A", remat="none",
                                      topology_schedule=schedule,
                                      node_size=node_size,
                                      inter_codec=inter_codec),
                 optim=OptimCfg(name=opt_name, p=2, compressor=codec,
                                use_kernel=use_kernel,
                                kernel_interpret=True, overlap=overlap,
                                wire_dtype=wire_dtype))
    mesh = make_debug_mesh(8, 1)   # 8 workers × TP1: per-device ≡ per-worker
    return build_train(run, mesh, InputShape("t", 16, 8, "train"))


def phase_sharded(full: bool) -> list:
    from repro.analysis import hlo_check as hc
    from repro.analysis import jaxpr_check as jc

    failures = []
    for opt_name, codec, use_kernel, schedule, overlap in _sharded_grid(full):
        label = (f"sharded/{opt_name}/{codec}/"
                 f"{'kernel' if use_kernel else 'tree'}/{schedule}"
                 + ("/overlap" if overlap else ""))
        try:
            pack = _build_pack(opt_name, codec, use_kernel, schedule, overlap)
        except ValueError as e:      # unsupported combo (e.g. CPD+schedule)
            print(f"  skip {label}: {e}")
            continue
        args = (pack.params_struct, pack.state_struct,
                pack.round_batch_struct)
        jx = jax.make_jaxpr(pack.train_round)(*args)
        v = []
        v += jc.check_no_host_callbacks(jx)
        v += jc.check_round_scan(jx, pack.opt.config.p)
        expected = None
        if opt_name == "pd_sgdm" and schedule == "static":
            deg = pack.opt.comm.topology.degree
            n_arrays = (1 if (use_kernel and pack.opt.kernel_comm_supported)
                        else len(jax.tree_util.tree_leaves(
                            pack.params_struct)))
            expected = deg * n_arrays
        if overlap:
            # same wire, moved to the round start: the exchange must
            # precede the p-step scan (scan-independent payload), with
            # the ppermute count unchanged from the sync contract
            v += jc.check_overlap_boundary(jx, p=pack.opt.config.p,
                                           expected=expected)
        else:
            v += jc.check_gossip_boundary(jx, expected=expected)
        if schedule != "static":
            v += jc.check_schedule_switch(jx, pack.opt.comm.period)
        with enable_x64():
            jx64 = jax.make_jaxpr(pack.train_round)(*args)
        v += jc.check_no_f64(jx64)
        # schedules vary wire bytes by round; byte equality is round-0 only
        v += hc.check_sharded_round(pack, check_bytes=(schedule == "static"),
                                    label=label)
        _report(label, v, failures)

    # hierarchical two-level rounds: psum inside the node, ppermute between
    # node leaders — per-level accounted ≡ shipped on static graphs
    from repro.core.topology import hierarchical_inter_shifts
    # (optimizer, use_kernel, schedule, overlap, wire_dtype, inter_codec)
    hier_grid = [
        ("pd_sgdm", False, "static", False, "float32", "none"),
        ("pd_sgdm", True, "static", False, "float32", "none"),
        ("pd_sgdm", False, "static", False, "bfloat16", "none"),
    ]
    if full:
        hier_grid += [
            ("mt_dsgdm", False, "static", False, "float32", "none"),
            ("pd_sgdm", True, "static", False, "bfloat16", "none"),
            ("pd_sgdm", False, "hier_one_peer", False, "float32", "none"),
            ("pd_sgdm", False, "static", True, "float32", "none"),
            ("pd_sgdm", True, "static", True, "float32", "none"),
            ("pd_sgdm", False, "static", False, "float32", "identity"),
            ("cpd_sgdm", False, "static", False, "float32", "none"),  # skip
        ]
    for opt_name, use_kernel, schedule, overlap, wdt, icodec in hier_grid:
        label = (f"sharded/{opt_name}/hier-m4/"
                 f"{'kernel' if use_kernel else 'tree'}/{schedule}"
                 + (f"/{wdt}" if wdt != "float32" else "")
                 + (f"/codec-{icodec}" if icodec != "none" else "")
                 + ("/overlap" if overlap else ""))
        try:
            pack = _build_pack(opt_name, "sign", use_kernel, schedule,
                               overlap, node_size=4, wire_dtype=wdt,
                               inter_codec=icodec)
        except ValueError as e:      # unsupported combo (e.g. CPD+hier)
            print(f"  skip {label}: {e}")
            continue
        args = (pack.params_struct, pack.state_struct,
                pack.round_batch_struct)
        jx = jax.make_jaxpr(pack.train_round)(*args)
        v = []
        v += jc.check_no_host_callbacks(jx)
        v += jc.check_round_scan(jx, pack.opt.config.p)
        expected = None
        if opt_name == "pd_sgdm" and schedule == "static":
            ideg = len(hierarchical_inter_shifts(pack.opt.comm.topology))
            n_arrays = (1 if (use_kernel and pack.opt.kernel_comm_supported)
                        else len(jax.tree_util.tree_leaves(
                            pack.params_struct)))
            expected = ideg * n_arrays
        if overlap:
            v += jc.check_overlap_boundary(jx, p=pack.opt.config.p,
                                           expected=expected)
        else:
            v += jc.check_gossip_boundary(jx, expected=expected)
        if schedule != "static":
            v += jc.check_schedule_switch(jx, pack.opt.comm.period)
        with enable_x64():
            jx64 = jax.make_jaxpr(pack.train_round)(*args)
        v += jc.check_no_f64(jx64)
        v += hc.check_sharded_round(pack, check_bytes=(schedule == "static"),
                                    label=label)
        _report(label, v, failures)
    return failures


def phase_retrace() -> list:
    from repro.analysis.retrace import check_schedule_no_retrace

    failures = []
    v = check_schedule_no_retrace()
    _report("retrace/one_peer_exp-sweep+resume", v, failures)
    return failures


def _report(label: str, violations: list, failures: list):
    status = "ok" if not violations else "FAIL"
    print(f"  {status:4s} {label}")
    for msg in violations:
        print(f"       - {msg}")
    if violations:
        failures.append((label, violations))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="round-contract static checks")
    ap.add_argument("--grid", choices=("fast", "full"), default="fast")
    ap.add_argument("--phase", choices=("all", "dense", "sharded", "retrace"),
                    default="all")
    args = ap.parse_args(argv)
    full = args.grid == "full"

    failures = []
    t0 = time.time()
    if args.phase in ("all", "dense"):
        print("[1/3] dense jaxpr contract grid")
        failures += phase_dense(full)
    if args.phase in ("all", "sharded"):
        print("[2/3] sharded jaxpr + HLO contract grid")
        failures += phase_sharded(full)
    if args.phase in ("all", "retrace"):
        print("[3/3] retrace guard")
        failures += phase_retrace()

    dt = time.time() - t0
    if failures:
        print(f"\nstatic-analysis: {len(failures)} combo(s) violated the "
              f"round contract ({dt:.0f}s)", file=sys.stderr)
        return 1
    print(f"\nstatic-analysis: round contract holds ({dt:.0f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
