"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run fig1 fig3   # a subset
"""
import sys
import time

SECTIONS = ["fig1", "fig2", "fig3", "speedup", "round", "kernels",
            "roofline"]


def main() -> None:
    want = [a for a in sys.argv[1:] if a in SECTIONS] or SECTIONS
    print("name,us_per_call,derived")
    t0 = time.time()
    if "fig1" in want:
        from benchmarks import fig1_pdsgdm
        fig1_pdsgdm.main()
    if "fig2" in want:
        from benchmarks import fig2_comm_cost
        fig2_comm_cost.main()
    if "fig3" in want:
        from benchmarks import fig3_cpdsgdm
        fig3_cpdsgdm.main()
    if "speedup" in want:
        from benchmarks import speedup
        speedup.main()
    if "round" in want:
        from benchmarks import round_engine
        round_engine.main()
    if "kernels" in want:
        from benchmarks import kernels_micro
        kernels_micro.main()
    if "roofline" in want:
        from benchmarks import roofline
        roofline.main()
    print(f"total_wall_s,{(time.time()-t0)*1e6:.0f},sections={want}")


if __name__ == '__main__':
    main()
