"""Post-SPMD HLO analysis: collective bytes, roofline terms.

``cost_analysis`` gives per-device FLOPs / bytes-accessed but no collective
traffic, so we parse the compiled (post-partitioning) HLO text and sum the
operand sizes of every collective op, converted to effective bytes-on-wire
per device with the standard ring-algorithm factors.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from repro.launch.mesh import HW

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms",
           "model_flops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]     # per device, per call, summed
    wire_bytes: Dict[str, float]     # effective ring-algorithm bytes/device
    lines: List[str]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


# computation definition header; param lists may contain nested parens
# (tuple-typed while-body params), so only anchor on name + '(' + '... {'
_COMP_DEF_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"%?([\w\.\-]+)")


def _computation_loop_depths(hlo_text: str) -> Dict[str, int]:
    """while-nesting depth of every computation (ENTRY = 0).

    A collective inside a scan body executes once *per trip*; the caller
    supplies the known trip counts per depth (our scans: train-round steps,
    layer repeats) to recover true per-call traffic.
    """
    comp_lines: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_DEF_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comp_lines[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comp_lines[cur].append(line)

    # edges: computation -> (callee, via_while)
    edges: Dict[str, List] = {}
    for name, lines in comp_lines.items():
        edges[name] = []
        for line in lines:
            wm = _WHILE_RE.search(line)
            body = wm.group(1) if wm else None
            for callee in _CALL_RE.findall(line):
                if callee in comp_lines:
                    edges[name].append((callee, callee == body))

    depths = {entry: 0} if entry else {}
    stack = [entry] if entry else []
    while stack:
        c = stack.pop()
        for callee, via_while in edges.get(c, []):
            d = depths[c] + (1 if via_while else 0)
            if callee not in depths or d > depths[callee]:
                depths[callee] = d
                stack.append(callee)
    return depths


def parse_collectives(hlo_text: str, loop_trips=()) -> CollectiveStats:
    """Sum collective traffic; ops at while-depth d are multiplied by
    prod(loop_trips[:d]) (deeper unknown loops contribute ×1)."""
    counts: Dict[str, int] = {}
    rbytes: Dict[str, int] = {}
    wbytes: Dict[str, float] = {}
    lines: List[str] = []
    depths = _computation_loop_depths(hlo_text) if loop_trips else {}

    def multiplier(depth: int) -> int:
        m = 1
        for t in list(loop_trips)[:depth]:
            m *= int(t)
        return m

    cur_comp = None
    for line in hlo_text.splitlines():
        dm = _COMP_DEF_RE.match(line.strip())
        if dm and line.rstrip().endswith("{"):
            cur_comp = dm.group(1)
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # async pairs: count -start only (the -done carries the same tensor)
        if "-done(" in line:
            continue
        size = _type_bytes(m.group("type"))
        n = _group_size(line)
        mult = multiplier(depths.get(cur_comp, 0)) if loop_trips else 1
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op == "all-gather":
            wire = (n - 1) / n * size          # size = gathered result
        elif op == "reduce-scatter":
            wire = (n - 1) * size              # size = scattered result
        elif op == "all-to-all":
            wire = (n - 1) / n * size
        else:                                   # collective-permute
            wire = float(size)
        counts[op] = counts.get(op, 0) + mult
        rbytes[op] = rbytes.get(op, 0) + size * mult
        wbytes[op] = wbytes.get(op, 0.0) + wire * mult
        lines.append(f"x{mult} " + line.strip()[:180])
    return CollectiveStats(counts, rbytes, wbytes, lines)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (per compiled call)."""
    compute = flops_per_device / HW.PEAK_FLOPS_BF16
    memory = bytes_per_device / HW.HBM_BW
    collective = wire_bytes_per_device / HW.ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dom}


def model_flops(n_active_params: float, tokens: float, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active_params * tokens
