"""Synthetic data streams — the non-IID Dirichlet partitioner contract.

The heterogeneity claim (benchmarks/noniid_sweep.py, MT-DSGDm) is only as
good as the data path under it: the partition must be deterministic,
``alpha`` must actually control the per-worker label skew, and the IID
setting must be the exact uniform marginal.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (ClassStreamCfg, class_batch,
                                  worker_class_probs)

K = 8


def _empirical_marginals(cfg, steps=40):
    """(K, n_classes) label frequencies over ``steps`` sampled batches."""
    counts = np.zeros((cfg.n_workers, cfg.n_classes))
    for t in range(steps):
        labels = np.asarray(class_batch(cfg, t)["labels"])
        for k in range(cfg.n_workers):
            counts[k] += np.bincount(labels[k], minlength=cfg.n_classes)
    return counts / counts.sum(axis=1, keepdims=True)


def _skew(probs):
    """Mean total-variation distance of the worker marginals from uniform."""
    u = 1.0 / probs.shape[1]
    return float(0.5 * np.abs(np.asarray(probs) - u).sum(axis=1).mean())


def test_partition_deterministic_across_calls():
    """Same cfg → identical partition and identical batches, call after
    call (the partition keys on the seed alone, batches on (seed, step))."""
    cfg = ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=0.1, seed=3)
    p1 = np.asarray(worker_class_probs(cfg))
    p2 = np.asarray(worker_class_probs(cfg))
    np.testing.assert_array_equal(p1, p2)
    for t in (0, 7):
        a = class_batch(cfg, t)
        b = class_batch(cfg, t)
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))
        np.testing.assert_array_equal(np.asarray(a["images"]),
                                      np.asarray(b["images"]))
    # a different seed is a different partition
    p3 = np.asarray(worker_class_probs(
        ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=0.1, seed=4)))
    assert np.abs(p1 - p3).max() > 1e-3


def test_skew_increases_as_alpha_shrinks():
    """Small α ⇒ strongly non-IID: the per-worker label-marginal distance
    from uniform is ordered α=0.1 > α=1.0 > α=100 ≈ IID, both for the
    partition itself and for the labels actually sampled."""
    skews = {}
    for alpha in (0.1, 1.0, 100.0):
        cfg = ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=alpha)
        skews[alpha] = _skew(worker_class_probs(cfg))
    assert skews[0.1] > 2 * skews[1.0], skews
    assert skews[1.0] > 2 * skews[100.0], skews
    assert skews[0.1] > 0.5          # mass concentrated on few classes

    emp_01 = _skew(_empirical_marginals(
        ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=0.1)))
    emp_1 = _skew(_empirical_marginals(
        ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=1.0)))
    assert emp_01 > emp_1, (emp_01, emp_1)


def test_iid_matches_uniform_marginal():
    """alpha=None is the exact uniform partition, and the sampled labels'
    empirical marginal concentrates around it (sampling noise only)."""
    cfg = ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=None)
    probs = np.asarray(worker_class_probs(cfg))
    np.testing.assert_array_equal(probs, np.float32(1.0 / cfg.n_classes))
    emp = _empirical_marginals(cfg, steps=60)
    # 60 steps × 16 samples = 960 draws/worker: TV from uniform is small
    assert _skew(emp) < 0.06, _skew(emp)
    # and per-class frequencies are individually near 1/C
    np.testing.assert_allclose(emp, 1.0 / cfg.n_classes, atol=0.05)
