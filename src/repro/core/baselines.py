"""Baselines the paper compares against (all built from PD/CPD machinery).

* **C-SGDM** — centralized momentum SGD (the paper's Fig. 1 reference):
  gradients are globally averaged every step, replicas stay bitwise
  identical.  Implemented as gradient-mixing with the complete topology so
  the dense and sharded backends share code with the decentralized methods.
* **D-SGD**  [Lian et al. '17] — D-PSGD: gossip every step, no momentum;
  the momentum-free control the non-IID sweep reports against.
* **PD-SGD** [Li et al. '19]  — periodic gossip, no momentum.
* **CHOCO-SGD** [Koloskova et al. '19] — compressed gossip every step,
  no momentum, no periodicity.  Built on CPD-SGDM's comm round, so it
  ships the real wire-codec payload (``repro.core.wire``) for *every*
  compression operator on both backends — same bytes, same accounting.
"""
from __future__ import annotations

import dataclasses

from repro.core.compression import Compressor
from repro.core.cpdsgdm import CPDSGDM, CPDSGDMConfig
from repro.core.gossip import CommBackend, DenseComm, ShardedComm
from repro.core.pdsgdm import PDSGDM, PDSGDMConfig
from repro.core.topology import complete
from repro.core.tracking import (MTDSGDMConfig, MTDSGDm, QGDSGDMConfig,
                                 QGDSGDm)

__all__ = ["CSGDM", "d_sgd", "pd_sgd", "choco_sgd", "make_optimizer"]


class CSGDM(PDSGDM):
    """Centralized momentum SGD: all-reduce mean of gradients every step.

    Uses the same ``CommBackend`` mixing primitive, but applied to *gradients*
    with the complete topology (W = 11ᵀ/K ⇒ mixing == exact mean).
    """

    def __init__(self, config: PDSGDMConfig, comm: CommBackend):
        cfg = dataclasses.replace(config, p=1)
        super().__init__(cfg, comm)
        if comm.topology.name != "complete":
            raise ValueError("C-SGDM requires the complete topology (mean)")

    def local_step(self, state, params, grads):
        grads = self.comm.mix(grads)       # the centralized all-reduce
        return super().local_step(state, params, grads)

    def comm_round(self, state, params):
        return params, state               # params never drift

    # kernel (flatten-once) round: same structure on the matrix layout —
    # the all-reduce mean of the gradient matrix, and no gossip drift.
    def local_step_mat(self, x_mat, mats, g_mat, step):
        return super().local_step_mat(x_mat, mats, self.comm.mix(g_mat),
                                      step)

    def comm_round_mat(self, x_mat, mats, counts, r, *, plan=None):
        return x_mat, mats


def d_sgd(eta: float, comm: CommBackend, weight_decay: float = 0.0) -> PDSGDM:
    return PDSGDM(PDSGDMConfig(eta=eta, mu=0.0, p=1, weight_decay=weight_decay), comm)


def pd_sgd(eta: float, p: int, comm: CommBackend,
           weight_decay: float = 0.0) -> PDSGDM:
    return PDSGDM(PDSGDMConfig(eta=eta, mu=0.0, p=p, weight_decay=weight_decay), comm)


def choco_sgd(eta: float, gamma: float, comm: CommBackend,
              compressor: Compressor | None = None,
              weight_decay: float = 0.0) -> CPDSGDM:
    cfg = CPDSGDMConfig(eta=eta, mu=0.0, p=1, gamma=gamma,
                        weight_decay=weight_decay)
    return CPDSGDM(cfg, comm, compressor)


def make_optimizer(name: str, comm: CommBackend, *, eta: float = 0.1,
                   mu: float = 0.9, p: int = 4, gamma: float = 0.4,
                   weight_decay: float = 0.0, compressor=None,
                   lr_schedule=None, use_kernel: bool = False,
                   kernel_interpret: bool | None = None,
                   overlap: bool = False):
    """Factory used by configs / launchers / benchmarks."""
    name = name.lower().replace("-", "_")
    if overlap and name in ("c_sgdm", "csgdm", "d_sgd", "dsgd",
                            "choco_sgd", "chocosgd", "choco"):
        raise ValueError(
            f"{name}: overlap=True needs a periodic round to hide the "
            "exchange behind (p > 1 local steps); every-step methods "
            "(C-SGDM / D-SGD / CHOCO-SGD) have no local scan to overlap.")
    if name in ("pd_sgdm", "pdsgdm"):
        return PDSGDM(PDSGDMConfig(eta=eta, mu=mu, p=p,
                                   weight_decay=weight_decay,
                                   lr_schedule=lr_schedule,
                                   use_kernel=use_kernel,
                                   kernel_interpret=kernel_interpret,
                                   overlap=overlap), comm)
    if name in ("mt_dsgdm", "mtdsgdm", "mt"):
        return MTDSGDm(MTDSGDMConfig(eta=eta, mu=mu, p=p,
                                     weight_decay=weight_decay,
                                     lr_schedule=lr_schedule,
                                     use_kernel=use_kernel,
                                     kernel_interpret=kernel_interpret,
                                     overlap=overlap),
                       comm, compressor)
    if name in ("qg_dsgdm", "qgdsgdm", "qg"):
        return QGDSGDm(QGDSGDMConfig(eta=eta, mu=mu, p=p,
                                     weight_decay=weight_decay,
                                     lr_schedule=lr_schedule,
                                     use_kernel=use_kernel,
                                     kernel_interpret=kernel_interpret,
                                     overlap=overlap),
                       comm)
    if name in ("cpd_sgdm", "cpdsgdm"):
        return CPDSGDM(CPDSGDMConfig(eta=eta, mu=mu, p=p, gamma=gamma,
                                     weight_decay=weight_decay,
                                     lr_schedule=lr_schedule,
                                     use_kernel=use_kernel,
                                     kernel_interpret=kernel_interpret,
                                     overlap=overlap),
                       comm, compressor)
    if name in ("c_sgdm", "csgdm"):
        if comm.topology.name == "hierarchical":
            raise ValueError(
                "c_sgdm is the centralized baseline (complete-graph "
                "all-reduce every step); node_size / hierarchical gossip "
                "does not apply.  Drop --node-size for c_sgdm runs.")
        K = comm.topology.n_workers
        comp_comm = type(comm)(complete(K), **(
            {"axis_names": comm.axis_names} if isinstance(comm, ShardedComm) else {}))
        return CSGDM(PDSGDMConfig(eta=eta, mu=mu, p=1,
                                  weight_decay=weight_decay,
                                  lr_schedule=lr_schedule,
                                  use_kernel=use_kernel,
                                  kernel_interpret=kernel_interpret),
                     comp_comm)
    if name in ("d_sgd", "dsgd"):
        return d_sgd(eta, comm, weight_decay)
    if name in ("pd_sgd", "pdsgd"):
        if overlap:
            return PDSGDM(PDSGDMConfig(eta=eta, mu=0.0, p=p,
                                       weight_decay=weight_decay,
                                       overlap=True), comm)
        return pd_sgd(eta, p, comm, weight_decay)
    if name in ("choco_sgd", "chocosgd", "choco"):
        return choco_sgd(eta, gamma, comm, compressor, weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
