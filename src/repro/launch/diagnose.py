import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import re            # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import get_config, long_ctx_variant  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.hlo_analysis import (_COLL_RE, _COMP_DEF_RE, _group_size,
                                       _type_bytes,
                                       _computation_loop_depths)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.runtime import build_serve, build_train  # noqa: E402

"""Collective-traffic diagnosis for the §Perf hypothesis loop.

Prints the top collective ops by (wire bytes × loop multiplicity) with their
op_name metadata, so each GB can be attributed to a specific model site
(attention out-proj psum, MoE dispatch, lm-head gather, gossip permute, ...).

  PYTHONPATH=src python -m repro.launch.diagnose --arch arctic-480b \
      --shape train_4k --top 15
"""

_META_RE = re.compile(r'op_name="([^"]+)"')


def lower_pair(arch: str, shape_name: str, multi_pod=False, overrides=None):
    shape = SHAPES[shape_name]
    run = get_config(arch)
    if overrides:
        run = overrides(run)
    mcfg = run.model if shape_name != "long_500k" else long_ctx_variant(
        run.model)
    from repro.launch.dryrun import compute_loop_trips
    mesh = make_production_mesh(multi_pod=multi_pod)
    trips = compute_loop_trips(mcfg, shape, shape.kind, run.optim.p)
    with mesh:
        if shape.kind == "train":
            pack = build_train(run, mesh, shape, model_cfg=mcfg)
            lowered = pack.train_round.lower(
                pack.params_struct, pack.state_struct,
                pack.round_batch_struct)
        elif shape.kind == "prefill":
            sp = build_serve(run, mesh, shape, model_cfg=mcfg)
            lowered = sp.prefill_step.lower(sp.params_struct, sp.pre_struct)
        else:
            sp = build_serve(run, mesh, shape, model_cfg=mcfg)
            lowered = sp.decode_step.lower(
                sp.params_struct, sp.cache_struct,
                jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    return compiled, trips, run, mcfg


def top_collectives(hlo_text: str, loop_trips, top: int = 15):
    depths = _computation_loop_depths(hlo_text)

    def mult(d):
        m = 1
        for t in list(loop_trips)[:d]:
            m *= int(t)
        return m

    items = []
    cur = None
    for line in hlo_text.splitlines():
        dm = _COMP_DEF_RE.match(line.strip())
        if dm and line.rstrip().endswith("{"):
            cur = dm.group(1)
            continue
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group("op")
        size = _type_bytes(m.group("type"))
        n = _group_size(line)
        k = mult(depths.get(cur, 0))
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op == "all-gather":
            wire = (n - 1) / n * size
        elif op == "reduce-scatter":
            wire = (n - 1) * size
        elif op == "all-to-all":
            wire = (n - 1) / n * size
        else:
            wire = float(size)
        meta = _META_RE.search(line)
        items.append({
            "op": op, "wire_total": wire * k, "mult": k, "group": n,
            "size_mb": size / 2 ** 20,
            "where": (meta.group(1) if meta else "?")[-110:],
        })
    items.sort(key=lambda r: -r["wire_total"])
    return items[:top], sum(i["wire_total"] for i in items)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    compiled, trips, run, mcfg = lower_pair(args.arch, args.shape,
                                            args.multi_pod)
    items, total = top_collectives(compiled.as_text(), trips, args.top)
    print(f"total wire: {total/1e9:.1f} GB/device  (loop trips {trips})")
    for it in items:
        print(f"  {it['wire_total']/1e9:8.2f} GB  {it['op']:<19} "
              f"x{it['mult']:<4} grp={it['group']:<3} "
              f"{it['size_mb']:9.1f} MB/call  {it['where']}")


if __name__ == "__main__":
    main()
