"""Ablation: data heterogeneity (non-IID Dirichlet splits) × communication
period p × optimizer (plain momentum vs momentum tracking).

The paper's Assumption 4 bounds per-worker gradients uniformly; in practice
heterogeneity is where decentralized methods diverge from centralized ones.
Workers draw labels from Dirichlet(α) class distributions — small α =
strongly non-IID — and we sweep p to show the consensus/staleness trade-off.
The ``mt_dsgdm`` rows run Momentum Tracking (Takezawa et al. '22): the
gossiped gradient-tracking correction removes the heterogeneity dependence
plain momentum suffers (see ``benchmarks/noniid_sweep.py`` for the
machine-checkable version judged on the global loss of the averaged model).

  PYTHONPATH=src python examples/noniid_ablation.py

CI runs this as a smoke job with ``ABLATION_STEPS=8`` (trimmed steps —
same code path, just short).
"""
import os

import jax

from repro.core import make_optimizer
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.data.synthetic import ClassStreamCfg, class_batch
from repro.models.resnet import resnet20_init, resnet20_loss
from repro.train.trainer import SimTrainer

import jax.numpy as jnp

K = 8
STEPS = int(os.environ.get("ABLATION_STEPS", "50"))
# CI smoke (tiny step budget): shrink the grid too — each sweep point pays
# a full jit compile, which dwarfs 8 training steps
SMOKE = STEPS <= 8
ALPHAS = [None, 0.1] if SMOKE else [None, 1.0, 0.1]
# per-optimizer step size and period grid: the tracked correction ages p
# steps between mixes and diverges for large p·η (see
# benchmarks/noniid_sweep.py), so MT runs its stable region at η = 0.05
# while PD-SGDM keeps the original η = 0.1 staleness sweep
ETA = {"pd_sgdm": 0.1, "mt_dsgdm": 0.05}
PS_BY_OPT = {"pd_sgdm": [1, 4] if SMOKE else [1, 4, 16],
             "mt_dsgdm": [2] if SMOKE else [1, 2]}


def stacked(width=4):
    p = resnet20_init(jax.random.PRNGKey(0), width=width)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), p)


print(f"{'alpha':>8}{'p':>4}{'optimizer':>11}{'final loss':>12}{'comm MB':>9}")
for alpha in ALPHAS:
    for name in ["pd_sgdm", "mt_dsgdm"]:
        for p in PS_BY_OPT[name]:
            cfg = ClassStreamCfg(batch=16, n_workers=K,
                                 dirichlet_alpha=alpha)
            opt = make_optimizer(name, DenseComm(ring(K)), eta=ETA[name],
                                 mu=0.9, p=p, weight_decay=1e-4)
            # one fused log block per sweep point: the round engine syncs
            # the host once at the end instead of every step
            trainer = SimTrainer(resnet20_loss, opt)
            _, _, h = trainer.train(stacked(), lambda t: class_batch(cfg, t),
                                    STEPS, log_every=max(STEPS - 1, 1))
            label = "IID" if alpha is None else f"{alpha:g}"
            print(f"{label:>8}{p:>4}{name:>11}"
                  f"{h.loss[-1]:>12.4f}{h.comm_mb[-1]:>9.2f}")
print("\nreading: within every alpha row the loss degrades as p grows — "
      "the staleness Theorem 1 prices via p²G²/ρ².  Note the *local* loss "
      "is easier under strong non-IID (a worker seeing few classes has a "
      "simpler problem); judge heterogeneity on the averaged model over "
      "the global distribution (SimTrainer's eval_fn hook — "
      "benchmarks/noniid_sweep.py does exactly that, and there MT-DSGDm's "
      "tracked correction pays off while the comm MB column here shows "
      "its (x, c) wire costing twice PD-SGDM's).  MT's p grid stops at 2: "
      "the correction ages p steps between mixes and diverges for large "
      "p·eta — the same staleness, hitting the tracked direction harder.")
