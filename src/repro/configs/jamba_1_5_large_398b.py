"""jamba-1.5-large-398b — Jamba 1.5 [arXiv:2403.19887].

72L, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536.
Hybrid Mamba+attention at 1:7 interleave (one attention layer per 8-layer
block) with MoE (16 experts top-2) on every other layer.  The SSM mixer is
implemented with the Mamba-2 SSD algorithm (TPU adaptation: chunked matmul
form instead of Jamba's Mamba-1 CUDA selective scan — noted in DESIGN.md);
state 64, headdim 64.
"""
from repro.configs.base import LayerSpec, ModelCfg, OptimCfg, ParallelCfg, RunCfg

# 8-layer block: attention at position 3 (1:7), MoE on odd positions (1:2).
_PATTERN = tuple(
    LayerSpec(mixer=("attn" if i == 3 else "mamba"),
              ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)


def config() -> RunCfg:
    model = ModelCfg(
        name="jamba-1.5-large-398b", arch_type="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536,
        n_experts=16, top_k=2,
        pattern=_PATTERN,
        ssm_state=64, ssm_headdim=64, ssm_expand=2,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2403.19887",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="B"),
                  optim=OptimCfg())
