"""Wire-codec benchmark: bytes/round and round-time per codec on the
fused path.

One CPD-SGDM fused round (p local momentum steps + consensus + compressed
wire) is driven over a many-leaf ragged parameter tree with each wire
codec in turn.  Two numbers per codec:

  * ``bytes_per_round``  — the exact accounted (≡ shipped) payload bytes
    per worker per gossip round, from ``opt.bytes_per_comm_round``; the
    ``x_bf16`` derived field is the reduction vs a bf16 full-precision
    wire of the same tree.
  * ``rounds_per_s``     — wall-clock fused rounds/sec on this host.  The
    kernel-wire codecs (sign/topk/qsgd at block 1024) execute their
    Pallas pack in interpret mode on CPU, so absolute times carry the
    emulation overhead (see benchmarks/kernel_path.py); the bytes column
    is host-independent.

Standalone runs write ``benchmarks/BENCH_wire_codecs.json`` (same row
schema as ``benchmarks/run.py``); under ``python -m benchmarks.run`` the
rows also land in the main ``BENCH_<tag>.json``.

``BENCH_REPEATS`` / ``BENCH_ROUNDS`` trim the timing loops for CI smoke
runs; the ``bytes_per_round`` / ``x_bf16`` columns are measurement-free
(payload arithmetic) and stay exact, which is what
``tools/bench_compare.py`` gates on.
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import (CPDSGDM, CPDSGDMConfig, IdentityCompressor,
                        QSGDCompressor, RandKCompressor, SignCompressor,
                        TopKCompressor)
from repro.core.gossip import DenseComm
from repro.core.topology import ring

K = 4
P = 4
REPEATS = int(os.environ.get("BENCH_REPEATS", "3"))
ROUNDS = int(os.environ.get("BENCH_ROUNDS", "8"))

CODECS = [
    ("identity", IdentityCompressor()),
    ("sign", SignCompressor()),
    ("topk", TopKCompressor(fraction=0.01)),
    ("randk", RandKCompressor(fraction=0.01)),
    ("qsgd", QSGDCompressor(levels=7)),
]


def _params():
    """Many-leaf tree with ragged sizes (tail-padded blocks exercised)."""
    key = jax.random.PRNGKey(0)
    leaves = {}
    for i, shape in enumerate(
            [(257, 129), (64, 300), (1000,), (33, 65), (7, 11, 13),
             (2048,), (129,), (301, 5)] * 2):
        leaves[f"w{i}"] = jax.random.normal(
            jax.random.fold_in(key, i), (K,) + shape) * 0.1
    return leaves


def _grads_fn(params, batch):
    grads = jax.tree_util.tree_map(lambda x: 0.3 * x + batch, params)
    return jnp.zeros(()), grads


def _time_rounds(round_fn, params, state, batches):
    def run():
        p_, s_ = params, state
        for _ in range(ROUNDS):
            p_, s_, _losses = round_fn(s_, p_, batches)
        jax.block_until_ready(p_)
    run()
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return ROUNDS / best


def main():
    results = {}
    params = _params()
    per_worker = jax.tree_util.tree_map(lambda x: x[0], params)
    n_elems = sum(l.size for l in jax.tree_util.tree_leaves(per_worker))
    deg = ring(K).degree
    bf16_baseline = deg * n_elems * 2
    batches = jnp.zeros((P, 1))
    for name, comp in CODECS:
        opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=P, gamma=0.4,
                                    weight_decay=1e-4),
                      DenseComm(ring(K)), comp)
        round_fn = jax.jit(
            lambda s, pp, bs, o=opt: o.round(s, pp, _grads_fn, bs))
        rps = _time_rounds(round_fn, params, opt.init(params), batches)
        bpr = opt.bytes_per_comm_round(per_worker)
        results[name] = (bpr, rps)
        csv_row(f"wire_codecs/{name}", 1e6 / rps,
                f"bytes_per_round={bpr};x_bf16={bf16_baseline / bpr:.2f};"
                f"rounds_per_s={rps:.2f}")
    return results


def _write_json(results) -> str:
    from benchmarks.common import collected_rows
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_wire_codecs.json")
    rows = [r for r in collected_rows() if r["name"].startswith("wire_codecs/")]
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": ["wire_codecs"],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = main()
    print(f"bench_json,0.0,path={os.path.relpath(_write_json(res))}")
