"""Blockwise top-k selection kernels: per-row magnitude top-k on the
flatten-once (rows, 1024) layout — the top-k wire format's hot spot.

Two kernels:

  * ``topk_select_kernel``  — x (rows, 1024) f32 → idx (rows, W) int32 +
                              vals (rows, W) f32, W = ceil(fraction·1024).
  * ``topk_scatter_kernel`` — inverse: Q(x)[i] = val_j where idx_j == i.

One *row* is one top-k block (matching ``compression.TopKCompressor``'s
per-leaf blocks via the ``KernelPlan`` row alignment).  Selection is W
unrolled rounds of (row-max |x|, lowest-index argmin tie-break, mask-out):
pure VPU reductions over one vreg-resident row block, no sort and no
gather — on TPU the "argmax" is the broadcasted-iota min-reduce idiom, so
nothing leaves registers between rounds.  This matches ``lax.top_k``'s
descending-|x|, stable-by-index order bit-exactly (the jnp oracle is
``repro.core.wire.topk_rows``).

Padding contract: slot ``j`` of a row is active iff
``j < ceil(fraction · counts[row])`` — the kept count follows the row's
true length (``counts`` from ``KernelPlan.row_counts``), so tail blocks
keep the same fraction as full blocks and pure-padding rows emit only
``(idx 0, val 0.0)`` placeholders, which the scatter (an *add*) turns into
exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import LANE, default_interpret

__all__ = ["topk_select_pallas", "topk_scatter_pallas", "LANE",
           "BLOCK_ROWS", "MAX_WIDTH"]

BLOCK_ROWS = 128
MAX_WIDTH = 128      # the select kernel unrolls W rounds; cap the unroll


def _select_kernel(x_ref, cnt_ref, idx_ref, val_ref, *, width, fraction):
    x = x_ref[...]                                    # (BR, 1024) f32
    cnt = cnt_ref[...]                                # (BR, 1) f32
    br = x.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (br, LANE), 1)
    k_active = jnp.ceil(jnp.float32(fraction) * cnt).astype(jnp.int32)
    a = jnp.abs(x)
    for j in range(width):
        m = jnp.max(a, axis=1, keepdims=True)
        sel = jnp.min(jnp.where(a == m, lanes, LANE), axis=1, keepdims=True)
        hit = lanes == sel
        val = jnp.sum(jnp.where(hit, x, jnp.float32(0.0)), axis=1,
                      keepdims=True)
        active = jnp.int32(j) < k_active
        idx_ref[:, j:j + 1] = jnp.where(active, sel, 0)
        val_ref[:, j:j + 1] = jnp.where(active, val, jnp.float32(0.0))
        a = jnp.where(hit, jnp.float32(-1.0), a)  # |x| ≥ 0: never re-selected


def _scatter_kernel(idx_ref, val_ref, out_ref, *, width):
    idx = idx_ref[...]                                # (BR, W) int32
    vals = val_ref[...]                               # (BR, W) f32
    br = idx.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (br, LANE), 1)
    acc = jnp.zeros((br, LANE), jnp.float32)
    for j in range(width):
        acc = acc + jnp.where(lanes == idx[:, j:j + 1],
                              vals[:, j:j + 1], jnp.float32(0.0))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("fraction", "width",
                                             "interpret"))
def topk_select_pallas(x, counts=None, *, fraction: float,
                       width: int | None = None,
                       interpret: bool | None = None):
    """x: (rows, 1024) f32 → (idx (rows, W) i32, vals (rows, W) f32)."""
    if interpret is None:
        interpret = default_interpret()
    rows, lane = x.shape
    assert lane == LANE and rows % BLOCK_ROWS == 0, (rows, lane)
    if width is None:
        width = max(1, int(np.ceil(fraction * LANE)))
    assert width <= MAX_WIDTH, (
        f"top-k width {width} > {MAX_WIDTH}: the select kernel unrolls W "
        "selection rounds — use the jnp rows path for coarse fractions")
    if counts is None:
        counts = jnp.full((rows, 1), float(LANE), jnp.float32)
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_select_kernel, width=width,
                               fraction=float(fraction))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, width), jnp.int32),
                   jax.ShapeDtypeStruct((rows, width), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), counts.reshape(rows, 1).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_scatter_pallas(idx, vals, *, interpret: bool | None = None):
    """(rows, W) i32 + (rows, W) f32 → Q(x) (rows, 1024) f32."""
    if interpret is None:
        interpret = default_interpret()
    rows, width = idx.shape
    assert vals.shape == (rows, width) and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_scatter_kernel, width=width)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, width), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)],
        interpret=interpret,
    )(idx, vals.astype(jnp.float32))[0]
