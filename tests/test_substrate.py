"""Substrate layers: data pipeline, checkpointing, serving, gossip backends."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gossip import DenseComm
from repro.core.topology import ring, torus


# --------------------------------------------------------------------- gossip
def test_dense_mix_equals_matmul():
    top = ring(8)
    comm = DenseComm(top)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 5, 3))
    got = comm.mix({"w": x})["w"]
    want = jnp.einsum("kj,jab->kab", jnp.asarray(top.W, jnp.float32), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_dense_shift_views_roll():
    comm = DenseComm(ring(4))
    x = jnp.arange(4.0)[:, None]
    views = comm.shift_views({"w": x})
    np.testing.assert_allclose(np.asarray(views[(0, 1)]["w"][:, 0]),
                               [1, 2, 3, 0])
    np.testing.assert_allclose(np.asarray(views[(0, -1)]["w"][:, 0]),
                               [3, 0, 1, 2])


def test_torus_mix_factorizes():
    top = torus((2, 4))
    comm = DenseComm(top)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3))
    got = comm.mix({"w": x})["w"]
    want = jnp.einsum("kj,ja->ka", jnp.asarray(top.W, jnp.float32), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ----------------------------------------------------------------------- data
def test_lm_batch_deterministic_and_aligned():
    from repro.data.synthetic import LMStreamCfg, lm_batch
    cfg = LMStreamCfg(vocab=128, seq_len=16, batch=2, n_workers=4)
    b1 = lm_batch(cfg, 3)
    b2 = lm_batch(cfg, 3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 2, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][..., 1:]),
                                  np.asarray(b1["labels"][..., :-1]))
    b3 = lm_batch(cfg, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_class_batch_noniid():
    from repro.data.synthetic import ClassStreamCfg, class_batch
    iid = class_batch(ClassStreamCfg(batch=64, n_workers=4), 0)
    non = class_batch(ClassStreamCfg(batch=64, n_workers=4,
                                     dirichlet_alpha=0.1), 0)
    assert iid["images"].shape == (4, 64, 32, 32, 3)
    # non-IID: per-worker label histograms diverge more than IID
    def spread(b):
        h = np.stack([np.bincount(np.asarray(b["labels"][k]), minlength=10)
                      for k in range(4)])
        return np.abs(h / 64.0 - 0.1).mean()
    assert spread(non) > spread(iid)


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    state = {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
             "step": jnp.int32(7)}
    ckpt.save(str(tmp_path), 7, params=params, opt_state=state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7,
                       {"params": params, "opt_state": state})
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                  np.asarray(params["a"]))
    assert int(out["opt_state"]["step"]) == 7
    # shape mismatch is rejected
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 7, {"params": bad})


# -------------------------------------------------------------------- serving
def test_generate_greedy_deterministic():
    from repro.configs.base import ModelCfg
    from repro.models import make_model
    from repro.serve.serving import generate
    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=64)
    model = make_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    o1 = generate(model, params, prompts, 6)
    o2 = generate(model, params, prompts, 6)
    assert o1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(o1[:, :8]), np.asarray(prompts))


# ------------------------------------------------------------------ schedules
def test_warmup_cosine():
    from repro.core.schedules import warmup_cosine
    f = warmup_cosine(10, 100, min_factor=0.1)
    assert float(f(jnp.int32(0))) == pytest.approx(0.0)
    assert float(f(jnp.int32(10))) == pytest.approx(1.0)
    assert float(f(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


# -------------------------------------------------------------- hlo analysis
def test_collective_parse_units():
    from repro.launch.hlo_analysis import parse_collectives
    txt = """
ENTRY %main (a: f32[16]) -> f32[16] {
  %ar = f32[1024,8]{1,0} all-reduce(%x), replica_groups=[8,8]<=[64]
  %cp = bf16[512]{0} collective-permute(%y), channel_id=3
  %ag = f32[64,32]{1,0} all-gather(%z), replica_groups=[4,16]<=[64]
}
"""
    st = parse_collectives(txt)
    assert st.counts == {"all-reduce": 1, "collective-permute": 1,
                         "all-gather": 1}
    assert st.result_bytes["all-reduce"] == 1024 * 8 * 4
    assert st.result_bytes["collective-permute"] == 512 * 2
    # all-reduce wire = 2(n-1)/n * size, n=8
    assert st.wire_bytes["all-reduce"] == pytest.approx(
        2 * 7 / 8 * 1024 * 8 * 4)


def test_collective_parse_loop_multiplicity():
    from repro.launch.hlo_analysis import parse_collectives
    txt = """
%body (p: f32[8]) -> f32[8] {
  %ar = f32[256]{0} all-reduce(%x), replica_groups=[8,8]<=[64]
}
%cond (p: f32[8]) -> pred[] {
  %lt = pred[] compare(%i, %n)
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %w = f32[8] while(%a), condition=%cond, body=%body
  %cp = f32[128]{0} collective-permute(%y)
}
"""
    st = parse_collectives(txt, loop_trips=(4,))
    assert st.counts["all-reduce"] == 4          # ×4 inside the loop
    assert st.counts["collective-permute"] == 1  # top level
