"""Pallas kernels vs pure-jnp oracles: shape/dtype/hyper-param sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gossip_mix import BLOCK_ROWS as GBR
from repro.kernels.gossip_mix import gossip_mix
from repro.kernels.momentum import BLOCK_ROWS as MBR
from repro.kernels.momentum import momentum_update
from repro.kernels.sign_compress import BLOCK_ROWS as SBR
from repro.kernels.sign_compress import sign_pack_pallas, sign_unpack_pallas


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("rows", [MBR, 2 * MBR, 4 * MBR])
@pytest.mark.parametrize("mu,wd,nesterov", [
    (0.0, 0.0, False), (0.9, 0.0, False), (0.9, 1e-4, False),
    (0.99, 1e-2, False), (0.9, 1e-4, True),
])
def test_momentum_kernel_sweep(rows, mu, wd, nesterov):
    k = jax.random.PRNGKey(rows + int(mu * 100))
    x = _rand(k, (rows, 1024))
    m = _rand(jax.random.fold_in(k, 1), (rows, 1024))
    g = _rand(jax.random.fold_in(k, 2), (rows, 1024))
    lr = 0.05
    xn, mn = momentum_update(x, m, g, lr, mu=mu, wd=wd, nesterov=nesterov)
    xr, mr = ref.momentum_update_ref(x, m, g, lr, mu=mu, wd=wd,
                                     nesterov=nesterov)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(mr), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows", [SBR, 3 * SBR])
def test_sign_pack_kernel_sweep(rows, dtype):
    x = _rand(jax.random.PRNGKey(rows), (rows, 1024), dtype)
    pk, sl = sign_pack_pallas(x.astype(jnp.float32))
    pr, sr = ref.sign_pack_ref(x.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(sl[:, 0]), np.asarray(sr),
                               rtol=1e-6)
    un = sign_unpack_pallas(pk, sl[:, 0])
    ur = np.asarray(ref.sign_unpack_ref(pr, sr)).reshape(rows, 1024)
    np.testing.assert_allclose(np.asarray(un), ur, rtol=1e-6)


def test_sign_kernel_matches_core_compressor():
    """Kernel semantics == repro.core.compression.SignCompressor exactly."""
    from repro.core.compression import SignCompressor
    rows = SBR
    x = _rand(jax.random.PRNGKey(0), (rows, 1024))
    pk, sl = ops.sign_pack(x)
    q = ops.sign_unpack(pk, sl[:, 0]).reshape(-1)
    q_ref = SignCompressor(block=1024).apply(x.reshape(-1))
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref), rtol=1e-6)


@pytest.mark.parametrize("n_nbrs", [1, 2, 4])
def test_gossip_mix_kernel(n_nbrs):
    k = jax.random.PRNGKey(n_nbrs)
    tensors = tuple(_rand(jax.random.fold_in(k, i), (GBR, 1024))
                    for i in range(n_nbrs + 1))
    w = tuple(1.0 / (n_nbrs + 1) for _ in range(n_nbrs + 1))
    out = gossip_mix(tensors, weights=w)
    want = ref.gossip_mix_ref(tensors, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_momentum_tree_wrapper_ragged_shapes():
    """Wrapper must round-trip padding across odd-shaped pytrees."""
    key = jax.random.PRNGKey(7)
    params = {
        "a": _rand(key, (13, 17)),
        "b": {"c": _rand(jax.random.fold_in(key, 1), (3,)),
              "d": _rand(jax.random.fold_in(key, 2), (2, 5, 7))},
    }
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    g = jax.tree_util.tree_map(lambda x: 0.3 * x, params)
    xn, mn = ops.momentum_update_tree(params, m, g, mu=0.9, lr=0.1,
                                      weight_decay=1e-3)
    def want(x, mm, gg):
        return ref.momentum_update_ref(x, mm, gg, 0.1, mu=0.9, wd=1e-3)[0]
    for ka, a in jax.tree_util.tree_leaves_with_path(params):
        pass
    wref = jax.tree_util.tree_map(want, params, m, g)
    for a, b in zip(jax.tree_util.tree_leaves(xn),
                    jax.tree_util.tree_leaves(wref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        assert a.shape == b.shape


def test_pdsgdm_use_kernel_matches_jnp_path():
    """PD-SGDM with use_kernel=True is numerically identical to the jnp path."""
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    K = 4
    params = {"w": _rand(jax.random.PRNGKey(0), (K, 33, 65))}
    grads = {"w": _rand(jax.random.PRNGKey(1), (K, 33, 65))}
    outs = []
    for use_kernel in (False, True):
        opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=4, weight_decay=1e-4,
                                  use_kernel=use_kernel), DenseComm(ring(K)))
        st = opt.init(params)
        p1, s1 = opt.local_step(st, params, grads)
        p2, _ = opt.local_step(s1, p1, grads)
        outs.append(p2["w"])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5)
