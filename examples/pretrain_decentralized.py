"""End-to-end driver: decentralized LM pretraining on the sharded runtime.

Trains an OLMo-family model with PD-SGDM over a (data × model) mesh —
gossip lowers to collective-permute, exactly the production path the
dry-run compiles for 256/512 chips, here on forced CPU host devices.
Execution runs through ``TrainPack.train_round`` (fused p-step rounds,
donated buffers); checkpoints carry the full optimizer state so
``--resume`` continues bit-identically.

``--node-size m`` switches the flat gossip graph to the two-level
hierarchical round (exact intra-node average + ``--topology`` between
node leaders), ``--wire-dtype bfloat16`` halves the inter wire, and
``--inter-codec`` compresses it; ``--json-out`` writes the run record
(loss curve endpoints, tokens/sec, comm-MB) that
``benchmarks/pretrain_sweep.py`` consumes — the sweep and this example
share this one driver path.

Default is a ~100M-param model for a few hundred steps (the deliverable's
end-to-end scale); ``--quick`` shrinks it for a smoke pass.

  PYTHONPATH=src python examples/pretrain_decentralized.py --quick
  PYTHONPATH=src python examples/pretrain_decentralized.py \
      --steps 300 --devices 8      # ~100M params, the full driver
  PYTHONPATH=src python examples/pretrain_decentralized.py \
      --quick --node-size 2 --wire-dtype bfloat16   # two-level gossip
"""
import argparse
import json
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--quick", action="store_true")
ap.add_argument("--optimizer", default="pd_sgdm")
ap.add_argument("--p", type=int, default=4)
ap.add_argument("--topology", default="ring",
                help="gossip graph between workers (flat), or between "
                     "node leaders when --node-size is set")
ap.add_argument("--node-size", type=int, default=0,
                help="two-level gossip: exact intra-node averaging over "
                     "groups of this many workers (0 = flat)")
ap.add_argument("--wire-dtype", default="float32",
                choices=("float32", "bfloat16"),
                help="dtype of the gossip payload on the wire")
ap.add_argument("--inter-codec", default="none",
                help="compress the hierarchical inter-node wire "
                     "(identity/sign/topk/qsgd; needs --node-size)")
ap.add_argument("--json-out", default=None,
                help="write the run record (losses, tokens/sec, comm-MB) "
                     "to this JSON file")
ap.add_argument("--ckpt-dir", default=None)
ap.add_argument("--resume", action="store_true",
                help="continue from the latest checkpoint in --ckpt-dir")
args = ap.parse_args()
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices}")

import jax                                             # noqa: E402

from repro.configs.base import (ModelCfg, OptimCfg, ParallelCfg,
                                RunCfg)                # noqa: E402
from repro.configs.shapes import InputShape            # noqa: E402
from repro.data.synthetic import LMStreamCfg, lm_batch  # noqa: E402
from repro.launch.mesh import make_mesh                # noqa: E402
from repro.launch.runtime import build_train           # noqa: E402
from repro.train.trainer import ShardedTrainer         # noqa: E402

if args.quick:
    mcfg = ModelCfg(name="lm-5m", arch_type="dense", n_layers=4,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                    vocab=4096)
    seq, gbatch, steps = 64, 16, min(args.steps, 30)
else:
    # ~100M params: 12L × d768 (GPT-2-small-ish), 32k vocab
    mcfg = ModelCfg(name="lm-100m", arch_type="dense", n_layers=12,
                    d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                    vocab=32768)
    seq, gbatch, steps = 256, 16, args.steps

run = RunCfg(model=mcfg,
             parallel=ParallelCfg(profile="A", remat="none",
                                  topology=args.topology,
                                  node_size=args.node_size,
                                  inter_codec=args.inter_codec),
             optim=OptimCfg(name=args.optimizer, eta=0.25, mu=0.9,
                            p=args.p, weight_decay=1e-4,
                            wire_dtype=args.wire_dtype))

mesh = make_mesh((args.devices // 2, 2), ("data", "model"))
shape = InputShape("pretrain", seq, gbatch, "train")
pack = build_train(run, mesh, shape)
K = pack.layout.n_workers
n_params = mcfg.params_count()
print(f"model={mcfg.name} params={n_params/1e6:.1f}M workers={K} "
      f"optimizer={run.optim.name} p={run.optim.p} seq={seq} "
      f"global_batch={gbatch} topology={args.topology} "
      f"node_size={args.node_size} wire_dtype={args.wire_dtype}")

data = LMStreamCfg(vocab=mcfg.vocab, seq_len=seq, batch=gbatch // K,
                   n_workers=K)
trainer = ShardedTrainer(pack, ckpt_dir=args.ckpt_dir,
                         ckpt_every=100 if args.ckpt_dir else 0)
wall0 = time.time()
with mesh:
    out = trainer.train(jax.random.PRNGKey(0),
                        lambda t: lm_batch(data, t), steps,
                        log_every=max(steps // 20, 1),
                        resume=args.resume)
elapsed = time.time() - wall0
h = out["history"]
if not h.loss:          # --resume with a checkpoint at/past --steps
    print("no steps run")
    raise SystemExit(0)
ran = out["steps_run"]
tokens_per_s = ran * gbatch * seq / max(elapsed, 1e-9)
comm_mb = h.comm_mb[-1] if h.comm_mb else 0.0
print(f"loss: {h.loss[0]:.4f} -> {h.loss[-1]:.4f} over {ran} steps "
      f"({tokens_per_s:.0f} tok/s, {comm_mb:.1f} comm-MB/worker)")

if args.json_out:
    record = {
        "model": mcfg.name, "params": n_params, "workers": K,
        "optimizer": run.optim.name, "p": run.optim.p,
        "topology": args.topology, "node_size": args.node_size,
        "wire_dtype": args.wire_dtype, "inter_codec": args.inter_codec,
        "steps": ran, "seq": seq, "global_batch": gbatch,
        "first_loss": h.loss[0], "final_loss": h.loss[-1],
        "tokens_per_s": tokens_per_s, "comm_mb": comm_mb,
        "bytes_per_comm_round": trainer.bytes_per_round(),
        "wall_s": elapsed,
    }
    with open(args.json_out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.json_out}")

if ran == steps:        # a short resumed tail is too noisy to judge
    assert h.loss[-1] < h.loss[0], "training failed to reduce loss"
