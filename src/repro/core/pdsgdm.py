"""PD-SGDM — Periodic Decentralized Momentum SGD (paper Algorithm 1).

Per worker k, per iteration t::

    m⁽ᵏ⁾ₜ   = μ m⁽ᵏ⁾ₜ₋₁ + ∇F(x⁽ᵏ⁾ₜ; ξ⁽ᵏ⁾ₜ)
    x⁽ᵏ⁾ₜ₊½ = x⁽ᵏ⁾ₜ − η m⁽ᵏ⁾ₜ
    x⁽ᵏ⁾ₜ₊₁ = Σⱼ w_kj x⁽ʲ⁾ₜ₊½      if mod(t+1, p) == 0   (gossip)
            = x⁽ᵏ⁾ₜ₊½              otherwise

The optimizer is backend-agnostic: with :class:`~repro.core.gossip.DenseComm`
leaves carry a leading worker dim (simulation / paper-faithful experiments);
with :class:`~repro.core.gossip.ShardedComm` it runs inside ``shard_map`` on
per-worker shards and gossip lowers to ``collective-permute``.

Weight decay follows the paper's experimental setup (PyTorch SGD semantics:
decay folded into the gradient before the momentum update).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.gossip import CommBackend, DenseComm, HierarchicalComm

__all__ = ["PDSGDMConfig", "PDSGDM"]


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


@dataclasses.dataclass(frozen=True)
class PDSGDMConfig:
    eta: float = 0.1                 # step size η (peak LR if schedule given)
    mu: float = 0.9                  # momentum coefficient μ ∈ (0, 1)
    p: int = 4                       # communication period (p > 1 in paper)
    weight_decay: float = 0.0
    nesterov: bool = False           # beyond-paper option (off by default)
    lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    # Pallas execution path: the fused round runs on the flatten-once
    # (rows, 1024) kernel layout (momentum scan + gossip mix + CPD's sign
    # wire all on one matrix) — the recommended production configuration.
    use_kernel: bool = False
    # None → repro.kernels.default_interpret() (interpret off-TPU); tests
    # and benchmarks may force it either way.
    kernel_interpret: Optional[bool] = None
    # Communication-hiding overlapped rounds: the gossip payload of round r
    # is snapshotted at the end of round r's local scan, its exchange is
    # issued at the *start* of round r+1 (the collective has no data
    # dependence on round r+1's compute, so the interconnect transfer hides
    # behind the local scan), and the mixing correction lands one round
    # late — x ← x + (W·x̃ − x̃) applied to the drifted params at the end
    # of round r+1.  The in-flight snapshot + staleness phase ride the
    # optimizer state as ``DelayedMixState`` (state["mix"]), so checkpoint
    # resume mid-overlap is bit-identical.  Bytes per round are unchanged:
    # still exactly one payload exchange per round.
    overlap: bool = False

    def lr(self, step):
        if self.lr_schedule is None:
            return jnp.asarray(self.eta, jnp.float32)
        return self.eta * self.lr_schedule(step)


class PDSGDM:
    """Algorithm 1.

    ``step = local_step ∘ maybe_communicate`` is the per-iteration form;
    ``round`` is the fused form (p local steps + one unconditional gossip in
    a single ``lax.scan``) that the trainers execute.
    """

    def __init__(self, config: PDSGDMConfig, comm: CommBackend):
        if not (0.0 <= config.mu < 1.0):
            raise ValueError("momentum μ must be in [0, 1)")
        if config.p < 1:
            raise ValueError("communication period p must be ≥ 1")
        self.config = config
        self.comm = comm

    # -- state ---------------------------------------------------------------
    def init(self, params):
        state = {
            "m": _tree_map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.config.overlap:
            state["mix"] = self._delayed_mix_init(params)
        return state

    # -- DelayedMixState (overlap=True) ---------------------------------------
    # The in-flight gossip payload: ``buf`` is the f32 snapshot taken at the
    # end of the previous round's local scan (what the neighbours are
    # receiving *now*), ``phase`` is the staleness phase — 0 before any
    # payload has been cut (round 0 executes the exchange but gates the
    # correction to an exact no-op), 1 once a payload is in flight.
    def _delayed_mix_init(self, params):
        return {
            "buf": _tree_map(lambda x: x.astype(jnp.float32), params),
            "phase": jnp.zeros((), jnp.int32),
        }

    # delta-tree keys produced by overlap_begin (MT adds the tracking
    # correction "dc"); the runtime builds shard_map specs from these
    overlap_delta_keys: tuple = ("dx",)
    # whether overlap_step_refresh does anything (MT drips the stale
    # tracking correction into every local step; everyone else skips the
    # per-step hook entirely)
    overlap_refreshes: bool = False

    # -- local computation (Alg. 1 lines 2-4) ---------------------------------
    def local_step(self, state, params, grads):
        cfg = self.config
        lr = cfg.lr(state["step"]).astype(jnp.float32)
        mu = jnp.float32(cfg.mu)
        wd = jnp.float32(cfg.weight_decay)

        if cfg.use_kernel:
            from repro.kernels import ops as kops
            new_params, new_m = kops.momentum_update_tree(
                params, state["m"], grads, mu=cfg.mu, lr=lr,
                weight_decay=cfg.weight_decay, nesterov=cfg.nesterov,
                interpret=cfg.kernel_interpret)
        else:
            def upd(x, m, g):
                g32 = g.astype(jnp.float32) + wd * x.astype(jnp.float32)
                m_new = mu * m + g32
                d = (g32 + mu * m_new) if cfg.nesterov else m_new
                x_new = x.astype(jnp.float32) - lr * d
                return x_new.astype(x.dtype), m_new

            xs, treedef = jax.tree_util.tree_flatten(params)
            ms = treedef.flatten_up_to(state["m"])
            gs = treedef.flatten_up_to(grads)
            pairs = [upd(x, m, g) for x, m, g in zip(xs, ms, gs)]
            new_params = treedef.unflatten([x for x, _ in pairs])
            new_m = treedef.unflatten([m for _, m in pairs])

        new_state = dict(state)   # preserve subclass state (e.g. CPD's x̂)
        new_state["m"] = new_m
        new_state["step"] = state["step"] + 1
        return new_params, new_state

    # -- communication (Alg. 1 lines 5-9) --------------------------------------
    def round_index(self, state):
        """0-based index of the gossip round being applied.

        ``comm_round`` runs after the local step(s) advanced the counter to
        ``t+1 = (r+1)·p``, so ``r = step // p − 1``.  Time-varying topology
        schedules key on this — and because it is derived from the
        checkpointed step counter, resume restores the schedule phase
        bit-identically with no extra persisted cursor.
        """
        return state["step"] // self.config.p - 1

    def comm_round(self, state, params):
        """One gossip round (unconditional), with round ``r``'s topology."""
        return self.comm.mix(params, r=self.round_index(state)), state

    def is_comm_step(self, state):
        """mod(t+1, p) == 0, evaluated *after* the local step incremented t."""
        return (state["step"] % self.config.p) == 0

    def maybe_communicate(self, state, params):
        do = self.is_comm_step(state)
        params, state = jax.lax.cond(
            do,
            lambda s, p: self.comm_round(s, p),
            lambda s, p: (p, s),
            state, params)
        return params, state

    # -- overlapped rounds: one-round-stale delayed mixing ----------------------
    def overlap_begin(self, state):
        """Issue the in-flight payload's exchange and form the delayed-mix
        correction — the only collectives in an overlapped round, with no
        data dependence on the round's local scan (communication hiding).

        Evaluated at round start, ``round_index(state)`` *is* the payload's
        round r (step = (r+1)·p), so time-varying topologies key on the
        payload round while the membership mask keys on the delivery round
        r+1 inside ``stale_mix``.  ``phase == 0`` (nothing in flight yet)
        gates the correction to exact zero; the exchange still runs so one
        trace and one byte pattern serve every round.
        """
        mix = state["mix"]
        r = self.round_index(state)
        gate = (mix["phase"] > 0).astype(jnp.float32)
        mixed = self.comm.stale_mix(mix["buf"], r=r)
        dx = _tree_map(lambda mb, b: (mb - b) * gate, mixed, mix["buf"])
        return {"dx": dx}

    def overlap_step_refresh(self, state, delta):
        """Per-local-step refresh from the in-flight payload (no-op here;
        MT-DSGDm drips its stale tracking correction through this hook)."""
        return state

    def overlap_apply(self, state, params, delta):
        """Land the one-round-stale correction on the drifted params at the
        round's end, then cut the next payload (snapshot + phase=1)."""
        params_new = _tree_map(
            lambda x, d: (x.astype(jnp.float32) + d).astype(x.dtype),
            params, delta["dx"])
        new_state = dict(state)
        new_state["mix"] = self._snapshot_mix(new_state, params_new)
        return params_new, new_state

    def _snapshot_mix(self, state, params):
        return {
            "buf": _tree_map(lambda x: x.astype(jnp.float32), params),
            "phase": jnp.ones((), jnp.int32),
        }

    # -- full iteration ---------------------------------------------------------
    def step(self, state, params, grads):
        if self.config.overlap:
            # Per-step form of the overlapped round (debugging / off-round
            # resume).  The correction depends only on the in-flight buf,
            # so recomputing it each step is value-identical to the fused
            # round's single round-start computation — the per-step path
            # continues a mid-overlap checkpoint bit-identically.
            delta = self.overlap_begin(state)
            params, state = self.local_step(state, params, grads)
            state = self.overlap_step_refresh(state, delta)
            params, state = jax.lax.cond(
                self.is_comm_step(state),
                lambda s, p: self.overlap_apply(s, p, delta),
                lambda s, p: (p, s),
                state, params)
            return params, state
        params, state = self.local_step(state, params, grads)
        params, state = self.maybe_communicate(state, params)
        return params, state

    # -- fused round (the canonical hot path) -----------------------------------
    def round(self, state, params, grads_fn, batches, *,
              local_step=None, comm_round=None, gossip=True,
              overlap_begin=None, overlap_apply=None, overlap_refresh=None):
        """One whole round, fused: ``lax.scan`` of p local steps then exactly
        one unconditional gossip round — no per-step ``lax.cond``, no per-step
        Python dispatch.

        ``grads_fn(params, batch) -> (loss, grads)``; ``batches`` carries a
        leading scan dim of length p.  ``local_step``/``comm_round`` default
        to the optimizer's own methods (DenseComm simulation); the sharded
        runtime passes ``shard_map``-wrapped versions so the identical scan
        structure drives both backends.  ``gossip=False`` runs a fused tail
        of local steps only (a run whose length is not a multiple of p).

        With ``use_kernel`` and no injected overrides the round executes on
        the flatten-once Pallas layout instead (:meth:`kernel_round`).

        With ``overlap`` the round takes the delayed-mixing form instead:
        the in-flight payload's exchange is issued at round *start*
        (``overlap_begin``), the p-step scan runs with no data dependence
        on it (MT's per-step refresh excepted), and the stale correction
        lands after the scan (``overlap_apply``), which also cuts the next
        round's payload.  ``overlap_begin``/``overlap_refresh``/
        ``overlap_apply`` are injectable exactly like ``local_step``/
        ``comm_round`` (the sharded runtime passes shard_mapped versions).

        Returns ``(params, state, losses)`` with ``losses`` stacked over the
        p local steps.
        """
        if (self.config.use_kernel and local_step is None
                and comm_round is None and overlap_begin is None
                and overlap_apply is None):
            return self.kernel_round(state, params, grads_fn, batches,
                                     gossip=gossip)
        if local_step is None:
            local_step = self.local_step
        if comm_round is None:
            comm_round = self.comm_round

        if self.config.overlap:
            if overlap_begin is None:
                overlap_begin = self.overlap_begin
            if overlap_apply is None:
                overlap_apply = self.overlap_apply
            if overlap_refresh is None and self.overlap_refreshes:
                overlap_refresh = self.overlap_step_refresh
            delta = overlap_begin(state) if (gossip or overlap_refresh) \
                else None

            def body(carry, batch):
                params, state = carry
                loss, grads = grads_fn(params, batch)
                params, state = local_step(state, params, grads)
                if overlap_refresh is not None:
                    state = overlap_refresh(state, delta)
                return (params, state), loss

            (params, state), losses = jax.lax.scan(body, (params, state),
                                                   batches)
            if gossip:
                params, state = overlap_apply(state, params, delta)
            return params, state, losses

        def body(carry, batch):
            params, state = carry
            loss, grads = grads_fn(params, batch)
            params, state = local_step(state, params, grads)
            return (params, state), loss

        (params, state), losses = jax.lax.scan(body, (params, state), batches)
        if gossip:
            params, state = comm_round(state, params)
        return params, state, losses

    # -- kernel round: flatten once, scan + gossip on the (rows, 1024) layout --
    @property
    def kernel_comm_supported(self) -> bool:
        """Whether ``comm_round_mat`` can run this optimizer's gossip on the
        kernel matrix (PD-SGDM: always — worst case it falls back to
        ``comm.mix`` *on the matrix*, still flatten-once)."""
        return True

    def mat_state(self, plan, state) -> dict:
        """Flatten the per-element optimizer state trees into kernel mats."""
        mats = {"m": plan.flatten(state["m"])}
        if self.config.overlap:
            mats["mix_buf"] = plan.flatten(state["mix"]["buf"])
        return mats

    def unmat_state(self, plan, mats, state, step) -> dict:
        new_state = dict(state)
        new_state["m"] = plan.unflatten(mats["m"], dtype=jnp.float32)
        new_state["step"] = step
        if self.config.overlap:
            new_state["mix"] = {
                **state["mix"],
                "buf": plan.unflatten(mats["mix_buf"], dtype=jnp.float32),
            }
        return new_state

    def local_step_mat(self, x_mat, mats, g_mat, step):
        """One fused momentum update on the kernel layout (Alg. 1 lines 2-4)."""
        from repro.kernels import ops as kops
        cfg = self.config
        x_new, m_new = kops.momentum_update_mat(
            x_mat, mats["m"], g_mat, mu=cfg.mu,
            lr=cfg.lr(step).astype(jnp.float32),
            weight_decay=cfg.weight_decay, nesterov=cfg.nesterov,
            interpret=cfg.kernel_interpret)
        return x_new, {**mats, "m": m_new}

    def _shift_view_mat(self, mat, ax: int, sh: int):
        """The matrix each worker receives from its (ax, sh) neighbour."""
        if isinstance(self.comm, DenseComm):
            return self.comm._roll(mat, ax, sh)
        return self.comm._receive_from(mat, ax, sh)

    def _mat_wire_static(self) -> bool:
        """Whether ``_gossip_mat`` runs the shift-structured AXPY wire:
        static graph, full membership, no perms, not complete — the path
        whose neighbour exchanges slice to ``plan.used_rows`` (block-exact
        accounting).  Elastic membership routes through ``comm.mix`` on
        the matrix, which owns the per-round edge pruning."""
        top = self.comm.topology
        return ((self.comm.schedule is None or self.comm.period == 1)
                and self.comm.membership is None
                and not top.perms
                and top.name not in ("complete", "disconnected",
                                     "hierarchical"))

    def _gossip_mat(self, x_mat, r, *, plan=None):
        """Gossip mix on the kernel layout.  Static shift-structured graphs
        run the fused Pallas AXPY per topology axis (mirroring
        ``ShardedComm._mix_with``'s Kronecker factorization); everything
        else (schedules, ``complete``, perm graphs) falls back to
        ``comm.mix`` applied to the matrix — still flatten-once.

        With a ``plan``, each neighbour exchange ships only the
        ``plan.used_rows`` wire extent: the block-alignment tail is zero
        on every worker and row-local mixing keeps it zero, so slicing is
        exact and the ppermute bytes equal ``bytes_per_comm_round``.
        """
        from repro.kernels import ops as kops
        if not self._mat_wire_static():
            comm = self.comm
            if (isinstance(comm, HierarchicalComm)
                    and (comm.schedule is None or comm.period == 1)):
                # two-level round on the matrix: intra pmean on the full
                # rows, inter wire sliced to used_rows (accounted ≡ shipped)
                return comm.mix_mat(x_mat, plan=plan)
            return self.comm.mix(x_mat, r=r)
        top = self.comm.topology
        u = plan.used_rows if plan is not None else None
        per_axis: dict = {}
        for (ax, sh, w) in top.shifts:
            per_axis.setdefault(ax, []).append((sh, w))
        y = x_mat
        for ax in sorted(per_axis):
            views, weights = [], []
            payload = self._wire_cast_mat(y)
            for (sh, w) in per_axis[ax]:
                if sh == 0:
                    views.append(y)
                elif u is not None and u < y.shape[-2]:
                    views.append(plan.pad_wire(self._unwire_cast_mat(
                        self._shift_view_mat(plan.wire(payload), ax, sh))))
                else:
                    views.append(self._unwire_cast_mat(
                        self._shift_view_mat(payload, ax, sh)))
                weights.append(w)
            y = kops.gossip_mix_mat(tuple(views), tuple(weights),
                                    interpret=self.config.kernel_interpret)
        return y

    def _wire_cast_mat(self, v):
        """The neighbour payload in the backend's wire dtype (bf16 halves
        the kernel-path bytes; the self view stays f32).  Bitcast to u16
        so the down-cast cannot slide past the ppermute (see
        ``CommBackend._wire_cast``)."""
        if getattr(self.comm, "wire_dtype", "float32") == "bfloat16":
            return jax.lax.bitcast_convert_type(v.astype(jnp.bfloat16),
                                                jnp.uint16)
        return v

    def _unwire_cast_mat(self, v):
        """Received kernel payload back to f32 (inverse of
        ``_wire_cast_mat``)."""
        if getattr(self.comm, "wire_dtype", "float32") == "bfloat16":
            return jax.lax.bitcast_convert_type(
                v, jnp.bfloat16).astype(jnp.float32)
        return v.astype(jnp.float32)

    def comm_round_mat(self, x_mat, mats, counts, r, *, plan=None):
        """One gossip round on the kernel layout (``counts`` unused here;
        CPD-SGDM's override feeds it to the sign kernel)."""
        return self._gossip_mat(x_mat, r, plan=plan), mats

    # -- overlapped rounds on the kernel layout ---------------------------------
    def _stale_gossip_mat(self, x_mat, r, *, plan=None):
        """Stale mix on the kernel matrix.  Static full-membership graphs
        reuse the shift-structured AXPY wire (stale ≡ regular there: no
        membership mask to shift by one round); hierarchical comms carry
        no membership either, so stale ≡ regular and the plan-sliced wire
        applies too; elastic/scheduled comms route through
        ``comm.stale_mix`` on the matrix, which keys the membership mask
        on the delivery round r+1."""
        if self._mat_wire_static() or isinstance(self.comm,
                                                 HierarchicalComm):
            return self._gossip_mat(x_mat, r, plan=plan)
        return self.comm.stale_mix(x_mat, r=r)

    def overlap_begin_mat(self, mats, r, gate, *, plan=None):
        """Matrix-domain ``overlap_begin``: issue the in-flight payload's
        exchange and form the stale correction, gated by the staleness
        phase (``gate`` is a traced f32 scalar, folded by multiply because
        the fused AXPY kernel takes static weights)."""
        buf = mats["mix_buf"]
        mixed = self._stale_gossip_mat(buf, r, plan=plan)
        return {"dx": (mixed - buf) * gate}

    def overlap_refresh_mat(self, mats, delta):
        """Per-local-step refresh on the kernel layout (no-op here; MT's
        override drips the stale tracking correction)."""
        return mats

    def overlap_apply_mat(self, x_mat, mats, delta, r):
        """Land the stale correction matrix-to-matrix (fused AXPY), then
        cut the next payload by snapshotting the mixed matrix.  ``r`` is
        the landing round (QG's override keys its LR normalizer on it)."""
        from repro.kernels import ops as kops
        x_new = kops.delayed_mix_mat(x_mat, delta["dx"],
                                     interpret=self.config.kernel_interpret)
        return x_new, {**mats, "mix_buf": x_new}

    def kernel_round(self, state, params, grads_fn, batches, *, gossip=True,
                     local_step_mat=None, comm_round_mat=None,
                     overlap_begin_mat=None, overlap_apply_mat=None,
                     overlap_refresh_mat=None):
        """The fused round on the flatten-once kernel layout.

        Params and the per-element state trees are flattened into the
        canonical (rows, 1024) matrices **once**, the ``lax.scan`` of p
        momentum updates runs matrix-to-matrix (the tree form is only
        rematerialized to evaluate ``grads_fn``), the gossip mix — and
        CPD-SGDM's sign pack/unpack — operate on the same layout, and the
        trees are rebuilt once at the round boundary.  Master copies stay
        f32 across the round (leaf dtypes are restored at unflatten).

        ``local_step_mat``/``comm_round_mat`` default to the optimizer's own
        matrix methods (DenseComm simulation); the sharded runtime passes
        ``shard_map``-wrapped versions, exactly like :meth:`round`.
        """
        from repro.kernels import ops as kops
        plan = kops.KernelPlan.for_tree(params, worker_dim=True)
        if local_step_mat is None:
            local_step_mat = self.local_step_mat
        if comm_round_mat is None:
            comm_round_mat = functools.partial(self.comm_round_mat,
                                               plan=plan)
        x_mat = plan.flatten(params)
        mats = self.mat_state(plan, state)

        if self.config.overlap:
            if not self.kernel_comm_supported:
                raise ValueError(
                    "overlap=True on the kernel path requires matrix-domain "
                    "gossip (kernel_comm_supported)")
            if overlap_begin_mat is None:
                overlap_begin_mat = functools.partial(self.overlap_begin_mat,
                                                      plan=plan)
            if overlap_apply_mat is None:
                overlap_apply_mat = self.overlap_apply_mat
            if overlap_refresh_mat is None and self.overlap_refreshes:
                overlap_refresh_mat = self.overlap_refresh_mat
            # round start: step = (r+1)·p, so r below is the payload round
            r = state["step"] // self.config.p - 1
            gate = (state["mix"]["phase"] > 0).astype(jnp.float32)
            delta = overlap_begin_mat(mats, r, gate)

            def body(carry, batch):
                x_mat, mats, step = carry
                loss, grads = grads_fn(plan.unflatten(x_mat), batch)
                x_mat, mats = local_step_mat(x_mat, mats,
                                             plan.flatten(grads), step)
                if overlap_refresh_mat is not None:
                    mats = overlap_refresh_mat(mats, delta)
                return (x_mat, mats, step + 1), loss

            (x_mat, mats, step), losses = jax.lax.scan(
                body, (x_mat, mats, state["step"]), batches)
            if gossip:
                x_mat, mats = overlap_apply_mat(x_mat, mats, delta,
                                                step // self.config.p - 1)
            params = plan.unflatten(x_mat)
            state = self.unmat_state(plan, mats, state, step)
            if gossip:
                state = dict(state)
                state["mix"] = {**state["mix"],
                                "phase": jnp.ones((), jnp.int32)}
            return params, state, losses

        def body(carry, batch):
            x_mat, mats, step = carry
            loss, grads = grads_fn(plan.unflatten(x_mat), batch)
            x_mat, mats = local_step_mat(x_mat, mats, plan.flatten(grads),
                                         step)
            return (x_mat, mats, step + 1), loss

        (x_mat, mats, step), losses = jax.lax.scan(
            body, (x_mat, mats, state["step"]), batches)

        if gossip and self.kernel_comm_supported:
            r = step // self.config.p - 1
            x_mat, mats = comm_round_mat(x_mat, mats, plan.row_counts(), r)
        params = plan.unflatten(x_mat)
        state = self.unmat_state(plan, mats, state, step)
        if gossip and not self.kernel_comm_supported:
            # e.g. CPD with a non-kernel compressor: tree comm at the boundary
            params, state = self.comm_round(state, params)
        return params, state, losses

    # -- comm-cost model ----------------------------------------------------------
    def _mat_wire_rows(self, params) -> int:
        """``used_rows`` wire extent of the kernel layout: Σ per-leaf
        ceil(size/1024) rows."""
        import numpy as np
        from repro.kernels import LANE
        return sum(-(-int(np.prod(l.shape, dtype=np.int64)) // LANE)
                   for l in jax.tree_util.tree_leaves(params))

    def _mat_wire_bytes(self, params) -> int:
        """Bytes of one neighbour exchange on the kernel layout: the
        ``used_rows`` wire extent (Σ per-leaf ceil(size/1024) rows × 1024)
        at the wire dtype — master copies stay f32 across the round, but a
        bf16 wire ships the neighbour payload at 2 B/elem."""
        from repro.kernels import LANE
        item = min(4, getattr(self.comm, "wire_itemsize", 4))
        return self._mat_wire_rows(params) * LANE * item

    def _kernel_wire_active(self) -> bool:
        return (self.config.use_kernel and self.kernel_comm_supported
                and self._mat_wire_static())

    def _kernel_hier_active(self) -> bool:
        """Whether the round gossips through ``HierarchicalComm.mix_mat``
        (kernel layout, static hierarchical graph) — the inter payload is
        then the ``(used_rows, 1024)`` matrix, not the leaf tree."""
        return (self.config.use_kernel and self.kernel_comm_supported
                and isinstance(self.comm, HierarchicalComm)
                and (self.comm.schedule is None or self.comm.period == 1))

    def hier_bytes_per_level(self, params, r: int = 0) -> dict:
        """Per-level byte split of one hierarchical round (see
        :func:`repro.core.gossip.hier_bytes_per_round`); on the kernel
        path the payload is the flatten-once ``used_rows × 1024`` matrix."""
        from repro.core.gossip import hier_bytes_per_round
        from repro.kernels import LANE
        payload = params
        if self._kernel_hier_active():
            payload = [jax.ShapeDtypeStruct(
                (self._mat_wire_rows(params) * LANE,), jnp.float32)]
        return hier_bytes_per_round(payload, self.comm, r=r)

    def bytes_per_comm_round(self, params, r: int = 0) -> int:
        from repro.core.gossip import gossip_bytes_per_round
        top = self.comm.topology_at(r)
        if top.name == "hierarchical" and self.comm.membership is None:
            return self.hier_bytes_per_level(params, r=r)["inter"]
        if self._kernel_wire_active():
            deg = self.comm.topology_at(r).degree
            return deg * self._mat_wire_bytes(params)
        return gossip_bytes_per_round(params, self.comm, r=r)

    def bytes_per_round_cycle(self, params) -> tuple:
        """Per-round bytes over one joint schedule × membership cycle
        (1-tuple when both static); the trainers accumulate these
        round-robin for comm-MB accounting.  Rounds where a worker is dead
        or straggling ship fewer bytes — dead edges count zero."""
        return tuple(self.bytes_per_comm_round(params, r=r)
                     for r in range(self.comm.round_cycle))
