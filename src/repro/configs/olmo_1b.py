"""olmo-1b — OLMo [arXiv:2402.00838].

16L, d_model 2048, 16 heads (MHA: kv=16), d_ff 8192, vocab 50304.
Non-parametric LayerNorm (no scale/bias) — OLMo's signature choice.
"""
from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="olmo-1b", arch_type="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304, norm="nonparametric", gated_mlp=False,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2402.00838",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="A"),
                  optim=OptimCfg())
