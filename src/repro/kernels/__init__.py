"""Pallas TPU kernels for the paper's memory-bound hot spots.

momentum       — fused SGDM update (PD-SGDM inner loop)
sign_compress  — blockwise scaled-sign + bit-pack (CPD-SGDM wire format)
gossip_mix     — fused W-row neighbour AXPY after ppermute

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling; ``ops.py``
holds the jit'd pytree wrappers (interpret-mode on CPU); ``ref.py`` the
pure-jnp oracles used by the allclose sweeps in tests/test_kernels.py.
"""
