"""Pallas TPU kernels for the paper's memory-bound hot spots.

momentum       — fused SGDM update (PD-SGDM inner loop)
sign_compress  — blockwise scaled-sign + bit-pack (sign wire codec)
topk_select    — per-row magnitude top-k select/scatter (top-k wire codec)
qsgd_quant     — s-level quantize + uintN bit-pack (QSGD wire codec)
row_gather     — scalar-prefetch touched-row gather/scatter (sparse wire)
gossip_mix     — fused W-row neighbour AXPY after ppermute

The three wire-codec kernel pairs all operate on the flatten-once
(rows, 1024) layout and are dispatched through ``repro.core.wire``'s
``rows_pack``/``rows_unpack`` — one codec interface covers the per-leaf
jnp fallback and the kernel path on both comm backends.

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling; ``ops.py``
holds the ``KernelPlan`` flatten-once layout and the jit'd pytree wrappers
(interpret-mode on CPU); ``ref.py`` the pure-jnp oracles used by the
allclose sweeps in tests/test_kernels.py.

This module stays import-light (no jax at module level) so configs and
the lint CLI can read :data:`LANE` without initializing a backend.
"""

# The kernel lane width: elements per row of the flatten-once (rows, LANE)
# layout (8 × 128-lane vregs) and the wire codecs' scale-block size.  This
# is the single definition site — everything else (kernels, configs,
# compression blocks) imports it; tools/lint_repro.py enforces that no
# bare 1024 lane literal exists outside this package.
LANE = 1024


def default_interpret() -> bool:
    """Whether Pallas calls should run in interpret mode *right now*.

    Evaluated lazily (not pinned at import time) so backend selection that
    happens after this package is imported — ``jax.config`` updates in
    tests, subprocess runners forcing host devices — is respected.  Every
    kernel entry point also takes an explicit ``interpret=`` override.
    """
    import jax
    return jax.default_backend() != "tpu"
