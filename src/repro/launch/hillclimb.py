import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import dataclasses   # noqa: E402

from repro.launch.dryrun import run_one  # noqa: E402

"""§Perf hillclimb driver: tagged variants of the three chosen pairs.

Each variant is a config delta over the paper-faithful baseline; artifacts
land in artifacts/hillclimb/ tagged so EXPERIMENTS.md §Perf can diff them
against artifacts/dryrun/ baselines.

  PYTHONPATH=src python -m repro.launch.hillclimb --pair olmo
  PYTHONPATH=src python -m repro.launch.hillclimb            # all pairs
"""


def _opt(name):
    def f(run):
        return dataclasses.replace(
            run, optim=dataclasses.replace(run.optim, name=name))
    return f


def _par(**kw):
    def f(run):
        return dataclasses.replace(
            run, parallel=dataclasses.replace(run.parallel, **kw))
    return f


def _model(**kw):
    def f(run):
        return dataclasses.replace(
            run, model=dataclasses.replace(run.model, **kw))
    return f


def _chain(*fns):
    def f(run):
        for fn in fns:
            run = fn(run)
        return run
    return f


PAIRS = {
    # --- adoption sweep: validated levers applied to further pairs ---
    "mixtral-adopt": ("mixtral-8x7b", "train_4k", [
        ("adopt_ctx_moe", _chain(_model(moe_groups=16),
                                 _par(attn_ctx_shard=True,
                                      moe_token_shard=True))),
    ]),
    "qwen2-adopt": ("qwen2-72b", "train_4k", [
        ("adopt_ctx", _par(attn_ctx_shard=True)),
    ]),
    "musicgen-adopt": ("musicgen-medium", "train_4k", [
        ("adopt_worker", _par(inner="worker", topology="torus")),
        ("adopt_worker_cpd", _chain(_par(inner="worker", topology="torus"),
                                    _opt("cpd_sgdm"))),
    ]),
    "stablelm-adopt": ("stablelm-12b", "train_4k", [
        ("adopt_ctx", _par(attn_ctx_shard=True)),
        ("adopt_ctx_dp", _par(attn_ctx_shard=True, inner="dp")),
    ]),
    "jamba-prefill-adopt": ("jamba-1.5-large-398b", "decode_32k", [
        ("adopt_moe_groups", _chain(_model(moe_groups=16),
                                    _par(moe_token_shard=True))),
    ]),
    # most representative of the paper's technique (profile-A gossip)
    "olmo": ("olmo-1b", "train_4k", [
        ("cpd_sign", _opt("cpd_sgdm")),
        ("inner_dp", _par(inner="dp")),
        ("inner_dp_cpd", _chain(_par(inner="dp"), _opt("cpd_sgdm"))),
        ("inner_dp_cpd_p16", _chain(
            _par(inner="dp"), _opt("cpd_sgdm"),
            lambda r: dataclasses.replace(
                r, optim=dataclasses.replace(r.optim, p=16)))),
        ("worker_per_chip", _par(inner="worker", topology="torus")),
        ("worker_per_chip_cpd", _chain(
            _par(inner="worker", topology="torus"), _opt("cpd_sgdm"))),
    ]),
    # worst roofline fraction: collective-bound MoE training
    "arctic": ("arctic-480b", "train_4k", [
        ("ctx_attn", _par(attn_ctx_shard=True)),
        ("ctx_attn_moe", _par(attn_ctx_shard=True, moe_token_shard=True)),
        ("ctx_moe_groups", _chain(_model(moe_groups=16),
                                  _par(attn_ctx_shard=True,
                                       moe_token_shard=True))),
        ("ctx_moe_noremat", _chain(_model(moe_groups=16),
                                   _par(attn_ctx_shard=True,
                                        moe_token_shard=True,
                                        remat="none"))),
    ]),
    # most collective-bound serving pair
    "jamba": ("jamba-1.5-large-398b", "prefill_32k", [
        ("ssm_bcast", _model(ssm_bcast_groups=True)),
        ("ssm_bcast_ctx", _chain(_model(ssm_bcast_groups=True),
                                 _par(attn_ctx_shard=True))),
        ("moe_groups", _chain(_model(moe_groups=16),
                              _par(moe_token_shard=True))),
        ("moe_groups_ctx", _chain(_model(moe_groups=16,
                                         ssm_bcast_groups=True),
                                  _par(attn_ctx_shard=True,
                                       moe_token_shard=True))),
    ]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), default=None)
    ap.add_argument("--tag", default=None, help="run a single variant")
    ap.add_argument("--outdir", default="artifacts/hillclimb")
    args = ap.parse_args()

    pairs = [args.pair] if args.pair else list(PAIRS)
    for p in pairs:
        arch, shape, variants = PAIRS[p]
        for tag, ov in variants:
            if args.tag and tag != args.tag:
                continue
            run_one(arch, shape, False, args.outdir, overrides=ov, tag=tag)


if __name__ == "__main__":
    main()
