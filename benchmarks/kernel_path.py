"""Kernel-path benchmark: per-leaf jnp packed round vs flatten-once rounds.

Three drivers execute the identical CPD-SGDM round (p local momentum steps
+ consensus + sign-compressed wire) over a many-leaf parameter tree:

  * ``jnp_perleaf``     — ``use_kernel=False``, ``packed_wire=False``: the
    per-leaf jnp path (Q applied leaf by leaf, momentum as a per-leaf
    tree_map) — the seed implementation of the wire.
  * ``kernel_perstep``  — ``use_kernel=True`` driven through the *tree*
    round (injected ``local_step``): every local step re-flattens the whole
    tree into the (rows, 1024) layout and unflattens it again — the old
    "kernel sidecar" behaviour this PR removes.
  * ``kernel_fused``    — ``use_kernel=True`` fused round
    (``PDSGDM.kernel_round``): flatten once per round, scan + gossip +
    sign wire all on the matrix.

All kernel calls run in interpret mode on CPU, so ``kernel_fused`` vs
``kernel_perstep`` is the *interpret-parity* comparison — both pay the same
per-kernel emulation cost and the measured gap is exactly the flatten-once
layout win.  ``kernel_fused`` vs ``jnp_perleaf`` additionally carries the
interpret-mode emulation overhead, which on CPU can mask the layout win for
small trees (the derived row notes when it does); on TPU the kernels are
the fast path, interpret mode exists only as the correctness harness.

Derived: rounds/sec per driver and speedups at each communication period p.

``BENCH_REPEATS`` / ``BENCH_ROUNDS`` / ``BENCH_PS`` trim the measurement
for CI smoke runs — absolute times shrink but the within-run *ratios*
(``fused_vs_perstep_parity``) stay comparable, which is what
``tools/bench_compare.py`` gates on.
"""
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import CPDSGDM, CPDSGDMConfig, SignCompressor
from repro.core.gossip import DenseComm
from repro.core.topology import ring

K = 4
REPEATS = int(os.environ.get("BENCH_REPEATS", "3"))
ROUNDS = int(os.environ.get("BENCH_ROUNDS", "12"))  # rounds timed per repeat
PS = [int(p) for p in os.environ.get("BENCH_PS", "1,4,8").split(",")]


def _params():
    """A many-leaf tree with ragged sizes (tail-padded rows exercised)."""
    key = jax.random.PRNGKey(0)
    leaves = {}
    for i, shape in enumerate(
            [(257, 129), (64, 300), (1000,), (33, 65), (7, 11, 13),
             (2048,), (129,), (301, 5)] * 3):
        leaves[f"w{i}"] = jax.random.normal(
            jax.random.fold_in(key, i), (K,) + shape) * 0.1
    return leaves


def _grads_fn(params, batch):
    losses = jnp.zeros((K,))
    grads = jax.tree_util.tree_map(lambda x: 0.3 * x + batch, params)
    return losses.mean(), grads


def _opt(p, *, use_kernel, packed_wire=True):
    cfg = CPDSGDMConfig(eta=0.05, mu=0.9, p=p, gamma=0.4,
                        weight_decay=1e-4, use_kernel=use_kernel,
                        packed_wire=packed_wire)
    return CPDSGDM(cfg, DenseComm(ring(K)), SignCompressor())


def _time_rounds(round_fn, params, state, batches):
    """Compile, then best-of-REPEATS wall time for ROUNDS rounds."""
    def run():
        p_, s_ = params, state
        for _ in range(ROUNDS):
            p_, s_, losses = round_fn(s_, p_, batches)
        jax.block_until_ready(p_)
    run()
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return ROUNDS / best


def main():
    results = {}
    params = _params()
    for p in PS:
        batches = jnp.zeros((p, 1))
        drivers = {}

        opt_jnp = _opt(p, use_kernel=False, packed_wire=False)
        drivers["jnp_perleaf"] = jax.jit(
            lambda s, pp, bs, o=opt_jnp: o.round(s, pp, _grads_fn, bs))

        # per-step kernel: tree round with the kernel local_step injected —
        # flatten/unflatten on every one of the p steps
        opt_ps = _opt(p, use_kernel=True)
        drivers["kernel_perstep"] = jax.jit(
            lambda s, pp, bs, o=opt_ps: o.round(
                s, pp, _grads_fn, bs,
                local_step=o.local_step, comm_round=o.comm_round))

        opt_fused = _opt(p, use_kernel=True)
        drivers["kernel_fused"] = jax.jit(
            lambda s, pp, bs, o=opt_fused: o.round(s, pp, _grads_fn, bs))

        rps = {}
        for name, fn in drivers.items():
            opt = {"jnp_perleaf": opt_jnp, "kernel_perstep": opt_ps,
                   "kernel_fused": opt_fused}[name]
            rps[name] = _time_rounds(fn, params, opt.init(params), batches)

        parity = rps["kernel_fused"] / rps["kernel_perstep"]
        vs_jnp = rps["kernel_fused"] / rps["jnp_perleaf"]
        results[p] = (rps, parity, vs_jnp)
        for name in drivers:
            csv_row(f"kernel_path/{name}_p{p}", 1e6 / rps[name],
                    f"rounds_per_s={rps[name]:.2f}")
        note = ""
        if vs_jnp < 1.2 and jax.default_backend() != "tpu":
            note = (";note=interpret-mode emulation overhead on CPU masks "
                    "the layout win vs raw jnp - parity row is the honest "
                    "comparison")
        csv_row(f"kernel_path/speedup_p{p}", 0.0,
                f"fused_vs_perstep_parity={parity:.2f};"
                f"fused_vs_jnp={vs_jnp:.2f}{note}")
    return results


if __name__ == "__main__":
    main()
