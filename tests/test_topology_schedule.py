"""Time-varying gossip through the fused round engine.

Fast tier: DenseComm scheduled rounds vs a numpy reference, fused-round vs
per-step equivalence under a schedule, varying-degree comm-MB accounting,
and the CPD-SGDM backend gates.  The ShardedComm scheduled equivalence
(ppermute programs selected by ``lax.switch``) runs in a slow-marked
subprocess with 8 forced host devices.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPDSGDM, CPDSGDMConfig, PDSGDM, PDSGDMConfig,
                        SignCompressor)
from repro.core.gossip import DenseComm, ShardedComm
from repro.core.topology import (make_schedule, one_peer_exponential_schedule,
                                 random_matching_schedule, ring)
from repro.train.trainer import SimTrainer, _bytes_through

K, D, P = 8, 6, 2


def _loss_fn(params, batch):
    return 0.5 * jnp.mean((params["w"] - batch) ** 2), {}


def _batch(t):
    return jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(7), t), (K, D))


def _params():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (K, D))}


def test_scheduled_dense_round_matches_numpy_reference():
    """Fused rounds under a one-peer exponential schedule must apply round
    r's W_r exactly — cross-checked against a from-scratch numpy loop."""
    sched = one_peer_exponential_schedule(K)
    opt = PDSGDM(PDSGDMConfig(eta=0.1, mu=0.9, p=P), DenseComm(sched))
    grad = jax.vmap(jax.value_and_grad(lambda pp, b: _loss_fn(pp, b)[0]))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    n_rounds = 2 * sched.period       # two full cycles
    params = _params()
    state = opt.init(params)
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))

    x = np.asarray(params["w"], np.float64)
    m = np.zeros_like(x)
    for r in range(n_rounds):
        bs = jnp.stack([_batch(r * P + i) for i in range(P)])
        params, state, _ = roundj(state, params, bs)
        for i in range(P):
            g = (x - np.asarray(bs[i], np.float64)) / x.size * K  # mean grad
            m = 0.9 * m + g
            x = x - 0.1 * m
        x = sched.at(r).W @ x

    np.testing.assert_allclose(np.asarray(params["w"]), x,
                               rtol=1e-5, atol=1e-5)
    assert int(state["step"]) == n_rounds * P


@pytest.mark.parametrize("sched_name", ["one_peer_exp", "random_matching"])
def test_scheduled_round_equals_per_step(sched_name):
    """opt.round == p × opt.step under a time-varying schedule: the fused
    path and the per-step ``lax.cond`` path must select the same W_r."""
    sched = make_schedule(sched_name, (K,), rounds=3, seed=2)
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=P), DenseComm(sched))
    grad = jax.vmap(jax.value_and_grad(lambda pp, b: _loss_fn(pp, b)[0]))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    steps = P * (sched.period + 1)    # wraps past the cycle boundary
    params = _params()
    state = opt.init(params)
    stepj = jax.jit(lambda s, pp, b: opt.step(s, pp, grad(pp, b)[1]))
    for t in range(steps):
        params, state = stepj(state, params, _batch(t))

    params2 = _params()
    state2 = opt.init(params2)
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    for r in range(steps // P):
        bs = jnp.stack([_batch(r * P + i) for i in range(P)])
        params2, state2, _ = roundj(state2, params2, bs)

    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(params2["w"]),
                               rtol=1e-6, atol=1e-6)


def test_varying_degree_comm_accounting():
    """comm-MB accounting must follow the per-round degree: one-peer rounds
    send half a ring round's bytes, and the cycle accumulates round-robin."""
    sched = one_peer_exponential_schedule(K)
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=P), DenseComm(sched))
    trainer = SimTrainer(_loss_fn, opt)
    steps = 9                          # 4 rounds + 1 tail step
    params, _, hist = trainer.train(_params(), _batch, steps, log_every=2)

    cycle = trainer.bytes_per_round_cycle(params)
    assert len(cycle) == sched.period
    # degree 1 each round → every round costs the same, half a ring round
    ring_opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=P),
                      DenseComm(ring(K)))
    ring_bytes = SimTrainer(_loss_fn, ring_opt).bytes_per_round(params)
    assert all(b == ring_bytes // 2 for b in cycle)
    for t, mb in zip(hist.steps, hist.comm_mb):
        assert mb == pytest.approx(
            _bytes_through((t + 1) // P, cycle) / 2 ** 20), t

    # a schedule with genuinely different per-round degrees accumulates
    # round-robin, not degree × rounds
    mixed = make_schedule("alt_axes", (2, 4))
    opt2 = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=P), DenseComm(mixed))
    cyc2 = opt2.bytes_per_round_cycle(
        jax.tree_util.tree_map(lambda x: x[0], _params()))
    assert _bytes_through(3, cyc2) == cyc2[0] + cyc2[1] + cyc2[0]


def test_cpdsgdm_backend_gates():
    """CPD-SGDM: time-varying schedules run on the dense backend but are
    rejected on the sharded one (xhat_nbrs needs a fixed neighbour set)."""
    sched = one_peer_exponential_schedule(4)
    with pytest.raises(ValueError, match="static topology"):
        CPDSGDM(CPDSGDMConfig(p=2), ShardedComm(sched, axis_names=("w",)),
                SignCompressor())

    # dense: one full cycle of compressed gossip runs and stays finite
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=P, gamma=0.4),
                  DenseComm(one_peer_exponential_schedule(K)),
                  SignCompressor(block=8))
    grad = jax.vmap(jax.value_and_grad(lambda pp, b: _loss_fn(pp, b)[0]))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    params = _params()
    state = opt.init(params)
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    for r in range(3):
        bs = jnp.stack([_batch(r * P + i) for i in range(P)])
        params, state, losses = roundj(state, params, bs)
    assert bool(jnp.all(jnp.isfinite(params["w"])))
    assert bool(jnp.all(jnp.isfinite(state["xhat"]["w"])))


def test_dense_schedule_requires_round_index():
    comm = DenseComm(one_peer_exponential_schedule(4))
    with pytest.raises(ValueError, match="round index"):
        comm.mix({"w": jnp.ones((4, 2))})


_SCRIPT_SHARDED_SCHED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import make_schedule
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.models import make_model

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    for sched_name in ["one_peer_exp", "random_matching"]:
        run = RunCfg(model=mcfg,
                     parallel=ParallelCfg(profile="A", remat="none",
                                          topology_schedule=sched_name,
                                          schedule_rounds=2, schedule_seed=5),
                     optim=OptimCfg(name="pd_sgdm", eta=0.05, mu=0.9, p=2,
                                    weight_decay=1e-4))
        mesh = make_debug_mesh(4, 2)
        pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
        K = pack.layout.n_workers
        sched = pack.opt.comm.schedule
        assert sched is not None and sched.period == 2, (sched_name, sched)
        params, state = pack.init_fn(jax.random.PRNGKey(0))
        nb = [train_batch_arrays(mcfg, K, 2, 16,
              jax.random.fold_in(jax.random.PRNGKey(1), t)) for t in range(8)]
        # 4 rounds = 2 full schedule cycles through the fused path
        for r in range(4):
            rb = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                        *nb[2*r:2*r+2])
            params, state, losses = pack.train_round(params, state, rb)
        sharded = jax.tree_util.tree_map(np.asarray, params)

        # dense single-device simulation of the same schedule
        model = make_model(mcfg)
        params2 = jax.vmap(lambda k: model.init(jax.random.PRNGKey(0)))(
            jax.random.split(jax.random.PRNGKey(0), K))
        dsched = make_schedule(sched_name, (K,), rounds=2, seed=5)
        opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=2, weight_decay=1e-4),
                     DenseComm(dsched))
        st = opt.init(params2)
        gradf = jax.vmap(jax.value_and_grad(lambda p, b: model.loss(p, b)[0]))
        def gfn(p_, b):
            losses, grads = gradf(p_, b)
            return losses.mean(), grads
        roundj = jax.jit(lambda s_, p_, b: opt.round(s_, p_, gfn, b))
        for r in range(4):
            rb = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                        *nb[2*r:2*r+2])
            params2, st, _ = roundj(st, params2, rb)
        sim = jax.tree_util.tree_map(np.asarray, params2)

        errs = [np.abs(a - b).max() for a, b in
                zip(jax.tree_util.tree_leaves(sharded),
                    jax.tree_util.tree_leaves(sim))]
        print(sched_name, "max err:", max(errs))
        assert max(errs) < 5e-4, (sched_name, max(errs))
        # worker mean preserved by every per-round W (doubly stochastic)
        for a, b in zip(jax.tree_util.tree_leaves(sharded),
                        jax.tree_util.tree_leaves(sim)):
            np.testing.assert_allclose(a.mean(0), b.mean(0), atol=2e-3)
        print("SCHED_EQUIV_OK", sched_name)
""")


def _run(script, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_scheduled_equals_dense_sim():
    """Scheduled ppermute gossip (lax.switch over precomputed per-round
    programs, incl. the perm-based random matchings) == dense (T,K,K)
    simulation, through TrainPack.train_round on both cycles."""
    out = _run(_SCRIPT_SHARDED_SCHED)
    assert "SCHED_EQUIV_OK one_peer_exp" in out
    assert "SCHED_EQUIV_OK random_matching" in out
