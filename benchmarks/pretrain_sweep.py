"""Pretraining sweep: hierarchical two-level gossip vs. the flat ring.

Two row families, one claim row each:

**Analytic comm rows** price one gossip round of the ~100M-param LM
(the ``examples/pretrain_decentralized.py`` full model: 12L × d768,
32k vocab) on K = 8 workers — flat ring(8) vs. the two-level round
(2 nodes × 4 workers, ring between node leaders) at f32 and bf16 inter
wires.  Pure byte accounting through the same
``bytes_per_comm_round`` / ``hier_bytes_per_level`` code the HLO gate
checks against compiled programs, so the numbers are exact on any host:

* flat ring(8): degree 2 × 4 B × N          = 8 N bytes/worker/round
* hier f32: 1 leader edge × 4 B × N ÷ m=4   = 1 N  (8× less inter)
* hier bf16: 1 × 2 B × N ÷ 4                = 0.5 N (16× less inter)

``pretrain/claim_inter_reduction`` pins both ratios (``rel_tol`` 0.02)
and ``reduction_ok`` = 1 iff both are ≥ 2× (``min_frac`` 1.0) — the
deliverable's headline: ≥ 2× inter-node comm reduction.

**Training rows** actually run ``examples/pretrain_decentralized.py``
(subprocess; the sweep and the example share one driver path) twice on
8 host devices — flat ring vs. ``--node-size 2 --wire-dtype bfloat16``
— and record tokens/sec, comm-MB/worker, and the loss-curve endpoints.
``pretrain/claim_equal_loss`` gates ``hier_loss_ok`` = 1 iff the
hierarchical final loss is within 5% of the flat run's (``min_frac``
1.0: equal-or-better final loss at a fraction of the comm volume);
``train_comm_reduction`` reports the measured accounted-MB ratio.
Tokens/sec is recorded but not gated (host-dependent).

Env knobs: ``PRETRAIN_STEPS`` (default 8) trims the training runs;
``PRETRAIN_MODEL=full`` switches them from the quick ~5M model to the
full ~100M one (CI smoke uses quick — the analytic rows always price
the 100M model).

Standalone runs write ``benchmarks/BENCH_pretrain.json``; under
``python -m benchmarks.run pretrain`` the rows land in the main
``BENCH_<tag>.json``.
"""
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row

K = 8            # analytic mesh: 8 workers, 2 nodes × 4
NODE_SIZE = 4
STEPS = int(os.environ.get("PRETRAIN_STEPS", "8"))
MODEL = os.environ.get("PRETRAIN_MODEL", "quick")   # quick | full

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lm100m():
    from repro.configs.base import ModelCfg
    return ModelCfg(name="lm-100m", arch_type="dense", n_layers=12,
                    d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                    vocab=32768)


def analytic_rows() -> dict:
    """Byte-accounting rows for one gossip round of the 100M model."""
    from repro.core import DenseComm, make_optimizer
    from repro.core.topology import hierarchical, ring

    n_params = _lm100m().params_count()
    # accounting only reads leaf sizes — one flat leaf prices the model
    params = [jax.ShapeDtypeStruct((n_params,), jnp.float32)]

    flat = make_optimizer("pd_sgdm", DenseComm(ring(K)), p=4)
    flat_b = float(flat.bytes_per_comm_round(params))
    csv_row("pretrain/comm_flat_ring", 0.0,
            f"mb_per_round={flat_b / 2**20:.4f};workers={K};"
            f"params={n_params}")

    inter = {}
    for wdt in ("float32", "bfloat16"):
        comm = DenseComm(hierarchical(K // NODE_SIZE, NODE_SIZE),
                         wire_dtype=wdt)
        opt = make_optimizer("pd_sgdm", comm, p=4)
        lv = opt.hier_bytes_per_level(params)
        inter[wdt] = lv["inter"]
        tag = "f32" if wdt == "float32" else "bf16"
        csv_row(f"pretrain/comm_hier_{tag}", 0.0,
                f"inter_mb={lv['inter'] / 2**20:.4f};"
                f"intra_mb={lv['intra_wire'] / 2**20:.4f};"
                f"node_size={NODE_SIZE};wire_dtype={wdt}")

    red_f32 = flat_b / inter["float32"]
    red_bf16 = flat_b / inter["bfloat16"]
    ok = int(red_f32 >= 2.0 and red_bf16 >= 2.0)
    csv_row("pretrain/claim_inter_reduction", 0.0,
            f"inter_reduction_f32={red_f32:.4f};"
            f"inter_reduction_bf16={red_bf16:.4f};reduction_ok={ok}")
    return {"flat": flat_b, "inter": inter}


def _run_driver(tag: str, extra: list) -> dict:
    out = os.path.join(tempfile.mkdtemp(prefix="pretrain_"), "run.json")
    cmd = [sys.executable,
           os.path.join(_REPO, "examples", "pretrain_decentralized.py"),
           "--devices", "8", "--steps", str(STEPS), "--json-out", out]
    if MODEL != "full":
        cmd.append("--quick")
    cmd += extra
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    # the driver forces its own host device count — run it clean
    env.pop("XLA_FLAGS", None)
    subprocess.run(cmd, check=True, env=env, cwd=_REPO)
    with open(out) as f:
        return json.load(f)


def train_rows() -> dict:
    """Drive the shared example end-to-end: flat ring vs. two-level."""
    runs = {
        "flat": [],
        "hier": ["--node-size", "2", "--wire-dtype", "bfloat16"],
    }
    recs = {}
    for tag, extra in runs.items():
        r = _run_driver(tag, extra)
        recs[tag] = r
        us = r["wall_s"] / max(r["steps"], 1) * 1e6
        csv_row(f"pretrain/train_{tag}", us,
                f"final_loss={r['final_loss']:.4f};"
                f"first_loss={r['first_loss']:.4f};"
                f"tokens_per_s={r['tokens_per_s']:.1f};"
                f"comm_mb={r['comm_mb']:.4f};"
                f"bytes_per_comm_round={r['bytes_per_comm_round']:.0f};"
                f"model={r['model']};workers={r['workers']};"
                f"steps={r['steps']}")

    flat, hier = recs["flat"], recs["hier"]
    loss_ok = int(hier["final_loss"] <= 1.05 * flat["final_loss"])
    comm_red = flat["comm_mb"] / max(hier["comm_mb"], 1e-12)
    csv_row("pretrain/claim_equal_loss", 0.0,
            f"hier_loss_ok={loss_ok};"
            f"train_comm_reduction={comm_red:.4f};"
            f"flat_final={flat['final_loss']:.4f};"
            f"hier_final={hier['final_loss']:.4f}")
    return recs


def main() -> dict:
    out = {"analytic": analytic_rows(), "train": train_rows()}
    return out


def _write_json(results) -> str:
    from benchmarks.common import collected_rows
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_pretrain.json")
    rows = [r for r in collected_rows() if r["name"].startswith("pretrain/")]
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": ["pretrain"],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "steps": STEPS,
        "model": MODEL,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = main()
    print(f"bench_json,0.0,path={os.path.relpath(_write_json(res))}")
