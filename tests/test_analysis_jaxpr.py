"""jaxpr-level round-contract checks: green on the real optimizers, and
each check catches its seeded violation (negative tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_check as jc
from repro.core import (CPDSGDM, CPDSGDMConfig, PDSGDM, PDSGDMConfig,
                        SignCompressor, make_optimizer)
from repro.core.gossip import DenseComm
from repro.core.topology import make_schedule, ring

K = 8


def _pd(p=3, **kw):
    return PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=p, **kw), DenseComm(ring(K)))


# ------------------------------------------------------------------- positive
def test_pd_tree_contract_clean():
    assert jc.check_round_contract(_pd(), jc.toy_params(K)) == []


def test_pd_kernel_contract_clean():
    opt = _pd(use_kernel=True, kernel_interpret=True)
    assert jc.check_round_contract(opt, jc.toy_params(K), kernel=True) == []


def test_cpd_sign_kernel_contract_clean():
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4,
                                use_kernel=True, kernel_interpret=True),
                  DenseComm(ring(K)), SignCompressor())
    assert jc.check_round_contract(opt, jc.toy_params(K), kernel=True) == []


def test_scheduled_dense_contract_clean():
    sched = make_schedule("one_peer_exp", (K,))
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=2), DenseComm(sched))
    assert jc.check_round_contract(opt, jc.toy_params(K)) == []


def _membership():
    from repro.core.topology import membership_from_events
    return membership_from_events(K, 4, [(1, "kill", 2), (3, "revive", 2),
                                         (2, "straggle", 5)])


def test_membership_contract_clean():
    """Elastic membership on the dense backend: the full round contract
    plus the traced mask semantics (row-stochastic over live peers, e_k
    rows for masked workers, zero dead columns) hold every round."""
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=3),
                 DenseComm(ring(K), membership=_membership()))
    assert jc.check_round_contract(opt, jc.toy_params(K)) == []


def test_catches_gossip_with_masked_out_peer():
    """Negative: a backend whose round-r matrix still carries the full
    topology weights (mask never applied) must be flagged — the dense
    trace shows a nonzero column for the dead worker and a non-identity
    row for the masked one."""
    comm = DenseComm(ring(K), membership=_membership())
    # sabotage the precomputed masked tables back to the raw topology W:
    # every round now gossips as if the whole fleet were alive
    comm._Wm = jnp.broadcast_to(jnp.asarray(ring(K).W, jnp.float32),
                                comm._Wm.shape)
    out = jc.check_membership_mask(comm)
    assert out, "unmasked gossip with a dead worker went undetected"
    joined = "\n".join(out)
    assert "masked-out worker" in joined
    # both failure modes surface: the dead worker still mixing, and an
    # active worker reading its column
    assert any("reads weight" in v for v in out)
    assert any("row != e_k" in v for v in out)


def test_membership_mask_check_skips_full_rounds():
    """All-active rounds reuse the topology matrix bitwise — the check
    passes and the traced matrix equals W exactly."""
    from repro.core.topology import full_membership
    comm = DenseComm(ring(K), membership=full_membership(K))
    assert jc.check_membership_mask(comm) == []
    np.testing.assert_array_equal(jc.traced_mixing_matrix(comm, 0),
                                  np.asarray(ring(K).W, np.float32))


def test_qsgd_tree_no_f64():
    """Regression: the qsgd dequant fill literal was a weak f64 scalar
    under x64 (kernels/qsgd_quant.py) — the whole dense round must now
    trace f64-free."""
    from repro.core import QSGDCompressor
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4),
                  DenseComm(ring(K)), QSGDCompressor())
    jx = jc.trace_round(opt, jc.toy_params(K), 2, x64=True)
    assert jc.check_no_f64(jx) == []


def test_topk_kernel_no_f64():
    """Same regression class for the topk select/scatter kernels."""
    from repro.kernels import topk_select
    from jax.experimental import enable_x64
    rows = topk_select.BLOCK_ROWS
    x = jnp.zeros((rows, 1024), jnp.float32)
    cnt = jnp.full((rows, 1), 1024.0, jnp.float32)
    with enable_x64():
        jx = jax.make_jaxpr(
            lambda x, c: topk_select.topk_select_pallas(
                x, c, fraction=0.01, interpret=True))(x, cnt)
    assert jc.check_no_f64(jx) == []


# ------------------------------------------------------------------- negative
def test_catches_callback_in_scan():
    opt = _pd()

    def noisy_grads(params, batch):
        jax.debug.print("step {x}", x=batch.mean())
        return jc.toy_grads_fn(params, batch)

    jx = jc.trace_round(opt, jc.toy_params(K), 3, grads_fn=noisy_grads)
    out = jc.check_no_host_callbacks(jx)
    assert out and "scan depth 1" in out[0]


def test_catches_f64_injection():
    opt = _pd()

    def leaky_grads(params, batch):
        loss, grads = jc.toy_grads_fn(params, batch)
        # a numpy f64 scalar: silently truncated without x64, a genuine
        # f64 operand with it
        grads = jax.tree_util.tree_map(
            lambda g: g * np.float64(1.0), grads)
        return loss, grads

    jx = jc.trace_round(opt, jc.toy_params(K), 3, x64=True,
                        grads_fn=leaky_grads)
    out = jc.check_no_f64(jx)
    assert out and "float64" in out[0]
    # without x64 the leak is invisible — that's why the checker retraces
    jx32 = jc.trace_round(opt, jc.toy_params(K), 3, grads_fn=leaky_grads)
    assert jc.check_no_f64(jx32) == []


def test_catches_wrong_scan_length():
    opt = _pd(p=3)
    jx = jc.trace_round(opt, jc.toy_params(K), 3)
    out = jc.check_round_scan(jx, 5)
    assert out and "p=5" in out[0]


def test_catches_collective_in_dense_round():
    """A dense-backend round that sneaks in a psum is flagged."""
    def bad_round(x):
        return jax.lax.psum(x, "i")

    jx = jax.make_jaxpr(
        lambda x: jax.vmap(bad_round, axis_name="i")(x))(
            jnp.zeros((4, 8), jnp.float32))
    out = jc.check_dense_no_collectives(jx)
    assert out and "psum" in out[0]


def test_catches_missing_schedule_switch():
    sched = make_schedule("one_peer_exp", (K,))     # period 3
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=2), DenseComm(sched))
    jx = jc.trace_round(opt, jc.toy_params(K), 2)
    # dense backend indexes stacked W — no lax.switch, so asking for one
    # with period > 2 must fail
    out = jc.check_schedule_switch(jx, 6)
    assert out and "6 branches" in out[0]


def test_kernel_flatten_once_negative():
    """A per-step flatten (tree riding the carry) fails the flatten-once
    check."""
    from repro.kernels import ops as kops
    opt = _pd(p=2)
    params = jc.toy_params(K)
    plan = kops.KernelPlan.for_tree(params, worker_dim=True)
    # tree-form round: the carry holds leaf trees, not the plan matrix
    jx = jc.trace_round(opt, params, 2, kernel=False)
    out = jc.check_kernel_flatten_once(jx, plan, 2)
    assert out and "flatten-once" in out[0]
    # kernel round passes
    jxk = jc.trace_round(opt, params, 2, kernel=True)
    assert jc.check_kernel_flatten_once(jxk, plan, 2) == []


def test_require_raises():
    with pytest.raises(jc.ContractViolation) as ei:
        jc.require(["a", "b"])
    assert ei.value.violations == ["a", "b"]
    jc.require([])   # no-op
