"""Round engine: fused ``opt.round`` ≡ p sequential ``opt.step`` calls.

Fast tier covers the DenseComm simulation backend (in-process); the
ShardedComm production backend (ppermute gossip under shard_map) runs in a
slow-marked subprocess with 8 forced host devices, comparing
``TrainPack.train_round`` against p sequential ``TrainPack.train_step``.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPDSGDM, CPDSGDMConfig, MTDSGDm, MTDSGDMConfig,
                        PDSGDM, PDSGDMConfig, QGDSGDm, QGDSGDMConfig,
                        SignCompressor)
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.train.trainer import SimTrainer

K, D, P = 8, 16, 4


def _loss_fn(params, batch):
    return 0.5 * jnp.sum((params["w"] - batch) ** 2), {}


def _batch(t):
    return jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(7), t), (K, D))


def _params():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (K, D))}


def _make_opt(name):
    if name == "pd_sgdm":
        return PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=P),
                      DenseComm(ring(K)))
    if name == "mt_dsgdm":
        return MTDSGDm(MTDSGDMConfig(eta=0.05, mu=0.9, p=P),
                       DenseComm(ring(K)))
    if name == "mt_dsgdm_sign":
        return MTDSGDm(MTDSGDMConfig(eta=0.05, mu=0.9, p=P),
                       DenseComm(ring(K)), SignCompressor(block=8))
    if name == "qg_dsgdm":
        return QGDSGDm(QGDSGDMConfig(eta=0.05, mu=0.9, p=P),
                       DenseComm(ring(K)))
    return CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=P, gamma=0.4),
                   DenseComm(ring(K)), SignCompressor(block=8))


_OPTIMIZERS = ["pd_sgdm", "cpd_sgdm", "mt_dsgdm", "mt_dsgdm_sign",
               "qg_dsgdm"]


@pytest.mark.parametrize("name", _OPTIMIZERS)
def test_round_equals_p_steps_dense(name):
    """opt.round == p × opt.step starting at a round boundary (DenseComm)."""
    opt = _make_opt(name)
    grad = jax.vmap(jax.value_and_grad(lambda pp, b: _loss_fn(pp, b)[0]))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    batches = [_batch(t) for t in range(P)]

    params = _params()
    state = opt.init(params)
    stepj = jax.jit(lambda s, pp, b: opt.step(s, pp, grad(pp, b)[1]))
    for b in batches:
        params, state = stepj(state, params, b)

    params2 = _params()
    state2 = opt.init(params2)
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    params2, state2, losses = roundj(state2, params2, jnp.stack(batches))

    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(params2["w"]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state["m"]["w"]),
                               np.asarray(state2["m"]["w"]),
                               rtol=1e-6, atol=1e-6)
    assert int(state2["step"]) == P
    # auxiliary per-element state (CPD's x̂, MT's c/ĝ_prev, QG's xprev)
    for k in ("xhat", "c", "g_prev", "xprev"):
        if k in state:
            np.testing.assert_allclose(np.asarray(state[k]["w"]),
                                       np.asarray(state2[k]["w"]),
                                       rtol=1e-6, atol=1e-6)
    assert losses.shape == (P,)


@pytest.mark.parametrize("name", _OPTIMIZERS)
def test_sim_trainer_matches_per_step_driver(name):
    """SimTrainer (block-scanned rounds + fused tail) reproduces the
    per-step reference loop exactly, including the logged History."""
    steps, log_every = 10, 3          # 2 full rounds + a 2-step tail
    opt = _make_opt(name)
    grad = jax.vmap(jax.value_and_grad(lambda pp, b: _loss_fn(pp, b)[0]))

    params = _params()
    state = opt.init(params)
    stepj = jax.jit(lambda s, pp, b: (*opt.step(s, pp, grad(pp, b)[1]),
                                      grad(pp, b)[0].mean()))
    ref_losses = []
    for t in range(steps):
        params, state, loss = stepj(state, params, _batch(t))
        ref_losses.append(float(loss))

    trainer = SimTrainer(_loss_fn, opt)
    params2, state2, hist = trainer.train(_params(), _batch, steps,
                                          log_every=log_every)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(params2["w"]),
                               rtol=1e-6, atol=1e-6)
    want = [t for t in range(steps)
            if t % log_every == 0 or t == steps - 1]
    assert hist.steps == want
    for t, lv in zip(hist.steps, hist.loss):
        assert lv == pytest.approx(ref_losses[t], rel=1e-5), t
    # comm accounting: one round per p steps completed
    per_round = trainer.bytes_per_round(params2)
    for t, mb in zip(hist.steps, hist.comm_mb):
        assert mb == pytest.approx(((t + 1) // P) * per_round / 2 ** 20)


_SCRIPT_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    for opt_name in ["pd_sgdm", "cpd_sgdm", "mt_dsgdm", "qg_dsgdm"]:
        run = RunCfg(model=mcfg,
                     parallel=ParallelCfg(profile="A", remat="none"),
                     optim=OptimCfg(name=opt_name, eta=0.05, mu=0.9, p=3,
                                    weight_decay=1e-4))
        mesh = make_debug_mesh(4, 2)
        pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
        K = pack.layout.n_workers
        p = run.optim.p
        batches = [train_batch_arrays(mcfg, K, 2, 16,
                   jax.random.fold_in(jax.random.PRNGKey(1), t))
                   for t in range(p)]

        params, state = pack.init_fn(jax.random.PRNGKey(0))
        for b in batches:
            params, state, _ = pack.train_step(params, state, b)
        seq = jax.tree_util.tree_map(np.asarray, (params, state))

        params2, state2 = pack.init_fn(jax.random.PRNGKey(0))
        rb = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)
        params2, state2, losses = pack.train_round(params2, state2, rb)
        fused = jax.tree_util.tree_map(np.asarray, (params2, state2))

        for a, b in zip(jax.tree_util.tree_leaves(seq),
                        jax.tree_util.tree_leaves(fused)):
            np.testing.assert_allclose(a, b, rtol=2e-6, atol=2e-6)
        assert losses.shape == (p,)
        print("ROUND_EQ_OK", opt_name)
""")


def _run(script, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_round_equals_p_steps_sharded():
    """TrainPack.train_round == p × TrainPack.train_step on the mesh, for
    both the full-precision and the packed-sign gossip paths."""
    out = _run(_SCRIPT_SHARDED)
    assert "ROUND_EQ_OK pd_sgdm" in out
    assert "ROUND_EQ_OK cpd_sgdm" in out
    assert "ROUND_EQ_OK mt_dsgdm" in out
    assert "ROUND_EQ_OK qg_dsgdm" in out
