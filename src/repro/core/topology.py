"""Gossip topologies, mixing matrices, and time-varying schedules.

A topology yields a doubly-stochastic mixing matrix ``W`` over K workers
(paper §3.2, Assumption 1: symmetric, ``W 1 = 1``, ``1ᵀ W = 1ᵀ``); the
spectral gap ``ρ = 1 - |λ₂|`` controls the topology term in Theorems 1/2.

Besides the dense matrix (used by the single-process simulation backend and
by the tests), each topology exposes its *neighbour structure* — weighted
circulant shifts (``shifts``) and, for non-circulant graphs such as random
matchings, explicit per-axis permutations (``perms``) — which the sharded
backend turns into ``jax.lax.ppermute`` schedules.

:class:`TopologySchedule` generalizes a single static graph to a periodic
sequence ``W_1, …, W_T`` applied round-robin: round ``r`` gossips with
``W_{(r mod T)+1}``.  Per-round matrices only need to be doubly stochastic
(one-peer exponential rounds are asymmetric); what matters for convergence
is the mixing of the *cycle product* ``W_T ⋯ W_1``, exposed as
``cycle_rho = 1 - ‖W_T ⋯ W_1 − (1/K)11ᵀ‖₂``.  The one-peer exponential
schedule reaches ``cycle_rho = 1`` (exact averaging every cycle) at degree
1 per round when K is a power of two — the same bytes-on-wire as a ring
round but hypercube-quality mixing over the cycle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "TopologySchedule",
    "MembershipSchedule",
    "ring",
    "torus",
    "complete",
    "exponential",
    "disconnected",
    "hierarchical",
    "hierarchical_schedule",
    "hierarchical_inter_shifts",
    "hierarchical_self_weight",
    "spectral_gap",
    "mixing_gap",
    "cycle_spectral_gap",
    "is_doubly_stochastic",
    "make_topology",
    "make_schedule",
    "static_schedule",
    "one_peer_exponential_schedule",
    "alternating_axes_schedule",
    "random_matching_schedule",
    "full_membership",
    "membership_from_events",
    "masked_matrix",
    "active_edge_count",
]


def is_doubly_stochastic(W: np.ndarray, atol: float = 1e-8,
                         require_symmetric: bool = True) -> bool:
    """Check Assumption 1: rows/cols sum to one, entries in [0,1]; symmetry
    is required for static topologies but waived for the per-round matrices
    of time-varying schedules (one-peer exponential rounds are directed)."""
    W = np.asarray(W, dtype=np.float64)
    if W.ndim != 2 or W.shape[0] != W.shape[1]:
        return False
    ones = np.ones(W.shape[0])
    return (
        (not require_symmetric or np.allclose(W, W.T, atol=atol))
        and np.allclose(W @ ones, ones, atol=atol)
        and np.allclose(ones @ W, ones, atol=atol)
        and bool(np.all(W >= -atol))
        and bool(np.all(W <= 1 + atol))
    )


def spectral_gap(W: np.ndarray) -> float:
    """ρ = 1 - |λ₂|  (Lemma 1).  ρ ∈ (0, 1] for connected non-bipartite W."""
    W = np.asarray(W, dtype=np.float64)
    eig = np.sort(np.abs(np.linalg.eigvalsh(W)))[::-1]
    if len(eig) == 1:
        return 1.0
    return float(1.0 - eig[1])


def mixing_gap(W: np.ndarray) -> float:
    """Norm-based gap ``1 - ‖W − (1/K)11ᵀ‖₂`` — equals ``1 - |λ₂|`` for
    symmetric W, and stays meaningful for asymmetric doubly-stochastic W
    (per-round matrices of one-peer schedules) and for cycle products."""
    W = np.asarray(W, dtype=np.float64)
    K = W.shape[0]
    if K == 1:
        return 1.0
    J = np.ones((K, K)) / K
    return float(1.0 - np.linalg.norm(W - J, 2))


def cycle_spectral_gap(Ws: Sequence[np.ndarray]) -> float:
    """Effective spectral gap of one schedule cycle: ``1 - ‖W_T ⋯ W_1 − J‖₂``
    where round 1 is applied first (``x ← W x`` each round)."""
    Ws = [np.asarray(W, dtype=np.float64) for W in Ws]
    P = np.eye(Ws[0].shape[0])
    for W in Ws:
        P = W @ P
    return mixing_gap(P)


# (TopologySchedule.cycle_rho goes through cycle_product + mixing_gap; this
# free function serves callers holding raw matrices, e.g. the tests.)


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph over ``n_workers`` with doubly-stochastic weights.

    Attributes:
      name: identifier ("ring", "torus", ...).
      W: dense (K, K) mixing matrix, numpy float64.
      shifts: for shift-structured (circulant / Kronecker-of-circulant)
        topologies, the list of (axis, shift, weight) triples describing the
        neighbour exchange pattern used by the ppermute backend.  ``axis``
        indexes into ``axis_sizes``.  ``shift`` of 0 denotes the self weight.
      axis_sizes: worker-grid shape whose product is K (1-d for ring, 2-d
        for torus). The sharded backend maps these onto mesh axes.
      perms: non-circulant exchanges as (axis, recv_from, weight) triples,
        where ``recv_from`` is a tuple of length ``axis_sizes[axis]`` and
        position ``i`` receives the value held by ``recv_from[i]``.  Used by
        random-matching rounds; lowered to one ``ppermute`` each.
      symmetric: whether W is symmetric (Assumption 1).  Per-round matrices
        of time-varying schedules may be asymmetric (one-peer exponential);
        only the cycle product's mixing then matters.
    """

    name: str
    W: np.ndarray
    shifts: tuple  # ((axis, shift, weight), ...)
    axis_sizes: tuple
    perms: tuple = ()  # ((axis, recv_from_tuple, weight), ...)
    symmetric: bool = True

    @property
    def n_workers(self) -> int:
        return int(self.W.shape[0])

    @property
    def rho(self) -> float:
        return spectral_gap(self.W) if self.symmetric else mixing_gap(self.W)

    @property
    def degree(self) -> int:
        """Number of non-self exchanges per worker per round — the
        bytes-on-wire driver.  Each perm entry is one ppermute payload."""
        return (sum(1 for (_, s, _) in self.shifts if s != 0)
                + len(self.perms))

    def self_weight(self) -> float:
        return float(self.W[0, 0])

    def structure_matrix(self) -> np.ndarray:
        """Dense W rebuilt from the shift/perm structure — i.e. what the
        ppermute backend actually executes (sequential per-axis application
        of the weighted exchanges).  Tests cross-validate this against the
        constructor-built ``W`` to catch structure/matrix drift (e.g. the
        ``exponential()`` ±K/2 alias at K a power of two)."""
        grid = self.axis_sizes
        K = self.n_workers
        axes = sorted({ax for (ax, _, _) in self.shifts}
                      | {ax for (ax, _, _) in self.perms})
        W = np.eye(K)
        for ax in axes:
            A = np.zeros((K, K))
            n = grid[ax]
            for (a, sh, w) in self.shifts:
                if a != ax:
                    continue
                for k in range(K):
                    idx = list(np.unravel_index(k, grid))
                    idx[ax] = (idx[ax] + sh) % n
                    A[k, np.ravel_multi_index(idx, grid)] += w
            for (a, recv, w) in self.perms:
                if a != ax:
                    continue
                for k in range(K):
                    idx = list(np.unravel_index(k, grid))
                    idx[ax] = recv[idx[ax]]
                    A[k, np.ravel_multi_index(idx, grid)] += w
            W = A @ W
        return W

    def validate(self) -> None:
        if not is_doubly_stochastic(self.W,
                                    require_symmetric=self.symmetric):
            raise ValueError(f"topology {self.name}: W is not doubly stochastic")
        if int(np.prod(self.axis_sizes)) != self.n_workers:
            raise ValueError(f"topology {self.name}: axis_sizes {self.axis_sizes} != K")
        for (ax, recv, _w) in self.perms:
            n = self.axis_sizes[ax]
            if sorted(recv) != list(range(n)):
                raise ValueError(
                    f"topology {self.name}: perm {recv} on axis {ax} is not "
                    f"a permutation of range({n})")


def _circulant(K: int, offsets_weights: dict) -> np.ndarray:
    W = np.zeros((K, K), dtype=np.float64)
    for off, w in offsets_weights.items():
        for i in range(K):
            W[i, (i + off) % K] += w
    return W


def ring(K: int, self_weight: float | None = None) -> Topology:
    """Ring of K workers (the paper's experimental topology, K=8).

    Default weights: 1/3 self, 1/3 each neighbour (Metropolis for a cycle);
    for K=2 the ring degenerates to a pair-average; K=1 is identity.
    """
    if K == 1:
        return Topology("ring", np.ones((1, 1)), ((0, 0, 1.0),), (1,))
    if K == 2:
        W = np.array([[0.5, 0.5], [0.5, 0.5]])
        return Topology("ring", W, ((0, 0, 0.5), (0, 1, 0.5)), (2,))
    ws = 1.0 / 3.0 if self_weight is None else float(self_weight)
    wn = (1.0 - ws) / 2.0
    W = _circulant(K, {0: ws, 1: wn, -1: wn})
    shifts = ((0, 0, ws), (0, 1, wn), (0, -1, wn))
    return Topology("ring", W, shifts, (K,))


def torus(shape: Sequence[int], self_weight: float | None = None) -> Topology:
    """Kronecker torus W = W_ring(shape[0]) ⊗ … — hierarchical pod×ring mixing.

    Applied by the sharded backend as sequential per-axis ring mixings (the
    Kronecker structure factorizes); ρ(W) = 1 - max_i |λ₂(W_i)| ... computed
    exactly from the dense product here.
    """
    shape = tuple(int(s) for s in shape)
    mats = [ring(s, self_weight).W for s in shape]
    W = mats[0]
    for M in mats[1:]:
        W = np.kron(W, M)
    shifts = []
    for ax, s in enumerate(shape):
        sub = ring(s, self_weight)
        for (_, sh, w) in sub.shifts:
            shifts.append((ax, sh, w))
    return Topology("torus", W, tuple(shifts), shape)


def complete(K: int) -> Topology:
    """Fully connected: W = (1/K) 11ᵀ — gossip == exact global average.

    Used by tests to show PD-SGDM(p=1, complete) ≡ centralized momentum SGD.
    """
    W = np.full((K, K), 1.0 / K)
    shifts = tuple((0, s, 1.0 / K) for s in range(K))
    return Topology("complete", W, shifts, (K,))


def exponential(K: int) -> Topology:
    """One-peer-per-power-of-two expander (hypercube-like), good ρ at low degree."""
    offs = [0]
    s = 1
    while s < K:
        offs.append(s)
        offs.append(-s)
        s *= 2
    w = 1.0 / len(offs)
    W = _circulant(K, {o: w for o in offs})
    # symmetrize (offsets come in ± pairs except when 2s == K aliases)
    W = (W + W.T) / 2.0
    shifts = tuple((0, o, w) for o in offs)
    top = Topology("exponential", W, shifts, (K,))
    return top


def disconnected(K: int) -> Topology:
    """W = I: no communication at all (lower bound / ablation)."""
    return Topology("disconnected", np.eye(K), ((0, 0, 1.0),), (K,))


def _hier_compose(sub: Topology, n_nodes: int, node_size: int) -> Topology:
    """Lift an inter-node graph ``sub`` over ``n_nodes`` to the two-level
    worker grid ``(n_nodes, node_size)``: W = W_inter ⊗ W_intra with
    W_intra = (1/m)11ᵀ (exact in-node average)."""
    m = int(node_size)
    C = np.full((m, m), 1.0 / m)
    W = np.kron(sub.W, C)
    shifts = (tuple((0, sh, w) for (_, sh, w) in sub.shifts)
              + tuple((1, s, 1.0 / m) for s in range(m)))
    return Topology("hierarchical", W, shifts, (int(n_nodes), m),
                    symmetric=bool(np.allclose(W, W.T)))


def hierarchical(n_nodes: int, node_size: int, *,
                 inter: str = "ring") -> Topology:
    """Two-level gossip graph: exact intra-node average × inter-node graph.

    Workers live on the grid ``(n_nodes, node_size)``; each round averages
    exactly inside every node (the complete graph on the fast intra links)
    and gossips between nodes over ``inter`` ("ring" / "exponential" /
    "complete") on the slow links.  The mixing matrix factors as::

        W_hier = W_intra · W_inter = (I ⊗ (1/m)11ᵀ)(W_inter ⊗ I)
               = W_inter ⊗ (1/m)11ᵀ

    (axis 1 is applied after axis 0 by ``structure_matrix``, matching the
    sharded execution order: average in-node first, then only node leaders
    ship the slow-link wire).  ρ(W_hier) = ρ(W_inter over nodes): the intra
    factor collapses each node to its mean, so mixing quality is set
    entirely by the inter graph while inter-node bytes drop by the
    node-size factor (only leaders ship, amortized over m workers).
    """
    n, m = int(n_nodes), int(node_size)
    if n < 1 or m < 1:
        raise ValueError(
            f"hierarchical: need n_nodes ≥ 1 and node_size ≥ 1, got "
            f"({n_nodes}, {node_size})")
    sub = make_topology(inter, (n,))
    if sub.perms:
        raise ValueError(
            f"hierarchical: inter graph {inter!r} must be shift-structured")
    return _hier_compose(sub, n, m)


def hierarchical_inter_shifts(top: Topology) -> tuple:
    """Non-self inter-node exchanges of a hierarchical topology, as
    ``(shift, weight)`` pairs on the node axis (axis 0)."""
    n = int(top.axis_sizes[0])
    return tuple((sh % n, w) for (ax, sh, w) in top.shifts
                 if ax == 0 and sh % n != 0)


def hierarchical_self_weight(top: Topology) -> float:
    """Inter-level self weight of a hierarchical topology (the mass each
    node keeps of its own post-average value)."""
    n = int(top.axis_sizes[0])
    return float(sum(w for (ax, sh, w) in top.shifts
                     if ax == 0 and sh % n == 0))


def make_topology(name: str, worker_grid: Sequence[int]) -> Topology:
    """Build topology by name for a worker grid (product = K)."""
    worker_grid = tuple(int(g) for g in worker_grid)
    K = int(np.prod(worker_grid)) if worker_grid else 1
    if name == "ring":
        return ring(K)
    if name == "torus":
        grid = worker_grid if len(worker_grid) > 1 else (K,)
        return torus(grid)
    if name == "complete":
        return complete(K)
    if name == "exponential":
        return exponential(K)
    if name == "disconnected":
        return disconnected(K)
    if name == "hierarchical":
        if len(worker_grid) != 2:
            raise ValueError(
                "hierarchical topology needs a (n_nodes, node_size) worker "
                f"grid; got {worker_grid}")
        return hierarchical(worker_grid[0], worker_grid[1])
    raise ValueError(f"unknown topology {name!r}")


# ------------------------------------------------------------------ schedules
@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A periodic sequence of topologies: round ``r`` uses ``at(r)``.

    All rounds must share ``n_workers`` and ``axis_sizes`` (the worker grid
    is fixed; only the exchange pattern varies).  The quantity that governs
    convergence is :attr:`cycle_rho`, the effective spectral gap of the
    cycle product ``W_T ⋯ W_1``.

    The round index is *derived from the optimizer's step counter*
    (``r = step // p − 1`` at gossip time), so checkpoint/resume restores
    the schedule phase for free — no extra cursor to persist.
    """

    name: str
    topologies: tuple  # (Topology, ...), length T ≥ 1

    def __post_init__(self):
        if not self.topologies:
            raise ValueError(f"schedule {self.name}: needs ≥ 1 topology")

    @property
    def period(self) -> int:
        return len(self.topologies)

    @property
    def n_workers(self) -> int:
        return self.topologies[0].n_workers

    @property
    def axis_sizes(self) -> tuple:
        return self.topologies[0].axis_sizes

    def at(self, r: int) -> Topology:
        """Topology of round ``r`` (0-based, wraps modulo the period)."""
        return self.topologies[int(r) % self.period]

    def stacked_W(self) -> np.ndarray:
        """(T, K, K) weight tensor — what DenseComm indexes per round."""
        return np.stack([t.W for t in self.topologies])

    def cycle_product(self) -> np.ndarray:
        """``W_T ⋯ W_1`` (round 0 applied first, as in ``x ← W x``)."""
        P = np.eye(self.n_workers)
        for t in self.topologies:
            P = t.W @ P
        return P

    @property
    def cycle_rho(self) -> float:
        """Effective spectral gap of one full cycle, ``1 - ‖∏W − J‖₂``."""
        return mixing_gap(self.cycle_product())

    def degrees(self) -> tuple:
        """Per-round non-self exchange count (comm accounting varies by round)."""
        return tuple(t.degree for t in self.topologies)

    def validate(self) -> None:
        K, grid = self.n_workers, self.axis_sizes
        for t in self.topologies:
            t.validate()
            if t.n_workers != K or t.axis_sizes != grid:
                raise ValueError(
                    f"schedule {self.name}: round {t.name} grid "
                    f"{t.axis_sizes} != {grid}")


def static_schedule(top: Topology) -> TopologySchedule:
    """Wrap a single topology as a period-1 schedule."""
    return TopologySchedule(f"static_{top.name}", (top,))


def one_peer_exponential_schedule(K: int,
                                  self_weight: float = 0.5) -> TopologySchedule:
    """One-peer exponential: round ``j`` exchanges only with offset ``2^j``.

    Degree 1 per round (vs 2 for a ring), per-round W asymmetric
    (directed send/recv), yet the ⌈log₂K⌉-round cycle product equals the
    exact global average when K is a power of two (``cycle_rho = 1``) —
    hypercube-quality mixing at ring-round bytes.  See "From promise to
    practice" (2024) / Ying et al. (2021).
    """
    if K == 1:
        return static_schedule(disconnected(1))
    ws = float(self_weight)
    T = max(1, math.ceil(math.log2(K)))
    tops = []
    for j in range(T):
        off = 2 ** j
        W = np.zeros((K, K))
        for i in range(K):
            W[i, i] += ws
            W[i, (i + off) % K] += 1.0 - ws
        tops.append(Topology(
            f"one_peer_exp[{off}]", W,
            ((0, 0, ws), (0, off, 1.0 - ws)), (K,),
            symmetric=bool(np.allclose(W, W.T))))
    return TopologySchedule("one_peer_exp", tuple(tops))


def hierarchical_schedule(n_nodes: int, node_size: int,
                          self_weight: float = 0.5) -> TopologySchedule:
    """Two-level schedule: one-peer exponential *between nodes*, exact
    average inside every node, every round.

    Round ``j`` lifts the one-peer exponential round ``R_j`` over nodes to
    ``R_j ⊗ (1/m)11ᵀ``, so each round ships exactly one inter-node wire per
    node (degree 1 on the slow links) while the cycle product
    ``(∏R_j) ⊗ (1/m)11ᵀ`` reaches exact averaging when ``n_nodes`` is a
    power of two (``cycle_rho = 1``) — hypercube mixing at leader bytes.
    """
    n, m = int(n_nodes), int(node_size)
    if n == 1:
        return static_schedule(hierarchical(1, m))
    base = one_peer_exponential_schedule(n, self_weight)
    tops = tuple(_hier_compose(t, n, m) for t in base.topologies)
    return TopologySchedule("hier_one_peer", tops)


def alternating_axes_schedule(shape: Sequence[int],
                              self_weight: float | None = None
                              ) -> TopologySchedule:
    """Alternate ring mixing along one torus axis per round.

    Round ``ax`` applies ``I ⊗ … ⊗ W_ring(shape[ax]) ⊗ … ⊗ I``; the cycle
    product over all axes equals the full Kronecker torus W at half (2-d)
    the per-round bytes.  Matches the pod×ring layout: even rounds gossip
    inside the pod, odd rounds across pods.
    """
    shape = tuple(int(s) for s in shape)
    tops = []
    for ax in range(len(shape)):
        sub = ring(shape[ax], self_weight)
        mats = [sub.W if a == ax else np.eye(s)
                for a, s in enumerate(shape)]
        W = mats[0]
        for M in mats[1:]:
            W = np.kron(W, M)
        shifts = tuple((ax, sh, w) for (_, sh, w) in sub.shifts)
        tops.append(Topology(f"axis{ax}_ring", W, shifts, shape))
    return TopologySchedule("alt_axes", tuple(tops))


def random_matching_schedule(K: int, rounds: int, seed: int = 0,
                             self_weight: float = 0.5) -> TopologySchedule:
    """Seeded random perfect matchings: each round pairs workers at random
    and pair-averages (``W = ws·I + (1−ws)·M``, M a symmetric matching).
    With odd K one worker idles per round.  Deterministic in ``seed`` so
    dense and sharded backends (and checkpoint resume) see identical
    matrices."""
    if rounds < 1:
        raise ValueError("random_matching_schedule: rounds must be ≥ 1")
    rng = np.random.default_rng(seed)
    ws = float(self_weight)
    tops = []
    for r in range(rounds):
        order = rng.permutation(K)
        recv = np.arange(K)
        for a, b in zip(order[0::2], order[1::2]):
            recv[a], recv[b] = b, a
        W = ws * np.eye(K)
        for i in range(K):
            W[i, recv[i]] += 1.0 - ws
        tops.append(Topology(
            f"matching[{r}]", W, ((0, 0, ws),), (K,),
            perms=((0, tuple(int(x) for x in recv), 1.0 - ws),)))
    return TopologySchedule("random_matching", tuple(tops))


def make_schedule(name: str, worker_grid: Sequence[int], *,
                  base_topology: str = "ring", rounds: int = 0,
                  seed: int = 0) -> TopologySchedule:
    """Build a topology schedule by name for a worker grid.

    ``"static"`` wraps ``base_topology``; ``rounds``/``seed`` parameterize
    the random-matching schedule (rounds=0 derives ⌈log₂K⌉).
    """
    grid = tuple(int(g) for g in worker_grid)
    K = int(np.prod(grid)) if grid else 1
    key = name.lower().replace("-", "_")
    if key == "static":
        return static_schedule(make_topology(base_topology, grid))
    if key in ("one_peer_exp", "one_peer_exponential"):
        if len(grid) > 1:
            raise ValueError(
                "one_peer_exp needs a single worker axis; got grid "
                f"{grid} (use alt_axes for multi-axis grids)")
        return one_peer_exponential_schedule(K)
    if key in ("alt_axes", "alternating_axes"):
        return alternating_axes_schedule(grid if len(grid) > 1 else (K,))
    if key in ("hier_one_peer", "hierarchical_one_peer"):
        if len(grid) != 2:
            raise ValueError(
                "hier_one_peer needs a (n_nodes, node_size) worker grid; "
                f"got {grid}")
        return hierarchical_schedule(grid[0], grid[1])
    if key in ("random_matching", "random_match"):
        if len(grid) > 1:
            raise ValueError(
                "random_matching needs a single worker axis; got grid "
                f"{grid}")
        T = rounds or max(2, math.ceil(math.log2(max(K, 2))))
        return random_matching_schedule(K, T, seed=seed)
    raise ValueError(f"unknown topology schedule {name!r}")


# --------------------------------------------------------- elastic membership
@dataclasses.dataclass(frozen=True)
class MembershipSchedule:
    """Per-round worker liveness for elastic membership, period ``M``.

    Two (M, K) bool masks, indexed ``[r % M, k]``:

    * ``live`` — worker k still holds state in round r.  A dead worker has
      left the fleet: its column and row are masked out of the round's
      mixing matrix and none of its edges ship bytes.
    * ``active`` — worker k participates in round r's *exchange*.
      ``active ⊆ live``: a live-but-inactive worker is a **straggler** —
      it keeps training locally but its exchange is skipped that round
      (effective self-weight 1, the masked row is ``e_k``).

    Dead and straggling workers are indistinguishable to the mixing matrix
    (both are excluded via ``active``); ``live`` additionally drives the
    chaos harness's metrics (loss/consensus over live workers only) and
    revival warm-starts.  Like :class:`TopologySchedule`, the round index
    is derived from the optimizer's checkpointed step counter, so resume
    restores the membership phase with no extra persisted cursor.
    """

    name: str
    live: np.ndarray      # (M, K) bool
    active: np.ndarray    # (M, K) bool, active ⊆ live

    @property
    def period(self) -> int:
        return int(self.live.shape[0])

    @property
    def n_workers(self) -> int:
        return int(self.live.shape[1])

    def live_at(self, r: int) -> np.ndarray:
        """(K,) bool — workers holding state in round ``r``."""
        return np.asarray(self.live[int(r) % self.period], dtype=bool)

    def active_at(self, r: int) -> np.ndarray:
        """(K,) bool — workers exchanging in round ``r``."""
        return np.asarray(self.active[int(r) % self.period], dtype=bool)

    def all_active(self) -> bool:
        return bool(np.all(self.active))

    def validate(self) -> None:
        live = np.asarray(self.live)
        active = np.asarray(self.active)
        if live.shape != active.shape or live.ndim != 2:
            raise ValueError(
                f"membership {self.name}: live {live.shape} and active "
                f"{active.shape} must both be (rounds, K)")
        if live.dtype != np.bool_ or active.dtype != np.bool_:
            raise ValueError(f"membership {self.name}: masks must be bool")
        if np.any(active & ~live):
            raise ValueError(
                f"membership {self.name}: active ⊄ live (a dead worker "
                "cannot exchange)")
        if not np.all(live.any(axis=1)):
            raise ValueError(
                f"membership {self.name}: some round has no live worker "
                "(nobody left to warm-start from)")


def full_membership(K: int, name: str = "full") -> MembershipSchedule:
    """Everyone live and active every round (period 1) — the degenerate
    schedule under which every masked quantity equals its unmasked form."""
    ones = np.ones((1, K), dtype=bool)
    return MembershipSchedule(name, ones, ones.copy())


def membership_from_events(K: int, n_rounds: int,
                           events: Sequence) -> MembershipSchedule:
    """Build a period-``n_rounds`` membership from a fault script.

    ``events`` is a sequence of ``(round, kind, worker)`` triples (or any
    objects with those attributes), applied in round order:

    * ``"kill"``     — worker leaves the fleet at that round (dead from
      that round on, until revived);
    * ``"revive"``   — worker rejoins at that round (the harness
      warm-starts its state from a live donor *before* the round runs);
    * ``"straggle"`` — worker is slow for that one round only: it stays
      live (and keeps computing) but skips the exchange.

    Workers start live; masks are deterministic in the event list, so the
    dense and sharded backends (and checkpoint resume) see identical
    membership.
    """
    def _fields(e):
        if hasattr(e, "round"):
            return int(e.round), str(e.kind), int(e.worker)
        r, kind, w = e
        return int(r), str(kind), int(w)

    by_round: dict = {}
    for e in events:
        r, kind, w = _fields(e)
        if kind not in ("kill", "revive", "straggle"):
            raise ValueError(f"unknown membership event kind {kind!r}")
        if not (0 <= w < K) or not (0 <= r < n_rounds):
            raise ValueError(f"membership event out of range: {(r, kind, w)}")
        by_round.setdefault(r, []).append((kind, w))

    live = np.ones((n_rounds, K), dtype=bool)
    straggle = np.zeros((n_rounds, K), dtype=bool)
    alive = np.ones(K, dtype=bool)
    for r in range(n_rounds):
        for (kind, w) in by_round.get(r, []):
            if kind == "kill":
                alive[w] = False
            elif kind == "revive":
                alive[w] = True
            else:
                straggle[r, w] = True
        live[r] = alive
    ms = MembershipSchedule("events", live, live & ~straggle)
    ms.validate()
    return ms


def masked_matrix(top: Topology, active) -> np.ndarray:
    """The round's effective mixing matrix with only ``active`` workers
    exchanging — the elastic-membership renormalization rule.

    Mirrors :meth:`Topology.structure_matrix` (sequential per-axis
    application — what the ppermute backend executes), with each axis
    factor ``A`` masked per worker ``k``::

        A'_kj = A_kj          if k ≠ j and both k, j active
              = 0             if k ≠ j and either endpoint inactive
        A'_kk = 1 − Σ_{j≠k} A'_kj      (lost neighbour mass → self)

    Every row sums to 1 by construction (row-stochastic over live peers);
    an inactive worker's row is ``e_k`` (self-weight 1: its exchange is
    skipped) and its column is zero in every active row (nobody reads a
    dead worker).  For a symmetric base W the masked factor stays
    symmetric, so the matrix is doubly stochastic *over the active set* —
    the worker-mean over active workers is preserved, which is what keeps
    MT's tracking correction and QG's displacement average bounded under
    churn.  With all workers active this equals ``structure_matrix()``.
    """
    act = np.asarray(active, dtype=bool)
    K = top.n_workers
    if act.shape != (K,):
        raise ValueError(f"active mask shape {act.shape} != ({K},)")
    grid = top.axis_sizes
    axes = sorted({ax for (ax, _, _) in top.shifts}
                  | {ax for (ax, _, _) in top.perms})
    W = np.eye(K)
    for ax in axes:
        A = np.zeros((K, K))
        n = grid[ax]
        for (a, sh, w) in top.shifts:
            if a != ax or sh == 0:
                continue
            for k in range(K):
                idx = list(np.unravel_index(k, grid))
                idx[ax] = (idx[ax] + sh) % n
                j = int(np.ravel_multi_index(idx, grid))
                if j != k and act[k] and act[j]:
                    A[k, j] += w
        for (a, recv, w) in top.perms:
            if a != ax:
                continue
            for k in range(K):
                idx = list(np.unravel_index(k, grid))
                idx[ax] = recv[idx[ax]]
                j = int(np.ravel_multi_index(idx, grid))
                if j != k and act[k] and act[j]:
                    A[k, j] += w
        for k in range(K):
            A[k, k] = 1.0 - A[k].sum()
        W = A @ W
    return W


def active_edge_count(top: Topology, active) -> int:
    """Directed exchanges that actually ship in a round where only
    ``active`` workers participate: one per (receiver, source) pair with
    both endpoints active, per weighted shift / perm — the wire-byte
    multiplier (dead edges ship zero bytes).  With everyone active this
    equals ``K × degree``."""
    act = np.asarray(active, dtype=bool)
    K = top.n_workers
    grid = top.axis_sizes
    count = 0
    for (ax, sh, _w) in top.shifts:
        if sh == 0:
            continue
        n = grid[ax]
        for k in range(K):
            idx = list(np.unravel_index(k, grid))
            idx[ax] = (idx[ax] + sh) % n
            j = int(np.ravel_multi_index(idx, grid))
            if j != k and act[k] and act[j]:
                count += 1
    for (ax, recv, _w) in top.perms:
        for k in range(K):
            idx = list(np.unravel_index(k, grid))
            idx[ax] = recv[idx[ax]]
            j = int(np.ravel_multi_index(idx, grid))
            if j != k and act[k] and act[j]:
                count += 1
    return count
