"""Non-IID sweep: heterogeneity α × period p × optimizer.

The source paper's Assumption 4 bounds per-worker gradients uniformly —
Dirichlet-α class skew is exactly the regime that breaks it, and the
regime Momentum Tracking (MT-DSGDm) is built for.  This sweep makes the
heterogeneity claim machine-checkable: workers draw labels from fixed
Dirichlet(α) class distributions (small α = strongly non-IID), train
through the fused round engine, and are judged on the **global** loss of
the worker-averaged model over an IID evaluation stream — the quantity
per-worker drift actually hurts (each worker's *local* loss gets easier
as its data narrows, so local loss alone would reward drift).

Grid: α ∈ {IID, 1.0, 0.1} × p ∈ {1, 2, 4} × optimizer ∈
{d_sgd (D-PSGD, the momentum-free control), pd_sgdm, qg_dsgdm,
mt_dsgdm}, ring of 8.  The tracked correction *ages* between mixes: at
p ≥ 4 (η = 0.05, μ = 0.9) the per-worker disagreement of c amplifies
through the momentum recursion faster than the ring mixes it away and
synchronous MT diverges — the staleness Theorem 1 prices as p²G²/ρ²
hits the tracking variable quadratically.  The committed p = 4 rows
record that divergence on purpose, next to the fix: the
``mt_dsgdm_ov`` row reruns MT with ``overlap=True``, whose
staleness-refreshed tracking drips the (one-round-stale) correction
delta in p equal parts after each local step instead of freezing c for
the whole round — correction age is bounded by one step and MT survives
p = 4 (claim row ``noniid/claim_p4_overlap`` pins
``mt_overlap_survives_p4 = 1``; ``NONIID_PS`` / ``NONIID_ETA`` expose
the knobs to explore the edge).  Rows carry
``final_loss`` (global, averaged model), ``local_loss`` (the drifted
workers' own stream) and ``comm_mb`` (MT pays the 2-tensor (x, c) wire).
D-PSGD gossips every step regardless of p, so it appears once per α
(``noniid/d_sgd_a<α>``, no ``_p`` suffix).
The summary row ``noniid/claim_alpha0.1`` reports
``mt_minus_pd_best`` (min over p of MT − PD final loss at α = 0.1) and
``mt_le_pd`` ∈ {0, 1} — the committed baseline pins ``mt_le_pd = 1``.

Standalone runs write ``benchmarks/BENCH_noniid.json``; under
``python -m benchmarks.run noniid`` the rows land in the main
``BENCH_<tag>.json``.  ``NONIID_STEPS`` trims the grid for smoke runs.
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, stacked_resnet
from repro.core import make_optimizer
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.data.synthetic import ClassStreamCfg, class_batch
from repro.models.resnet import resnet20_loss
from repro.train.trainer import SimTrainer

K = 8
WIDTH = 4
STEPS = int(os.environ.get("NONIID_STEPS", "64"))
# 0.05: the largest grid-stable step for *all* four methods — at 0.1 the
# tracked global direction (effective step η/(1−μ)) diverges at p = 4
ETA = float(os.environ.get("NONIID_ETA", "0.05"))
ALPHAS = [None, 1.0, 0.1]
PS = [int(p) for p in os.environ.get("NONIID_PS", "1,2,4").split(",")]
OPTIMIZERS = ["d_sgd", "pd_sgdm", "qg_dsgdm", "mt_dsgdm", "mt_dsgdm_ov"]
# the staleness-refreshed MT row runs where synchronous MT diverges
OVERLAP_PS = [p for p in PS if p >= 4]


def _stacked_params():
    return stacked_resnet(K=K, width=WIDTH)


def _make_eval_fn():
    """Global loss of the (averaged, re-stacked) model on an IID stream
    over the *same task* (the class means are keyed on the seed, so the
    eval cfg must share it — only the label marginal and the samples
    differ): uniform labels, step offset 10k keeps the draws disjoint
    from every training stream."""
    eval_cfg = ClassStreamCfg(batch=32, n_workers=K, seed=0,
                              dirichlet_alpha=None)
    eval_batches = [class_batch(eval_cfg, 10_000 + i) for i in range(2)]
    vloss = jax.jit(jax.vmap(lambda p, b: resnet20_loss(p, b)[0]))

    def eval_fn(avg_params):
        return float(jnp.mean(jnp.stack(
            [vloss(avg_params, b).mean() for b in eval_batches])))

    return eval_fn


def _alpha_label(alpha):
    return "iid" if alpha is None else f"{alpha:g}"


def main():
    results = {}
    eval_fn = _make_eval_fn()
    for alpha in ALPHAS:
        cfg = ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=alpha)
        for p in PS:
            for name in OPTIMIZERS:
                if name == "d_sgd" and p != PS[0]:
                    continue     # D-PSGD gossips every step: p-independent
                overlap = name.endswith("_ov")
                if overlap and p not in OVERLAP_PS:
                    continue     # the refresh only matters where MT ages
                opt = make_optimizer(name[:-3] if overlap else name,
                                     DenseComm(ring(K)), eta=ETA,
                                     mu=0.9, p=p, weight_decay=1e-4,
                                     overlap=overlap)
                trainer = SimTrainer(resnet20_loss, opt)
                t0 = time.time()
                _, _, h = trainer.train(
                    _stacked_params(), lambda t: class_batch(cfg, t),
                    STEPS, log_every=max(STEPS - 1, 1), eval_fn=eval_fn)
                dt = time.time() - t0
                key = (alpha, p, name)
                results[key] = (h.eval_metric[-1], h.loss[-1],
                                h.comm_mb[-1])
                tag = ("" if name == "d_sgd" else f"_p{p}")
                csv_row(
                    f"noniid/{name}_a{_alpha_label(alpha)}{tag}",
                    dt / STEPS * 1e6,
                    f"final_loss={h.eval_metric[-1]:.4f};"
                    f"local_loss={h.loss[-1]:.4f};"
                    f"comm_mb={h.comm_mb[-1]:.2f}")

    # the machine-checkable heterogeneity claim, at the skew the ISSUE
    # names: MT final (global) loss ≤ PD-SGDM for at least one p
    diffs = {p: results[(0.1, p, "mt_dsgdm")][0]
             - results[(0.1, p, "pd_sgdm")][0] for p in PS}
    best_p = min(diffs, key=diffs.get)
    csv_row("noniid/claim_alpha0.1", 0.0,
            f"mt_minus_pd_best={diffs[best_p]:.4f};best_p={best_p};"
            f"mt_le_pd={int(diffs[best_p] <= 0.0)}")

    # the overlap rescue claim: at p = 4 synchronous MT's correction ages
    # into divergence (its local loss explodes); the staleness-refreshed
    # overlap run must stay bounded — bench_compare pins survives = 1
    if 4 in OVERLAP_PS:
        import math
        sync = results[(0.1, 4, "mt_dsgdm")]
        ov = results[(0.1, 4, "mt_dsgdm_ov")]
        survives = int(math.isfinite(ov[1]) and ov[1] < 10.0)
        csv_row("noniid/claim_p4_overlap", 0.0,
                f"mt_sync_local_p4={sync[1]:.4f};"
                f"mt_overlap_local_p4={ov[1]:.4f};"
                f"overlap_minus_sync_global={ov[0] - sync[0]:.4f};"
                f"mt_overlap_survives_p4={survives}")
    return results


def _write_json(results) -> str:
    from benchmarks.common import collected_rows
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_noniid.json")
    rows = [r for r in collected_rows() if r["name"].startswith("noniid/")]
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": ["noniid"],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "steps": STEPS,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = main()
    print(f"bench_json,0.0,path={os.path.relpath(_write_json(res))}")
