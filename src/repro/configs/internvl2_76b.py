"""internvl2-76b — InternVL2 (InternViT-6B + InternLM2-70B) [arXiv:2404.16821].

Language backbone: 80L, d_model 8192, 64 heads (GQA kv=8), d_ff 28672,
vocab 128256.  The InternViT vision encoder + MLP projector are a STUB per
the assignment: ``input_specs()`` provides precomputed patch embeddings
(n_patches=1024 prefix) at d_model; the LM that consumes them is fully
implemented.
"""
from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="internvl2-76b", arch_type="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128256,
        input_mode="vlm", n_patches=1024,   # ViT patch count  # lint: allow
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2404.16821",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="B"),
                  optim=OptimCfg())
