"""Hierarchical two-level gossip: topology factoring, dense round
semantics (incl. the bf16 inter wire), per-level byte accounting, the
composition guards, and (slow tier) dense ≡ sharded equivalence on 8
forced host devices."""
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseComm, HierarchicalComm, make_optimizer
from repro.core.gossip import hier_bytes_per_round
from repro.core.topology import (MembershipSchedule, hierarchical,
                                 hierarchical_inter_shifts,
                                 hierarchical_schedule,
                                 hierarchical_self_weight, make_topology,
                                 ring)
from repro.core.wire import IdentityCodec


# ---------------------------------------------------------------- topology

def test_w_is_kron_of_inter_and_intra_average():
    top = hierarchical(2, 4)
    C = np.full((4, 4), 0.25)
    np.testing.assert_allclose(top.W, np.kron(ring(2).W, C))
    assert top.name == "hierarchical"
    assert top.axis_sizes == (2, 4)
    np.testing.assert_allclose(top.W.sum(axis=1), 1.0)   # row-stochastic
    top.validate()


def test_structure_matrix_is_block_support():
    top = hierarchical(4, 2)
    S = top.structure_matrix()
    # worker i·m+j talks to everyone in its node and in neighbour nodes
    W = np.kron(ring(4).W, np.full((2, 2), 0.5))
    np.testing.assert_array_equal(S != 0, W != 0)


def test_inter_shifts_and_self_weight():
    top = hierarchical(4, 2)
    shifts = dict(hierarchical_inter_shifts(top))
    # ring(4) between nodes: shifts ±1 (mod 4 → {1, 3}), equal weight
    assert set(shifts) == {1, 3}
    w = ring(4).W[0, 1]
    assert shifts[1] == pytest.approx(w)
    assert shifts[3] == pytest.approx(w)
    assert hierarchical_self_weight(top) == pytest.approx(ring(4).W[0, 0])


def test_schedule_cycle_reaches_exact_average():
    sched = hierarchical_schedule(4, 2)
    assert sched.name == "hier_one_peer"
    assert sched.period == 2          # ceil(log2 4) one-peer-exp rounds
    P = np.eye(8)
    for top in sched.topologies:
        assert top.name == "hierarchical"
        P = top.W @ P
    # (∏ R_j) ⊗ (1/m)11ᵀ = the exact global average on a power of two
    np.testing.assert_allclose(P, np.full((8, 8), 1.0 / 8), atol=1e-12)


def test_constructor_validation():
    with pytest.raises(ValueError):
        hierarchical(0, 4)
    with pytest.raises(ValueError):
        make_topology("hierarchical", (8,))   # needs a (n, m) grid


# ---------------------------------------------------------- dense semantics

def _stacked(K, d=7, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (K, d),
                             dtype=jnp.float32)


def test_dense_hier_mix_equals_W_matmul():
    top = hierarchical(2, 4)
    x = _stacked(8)
    mixed = DenseComm(top).mix([x])[0]
    np.testing.assert_allclose(np.asarray(mixed),
                               np.asarray(top.W) @ np.asarray(x),
                               atol=1e-5)


def test_dense_hier_bf16_wire_matches_oracle():
    """bf16 quantization sits exactly on the inter wire: node means are
    exact (f32), the self term is full precision, only the *shipped*
    neighbour means round through bf16."""
    top = hierarchical(2, 4)
    x = _stacked(8, seed=3)
    got = DenseComm(top, wire_dtype="bfloat16").mix([x])[0]

    R = jnp.asarray(ring(2).W, dtype=jnp.float32)
    xa = x.reshape(2, 4, -1).mean(axis=1)             # exact intra mean
    wire = xa.astype(jnp.bfloat16).astype(jnp.float32)
    diag = jnp.diagonal(R)
    mixed = diag[:, None] * xa + (R - jnp.diag(diag)) @ wire
    oracle = jnp.broadcast_to(mixed[:, None, :], (2, 4, x.shape[1]))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(oracle).reshape(8, -1), atol=1e-6)

    exact = DenseComm(top).mix([x])[0]
    err = np.abs(np.asarray(got) - np.asarray(exact)).max()
    assert 0 < err < 2e-2            # quantized, but at bf16 resolution


def test_dense_hier_all_active_membership_is_plain_round():
    top = hierarchical(2, 2)
    ms = MembershipSchedule("full", np.ones((1, 4), bool),
                            np.ones((1, 4), bool))
    x = _stacked(4, seed=5)
    got = DenseComm(top, membership=ms).stale_mix([x], r=0)[0]
    want = DenseComm(top).mix([x])[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------- byte accounting

_TREE = [jax.ShapeDtypeStruct((1024,), jnp.float32),
         jax.ShapeDtypeStruct((160,), jnp.float32)]
_ELEMS = 1024 + 160


def test_hier_bytes_leader_pruned_f32():
    lv = hier_bytes_per_round(_TREE, DenseComm(hierarchical(2, 4)))
    site = 1 * 4 * _ELEMS            # ideg(ring(2)) = 1 × f32 payload
    assert lv["inter_site"] == site
    assert lv["inter"] == pytest.approx(site / 4)      # leaders only
    assert lv["intra_wire"] == pytest.approx(
        2 * (2 * 3 / 4) * 4 * _ELEMS)                  # avg + rebroadcast
    assert lv["intra_result"] == 2 * 4 * _ELEMS


def test_hier_bytes_bf16_halves_inter_only():
    f32 = hier_bytes_per_round(_TREE, DenseComm(hierarchical(2, 4)))
    bf16 = hier_bytes_per_round(
        _TREE, DenseComm(hierarchical(2, 4), wire_dtype="bfloat16"))
    assert bf16["inter"] == pytest.approx(f32["inter"] / 2)
    assert bf16["intra_wire"] == pytest.approx(f32["intra_wire"])


def test_hier_bytes_two_axis_unpruned():
    comm = HierarchicalComm(hierarchical(2, 4), ("pod", "data"))
    assert comm.hier_leader_pruned is False
    lv = hier_bytes_per_round(_TREE, comm)
    assert lv["inter"] == lv["inter_site"]   # no leader amortization
    assert lv["intra_wire"] == pytest.approx(
        1 * (2 * 3 / 4) * 4 * _ELEMS)        # average only, no rebroadcast


def test_optimizer_headline_bytes_and_mt_doubling():
    pd = make_optimizer("pd_sgdm", DenseComm(hierarchical(2, 4)))
    lv = pd.hier_bytes_per_level(_TREE)
    assert pd.bytes_per_comm_round(_TREE) == pytest.approx(lv["inter"])
    mt = make_optimizer("mt_dsgdm", DenseComm(hierarchical(2, 4)))
    mt_lv = mt.hier_bytes_per_level(_TREE)
    assert mt_lv == {k: 2 * v for k, v in lv.items()}   # (x, c) pair
    assert mt.bytes_per_comm_round(_TREE) == pytest.approx(2 * lv["inter"])


def test_flat_ring_vs_hier_reduction_arithmetic():
    """The sweep's headline: ring(8) vs (2 nodes × 4, bf16) = 16×."""
    flat = make_optimizer("pd_sgdm", DenseComm(ring(8)))
    hier = make_optimizer("pd_sgdm", DenseComm(hierarchical(2, 4),
                                               wire_dtype="bfloat16"))
    red = flat.bytes_per_comm_round(_TREE) / hier.bytes_per_comm_round(_TREE)
    assert red == pytest.approx(16.0)


# ------------------------------------------------------------------ guards

def test_c_sgdm_rejects_hierarchical():
    with pytest.raises(ValueError, match="centralized baseline"):
        make_optimizer("c_sgdm", DenseComm(hierarchical(2, 4)))


def test_cpd_rejects_sharded_hierarchical():
    from repro.core import SignCompressor
    comm = HierarchicalComm(hierarchical(2, 2), ("d",))
    with pytest.raises(ValueError, match="CPD-SGDM does not compose"):
        make_optimizer("cpd_sgdm", comm, compressor=SignCompressor())


def test_mt_compressed_rejects_sharded_hierarchical():
    from repro.core import SignCompressor
    comm = HierarchicalComm(hierarchical(2, 2), ("d",))
    with pytest.raises(ValueError):
        make_optimizer("mt_dsgdm", comm, compressor=SignCompressor())


def test_hier_comm_rejects_membership():
    ms = MembershipSchedule("full", np.ones((1, 4), bool),
                            np.ones((1, 4), bool))
    with pytest.raises(ValueError, match="membership"):
        HierarchicalComm(hierarchical(2, 2), ("d",), membership=ms)


def test_hier_comm_rejects_flat_topology():
    with pytest.raises(ValueError, match="hierarchical"):
        HierarchicalComm(ring(4), ("d",))


def test_inter_codec_guards():
    with pytest.raises(ValueError, match="randk"):
        HierarchicalComm(hierarchical(2, 2), ("d",),
                         inter_codec=types.SimpleNamespace(name="randk"))
    with pytest.raises(ValueError, match="wire encoding"):
        HierarchicalComm(hierarchical(2, 2), ("d",),
                         wire_dtype="bfloat16",
                         inter_codec=IdentityCodec())


# --------------------------------------------- dense ≡ sharded (slow tier)

_SCRIPT_HIER_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import hierarchical
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.models import make_model

    WIRE = os.environ.get("TEST_WIRE", "float32")
    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    run = RunCfg(model=mcfg,
                 parallel=ParallelCfg(profile="A", remat="none",
                                      topology="ring", node_size=2),
                 optim=OptimCfg(name="pd_sgdm", eta=0.05, mu=0.9, p=2,
                                weight_decay=1e-4, wire_dtype=WIRE))
    mesh = make_debug_mesh(4, 2)   # 4 workers x TP2 -> 2 nodes of 2
    pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
    K = pack.layout.n_workers
    assert K == 4, K
    params, state = pack.init_fn(jax.random.PRNGKey(0))
    batches = [train_batch_arrays(mcfg, K, 2, 16,
               jax.random.fold_in(jax.random.PRNGKey(1), t))
               for t in range(6)]
    for b in batches:
        params, state, loss = pack.train_step(params, state, b)
    sharded_final = jax.tree_util.tree_map(np.asarray, params)

    # dense single-device simulation of the identical two-level round
    model = make_model(mcfg)
    params2 = jax.vmap(lambda k: model.init(jax.random.PRNGKey(0)))(
        jax.random.split(jax.random.PRNGKey(0), K))
    comm = DenseComm(hierarchical(2, 2), wire_dtype=WIRE)
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=2, weight_decay=1e-4),
                 comm)
    st = opt.init(params2)
    gradf = jax.vmap(jax.value_and_grad(lambda p, b: model.loss(p, b)[0]))
    stepf = jax.jit(lambda st, p, b: opt.step(st, p, gradf(p, b)[1]))
    for b in batches:
        params2, st = stepf(st, params2, b)
    sim_final = jax.tree_util.tree_map(np.asarray, params2)

    errs = [np.abs(a - b).max() for a, b in
            zip(jax.tree_util.tree_leaves(sharded_final),
                jax.tree_util.tree_leaves(sim_final))]
    print("max leaf err:", max(errs))
    # both paths quantize at the same point (the shipped node mean), so
    # trajectories agree up to reduction order even at bf16
    assert max(errs) < 5e-4, max(errs)
    print("HIER_EQUIV_OK", WIRE)
""")


def _run(script, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_hier_equals_dense_sim():
    """grouped pmean + leader ppermute + psum ≡ dense kron-W round."""
    out = _run(_SCRIPT_HIER_EQUIV, {"TEST_WIRE": "float32"})
    assert "HIER_EQUIV_OK float32" in out


@pytest.mark.slow
def test_sharded_hier_equals_dense_sim_bf16():
    """the bitcast-pinned bf16 inter wire ≡ dense bf16 round-trip sim."""
    out = _run(_SCRIPT_HIER_EQUIV, {"TEST_WIRE": "bfloat16"})
    assert "HIER_EQUIV_OK bfloat16" in out
