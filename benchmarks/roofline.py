"""Roofline table aggregator: reads artifacts/dryrun/*.json (deliverable g).

Emits one CSV row per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO ratio, and per-device memory.
"""
import glob
import json
import os

from benchmarks.common import csv_row

ARTIFACTS = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_records(pattern="*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return [r for r in recs if not r.get("skipped")]


def main():
    recs = load_records()
    if not recs:
        csv_row("roofline/missing", 0.0,
                f"no artifacts under {ARTIFACTS}; run repro.launch.dryrun")
        return
    for r in recs:
        t = r["terms"]
        total_us = max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("tag"):
            name += f"/{r['tag']}"
        csv_row(
            name, total_us,
            f"compute_ms={t['compute_s']*1e3:.2f};"
            f"memory_ms={t['memory_s']*1e3:.2f};"
            f"collective_ms={t['collective_s']*1e3:.2f};"
            f"dominant={t['dominant']};"
            f"useful_ratio={r['useful_flops_ratio']:.2f};"
            f"wire_gb={r['wire_bytes_per_device']/1e9:.3f};"
            f"hbm_arg_gb={r['memory']['argument_bytes']/1e9:.2f}")
    doms = {}
    for r in recs:
        doms[r["terms"]["dominant"]] = doms.get(r["terms"]["dominant"], 0) + 1
    csv_row("roofline/summary", 0.0,
            f"pairs={len(recs)};dominant_counts={doms}")


if __name__ == "__main__":
    main()
