"""Elastic membership + straggler tolerance under a chaos harness.

Fast tier — dense backend, seeded kill / revive / straggle scripts from
``repro.testing.chaos``:

* mixing-matrix invariants every round (row-stochastic over live peers,
  e_k rows for masked workers, zero dead columns, doubly stochastic over
  the active set for symmetric bases);
* pruned-ppermute zero payloads decode to exactly 0 for every wire codec
  (the property that keeps CPD's neighbour copies from drifting when a
  source skips a round);
* all five fused-round optimizers survive churn with bounded survivor
  consensus and worker-averaged loss, accounted bytes ≡ an independent
  structure-graph oracle (dead edges ship zero bytes);
* CPD freezes a dead worker's x̂ exactly while it is down;
* K→K' checkpoint re-partitioning and in-fleet warm starts
  (``repro.checkpoint.elastic``).

Slow tier — a subprocess forces 8 host devices and asserts the sharded
(ppermute) backend tracks the dense reference parameter-for-parameter
through the same churn script, for CPD (packed sign wire) and MT.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint import elastic
from repro.core import make_compressor, make_optimizer
from repro.core.gossip import DenseComm, gossip_bytes_per_round
from repro.core.topology import (complete, exponential, full_membership,
                                 make_topology, membership_from_events, ring)
from repro.core.wire import make_codec
from repro.testing import (ChaosEvent, chaos_script, check_round_matrix,
                           membership_for, oracle_fleet_bytes,
                           revivals_by_round, run_dense_chaos)

K, D, P = 8, 24, 2
R = 12          # chaos horizon (rounds)
SEED = 7

tmap = jax.tree_util.tree_map


def _script():
    return chaos_script(K, R, seed=SEED)


def _membership():
    return membership_for(K, R, _script())


def _quadratic():
    """Heterogeneous per-worker quadratic: F_k(x) = ||x − b_k||²/2 with
    well-separated optima — consensus pressure and churn stress at once."""
    b = 2.0 * jax.random.normal(jax.random.PRNGKey(3), (K, D))

    def grads_fn(params, batch):
        g = {"w": params["w"] - b}
        return 0.5 * jnp.sum((params["w"] - b) ** 2, axis=-1).mean(), g

    return grads_fn


def _params0():
    # identical (consensus) init across workers — the trainers broadcast
    # x₀, and CPD's neighbour x̂ copies assume it
    x0 = jax.random.normal(jax.random.PRNGKey(0), (1, D))
    return {"w": jnp.broadcast_to(x0, (K, D))}


CONFIGS = [
    ("pd_sgdm", {}),
    ("cpd_sgdm", {"gamma": 0.5, "compressor": make_compressor("sign")}),
    ("cpd_sgdm", {"gamma": 0.5,
                  "compressor": make_compressor("topk", fraction=0.25)}),
    ("mt_dsgdm", {}),
    ("mt_dsgdm", {"compressor": make_compressor("sign")}),
    ("qg_dsgdm", {}),
]
CONFIG_IDS = ["pd", "cpd_sign", "cpd_topk", "mt", "mt_sign", "qg"]


def _make_opt(name, kw, membership):
    return make_optimizer(name, DenseComm(ring(K), membership=membership),
                          eta=0.05, mu=0.9, p=P, **kw)


# ----------------------------------------------------------------- the script
def test_chaos_script_deterministic_and_min_live():
    a, b_ = _script(), _script()
    assert a == b_
    ms = _membership()
    assert ms.live.min(axis=1).sum() >= 0            # shape sanity
    for r in range(R):
        assert ms.live_at(r).sum() >= 2              # min_live floor
        assert ms.active_at(r).sum() >= 1
    kinds = {e.kind for e in a}
    assert kinds == {"kill", "revive", "straggle"}   # seed exercises all


def test_membership_event_semantics():
    events = [ChaosEvent(1, "kill", 2), ChaosEvent(3, "revive", 2),
              ChaosEvent(2, "straggle", 5)]
    ms = membership_from_events(K, 6, events)
    assert ms.live_at(0).all() and ms.active_at(0).all()
    for r in (1, 2):                                 # kill persists
        assert not ms.live_at(r)[2] and not ms.active_at(r)[2]
    assert ms.live_at(3)[2] and ms.active_at(3)[2]   # revive restores
    assert ms.live_at(2)[5] and not ms.active_at(2)[5]   # straggle: 1 round
    assert ms.active_at(3)[5]
    assert revivals_by_round(events) == {3: [2]}


# ------------------------------------------------------------ matrix contract
@pytest.mark.parametrize("topo", [ring(K), exponential(K), complete(K)],
                         ids=["ring", "exp", "complete"])
def test_masked_matrix_invariants_every_round(topo):
    comm = DenseComm(topo, membership=_membership())
    for r in range(R):
        W = check_round_matrix(comm, r)
        act = np.asarray(comm.active_at(r), dtype=bool)
        if topo.symmetric:
            # doubly stochastic over the active set: columns of active
            # workers sum to 1 too, so the live-average is preserved
            np.testing.assert_allclose(W[:, act].sum(axis=0),
                                       np.ones(int(act.sum())), atol=1e-12)


def test_full_membership_matrix_is_topology_bitwise():
    topo = ring(K)
    comm = DenseComm(topo, membership=full_membership(K))
    np.testing.assert_array_equal(np.asarray(comm.effective_matrix(0)),
                                  topo.W)


# ------------------------------------------------------------ zero-wire decode
@pytest.mark.parametrize("comp_name,kw", [
    ("identity", {}), ("sign", {}), ("topk", {"fraction": 0.25}),
    ("randk", {"fraction": 0.25}), ("qsgd", {"levels": 16})])
def test_zero_wire_payload_decodes_to_exact_zero(comp_name, kw):
    """A receiver whose source skipped the round gets all-zero wire
    arrays from the pruned ppermute — every codec must decode that to
    exactly 0, so neighbour x̂ copies stay put (no drift)."""
    codec = make_codec(make_compressor(comp_name, **kw))
    n = 96
    x = jax.random.normal(jax.random.PRNGKey(1), (n,))
    key = jax.random.PRNGKey(2)
    payload = codec.pack(x, key)
    wired = codec.wire(payload)
    zeroed = {k: (jnp.zeros_like(v) if k in wired else v)
              for k, v in payload.items()}
    out = codec.unpack(zeroed, n, x.shape, x.dtype, key)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(n))


# ----------------------------------------------------------- chaos drive (fast)
@pytest.mark.parametrize("name,kw", CONFIGS, ids=CONFIG_IDS)
def test_dense_chaos_survivors_bounded(name, kw):
    """Under the seeded churn script: training still converges for the
    survivors, consensus stays within a small factor of the churn-free
    run, and the accounted wire bytes equal the structure-graph oracle's
    every round (dead edges ship zero)."""
    grads_fn = _quadratic()
    events = _script()
    opt = _make_opt(name, kw, _membership())
    run = run_dense_chaos(opt, events, _params0(), grads_fn, R)
    base = run_dense_chaos(_make_opt(name, kw, full_membership(K)),
                           [], _params0(), grads_fn, R)

    assert np.isfinite(run.consensus).all()
    assert np.isfinite(run.avg_loss).all()
    # survivors' averaged model still trains ...
    assert run.avg_loss[-1] < run.avg_loss[0]
    # ... never blows past the initial loss ...
    assert run.avg_loss.max() <= 1.3 * run.avg_loss[0]
    # ... and churn costs at most a modest factor over the clean run
    assert run.avg_loss[-1] <= 1.5 * base.avg_loss[-1]
    assert run.consensus.max() <= 3.0 * base.consensus.max()

    per_worker = {"w": jax.ShapeDtypeStruct((D,), jnp.float32)}
    for r in range(R):
        check_round_matrix(opt.comm, r)
        np.testing.assert_allclose(
            run.accounted_bytes[r],
            oracle_fleet_bytes(opt, per_worker, r),
            rtol=1e-12, err_msg=f"round {r}: accounted != shipped")


def test_bytes_cycle_covers_membership_period():
    """``bytes_per_round_cycle`` spans lcm(schedule, membership) rounds
    and matches the per-round accounting; churn rounds really charge
    less than full rounds."""
    opt = _make_opt("pd_sgdm", {}, _membership())
    per_worker = {"w": jax.ShapeDtypeStruct((D,), jnp.float32)}
    cycle = opt.bytes_per_round_cycle(per_worker)
    assert len(cycle) == opt.comm.round_cycle == R
    full = gossip_bytes_per_round(per_worker, DenseComm(ring(K)))
    for r, v in enumerate(cycle):
        assert v == opt.bytes_per_comm_round(per_worker, r=r)
        assert v <= full
    assert min(cycle) < full          # the script really kills edges


def test_cpd_dead_worker_xhat_frozen_exactly():
    """While a worker is down, its x̂ (and every copy implication) must
    not move at all — frozen bit-for-bit, not merely damped."""
    events = [ChaosEvent(1, "kill", 3), ChaosEvent(4, "revive", 3)]
    ms = membership_from_events(K, 6, events)
    opt = _make_opt("cpd_sgdm",
                    {"gamma": 0.5, "compressor": make_compressor("sign")},
                    ms)
    grads_fn = _quadratic()
    params, state = _params0(), None
    state = opt.init(params)
    batches = jnp.zeros((P, 1))
    roundj = jax.jit(lambda s, pp: opt.round(s, pp, grads_fn, batches))
    xhat_frozen = None
    for r in range(6):
        params, state, _ = roundj(state, params)
        xh3 = np.asarray(state["xhat"]["w"])[3]
        if r == 0:
            xhat_frozen = xh3                     # last commit before kill
        elif 1 <= r < 4:
            np.testing.assert_array_equal(xh3, xhat_frozen)
        elif r >= 4:
            assert not np.array_equal(xh3, xhat_frozen)   # resumed


# -------------------------------------------------------- elastic checkpoints
def _cpd_pair(k):
    comm = DenseComm(make_topology("ring", (k,)))
    return make_optimizer("cpd_sgdm", comm, eta=0.05, mu=0.9, p=P,
                          gamma=0.5, compressor=make_compressor("sign"))


def _trained_cpd_ckpt(tmp_path):
    opt = _cpd_pair(K)
    grads_fn = _quadratic()
    params = _params0()
    state = opt.init(params)
    batches = jnp.zeros((P, 1))
    roundj = jax.jit(lambda s, pp: opt.round(s, pp, grads_fn, batches))
    for _ in range(3):
        params, state, _ = roundj(state, params)
    step = int(np.asarray(state["step"]))
    ckpt.save(str(tmp_path), step, params=params, opt_state=state)
    return opt, params, state, step


def test_restore_elastic_same_k_bit_identical(tmp_path):
    opt, params, state, step = _trained_cpd_ckpt(tmp_path)
    out = elastic.restore_elastic(
        str(tmp_path), step,
        params_template=jax.eval_shape(lambda: params),
        state_template=jax.eval_shape(lambda: state), comm=opt.comm)
    for a, b_ in zip(jax.tree_util.tree_leaves(out["params"]),
                     jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    for a, b_ in zip(jax.tree_util.tree_leaves(out["opt_state"]),
                     jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


@pytest.mark.parametrize("new_k", [12, 5], ids=["grow", "shrink"])
def test_restore_elastic_repartitions(tmp_path, new_k):
    """K→K': survivors keep their shards bit-for-bit, joiners clone a
    live neighbour (params AND full optimizer state), and the step
    counter rides through so round/schedule/membership phase survive."""
    opt, params, state, step = _trained_cpd_ckpt(tmp_path)
    opt2 = _cpd_pair(new_k)
    p2 = {"w": jnp.zeros((new_k, D))}
    out = elastic.restore_elastic(
        str(tmp_path), step,
        params_template=jax.eval_shape(lambda: p2),
        state_template=jax.eval_shape(opt2.init, p2), comm=opt2.comm)
    dm = elastic.donor_map(K, new_k)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(params["w"])[dm])
    for key_ in ("m", "xhat"):
        np.testing.assert_array_equal(np.asarray(out["opt_state"][key_]["w"]),
                                      np.asarray(state[key_]["w"])[dm])
    assert int(np.asarray(out["opt_state"]["step"])) == step
    # the restored fleet must run: one full round, finite everywhere
    b2 = 2.0 * jax.random.normal(jax.random.PRNGKey(3), (new_k, D))

    def gfn(pp, batch):
        return (0.5 * jnp.sum((pp["w"] - b2) ** 2, axis=-1).mean(),
                {"w": pp["w"] - b2})

    np_, ns, _ = jax.jit(
        lambda s, pp: opt2.round(s, pp, gfn, jnp.zeros((P, 1))))(
            out["opt_state"], out["params"])
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves((np_, ns)))


def test_restore_elastic_rederives_nbr_copies(tmp_path):
    """Sharded-style states carry per-shift neighbour x̂ copies; after a
    K→K' re-partition every copy must equal its *new* owner's x̂ (the
    commit protocol's round-boundary invariant), not a stale donor's."""
    opt, params, state, step = _trained_cpd_ckpt(tmp_path)
    state_sh = dict(state)
    state_sh["xhat_nbrs"] = {
        f"ax0_sh{sh:+d}": tmap(
            lambda h: jnp.take(h, jnp.asarray((np.arange(K) + sh) % K),
                               axis=0), state["xhat"])
        for sh in (-1, 1)}
    ckpt.save(str(tmp_path / "shstate"), step, params=params,
              opt_state=state_sh)
    new_k = 12
    opt2 = _cpd_pair(new_k)
    p2 = {"w": jnp.zeros((new_k, D))}
    st2 = dict(jax.eval_shape(opt2.init, p2))
    st2["xhat_nbrs"] = {
        f"ax0_sh{sh:+d}": {"w": jax.ShapeDtypeStruct((new_k, D),
                                                     jnp.float32)}
        for sh in (-1, 1)}
    out = elastic.restore_elastic(
        str(tmp_path / "shstate"), step,
        params_template=jax.eval_shape(lambda: p2),
        state_template=st2, comm=opt2.comm)
    xh = np.asarray(out["opt_state"]["xhat"]["w"])
    for keyname, sub in out["opt_state"]["xhat_nbrs"].items():
        sh = int(keyname.split("_sh")[1])
        np.testing.assert_allclose(np.asarray(sub["w"]),
                                   xh[(np.arange(new_k) + sh) % new_k],
                                   err_msg=keyname)


def test_warm_start_worker_clones_full_state():
    opt = _cpd_pair(K)
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (K, D))}
    state = opt.init(params)
    state["m"] = {"w": jax.random.normal(jax.random.PRNGKey(5), (K, D))}
    wp, ws = elastic.warm_start_worker(params, state, joiner=3, donor=6)
    np.testing.assert_array_equal(np.asarray(wp["w"])[3],
                                  np.asarray(params["w"])[6])
    np.testing.assert_array_equal(np.asarray(ws["m"]["w"])[3],
                                  np.asarray(state["m"]["w"])[6])
    np.testing.assert_array_equal(np.asarray(ws["xhat"]["w"])[3],
                                  np.asarray(state["xhat"]["w"])[6])
    # untouched slots stay bit-identical
    keep = [i for i in range(K) if i != 3]
    np.testing.assert_array_equal(np.asarray(wp["w"])[keep],
                                  np.asarray(params["w"])[keep])


def test_pick_donor_nearest_live():
    live = np.array([1, 0, 0, 1, 1, 1, 1, 1], dtype=bool)
    assert elastic.pick_donor(live, 1) == 0
    assert elastic.pick_donor(live, 2) == 3
    with pytest.raises(ValueError):
        elastic.pick_donor(np.zeros(4, dtype=bool), 0)


# ------------------------------------------------------------- sharded (slow)
_SCRIPT_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import make_compressor, make_optimizer
    from repro.core.gossip import DenseComm, ShardedComm
    from repro.core.topology import ring
    from repro.launch.runtime import _smap
    from repro.testing import chaos_script, check_round_matrix, membership_for

    K, D, PP, R = 8, 16, 2, 6
    events = chaos_script(K, R, seed=11)
    ms = membership_for(K, R, events)
    b = 2.0 * jax.random.normal(jax.random.PRNGKey(3), (K, D))
    x0 = jax.random.normal(jax.random.PRNGKey(0), (1, D))
    params0 = {"w": jnp.broadcast_to(x0, (K, D))}
    batches = jnp.zeros((PP, 1))
    mesh = Mesh(np.array(jax.devices()[:K]).reshape(K), ("w",))
    pspec = {"w": P("w", None)}

    def gfn(pp, batch, bb):
        return (0.5 * jnp.sum((pp["w"] - bb) ** 2, axis=-1).mean(),
                {"w": pp["w"] - bb})

    for name, kw in [
            ("cpd_sgdm", dict(gamma=0.5,
                              compressor=make_compressor("sign"))),
            ("cpd_sgdm", dict(gamma=0.5,
                              compressor=make_compressor("topk",
                                                         fraction=0.25))),
            ("mt_dsgdm", {})]:
        opt_d = make_optimizer(name, DenseComm(ring(K), membership=ms),
                               eta=0.05, mu=0.9, p=PP, **kw)
        opt_s = make_optimizer(
            name, ShardedComm(ring(K), axis_names=("w",), membership=ms),
            eta=0.05, mu=0.9, p=PP, **kw)

        # dense reference
        pd_, sd = params0, opt_d.init(params0)
        rd = jax.jit(lambda s, pp: opt_d.round(
            s, pp, lambda p_, bt: gfn(p_, bt, b), batches))
        for _ in range(R):
            pd_, sd, _ = rd(sd, pd_)

        # sharded run through the same script
        with mesh:
            sshape = jax.eval_shape(
                opt_s.init, {"w": jax.ShapeDtypeStruct((1, D),
                                                       jnp.float32)})
            sspec = jax.tree_util.tree_map(
                lambda l: P() if l.ndim == 0
                else P("w", *([None] * (l.ndim - 1))), sshape)
            ps_ = params0
            ss = jax.jit(_smap(mesh)(opt_s.init, in_specs=(pspec,),
                                     out_specs=sspec))(ps_)

            def rnd(s, pp, bb):
                return opt_s.round(
                    s, pp, lambda p_, bt: gfn(p_, bt, bb), batches)

            rs = jax.jit(_smap(mesh)(rnd,
                                     in_specs=(sspec, pspec, P("w", None)),
                                     out_specs=(pspec, sspec, P())))
            for _ in range(R):
                ps_, ss, _ = rs(ss, ps_, b)

        for r in range(R):
            check_round_matrix(opt_s.comm, r)
        np.testing.assert_allclose(np.asarray(ps_["w"]),
                                   np.asarray(pd_["w"]),
                                   rtol=5e-6, atol=5e-6)
        print(f"SHARDED_CHAOS_OK {name} {list(kw)}")
""")


@pytest.mark.slow
def test_sharded_chaos_matches_dense():
    """The sharded (pruned-ppermute) elastic path tracks the dense masked
    matrix reference parameter-for-parameter through a churn script with
    kills, revivals and stragglers — CPD (sign + topk wires) and MT."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT_SHARDED], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert r.stdout.count("SHARDED_CHAOS_OK") == 3
