"""Fused gossip mixing kernel:  y = w_self·x + Σᵢ wᵢ·nbrᵢ.

After the ppermute exchange lands the neighbours' parameter shards in HBM,
the W-row combination is a pure AXPY chain; fusing it reads every stream
once instead of materializing the partial sums (which for a ring costs one
extra full read+write of the parameter vector).  Mixing weights are static
(the topology is fixed for a run) so they are baked into the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import LANE, default_interpret

__all__ = ["gossip_mix", "LANE", "BLOCK_ROWS"]

BLOCK_ROWS = 128


def _kernel(*refs, weights):
    # refs = (x0_ref, ..., xn_ref, out_ref)
    out_ref = refs[-1]
    acc = weights[0] * refs[0][...]
    for w, r in zip(weights[1:], refs[1:-1]):
        acc = acc + w * r[...]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("weights", "interpret"))
def gossip_mix(tensors, *, weights, interpret: bool | None = None):
    """tensors: tuple of (rows, 1024) f32; weights: tuple of floats."""
    if interpret is None:
        interpret = default_interpret()
    assert len(tensors) == len(weights) >= 1
    rows, lane = tensors[0].shape
    assert lane == LANE and rows % BLOCK_ROWS == 0, (rows, lane)
    grid = (rows // BLOCK_ROWS,)
    blk = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, weights=tuple(float(w) for w in weights)),
        grid=grid,
        in_specs=[blk] * len(tensors),
        out_specs=[blk],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)],
        interpret=interpret,
    )(*[t.astype(jnp.float32) for t in tensors])[0]
