"""ResNet-20 (CIFAR-10 variant, He et al. '16) — the paper's own test model.

Used by the paper-faithful reproduction benchmarks (Fig. 1-3) on synthetic
CIFAR-shaped data.  BatchNorm is replaced by GroupNorm(8): running statistics
are cross-step state that would entangle the optimizer comparison (and BN's
per-worker batch statistics differ between the decentralized and centralized
settings anyway); GroupNorm keeps the comparison purely about the optimizer.
Noted as a deviation in DESIGN.md/EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["resnet20_init", "resnet20_apply", "resnet20_loss"]


def _conv_init(key, k, cin, cout, dtype=jnp.float32):
    fan_in = k * k * cin
    w = jax.random.normal(key, (k, k, cin, cout), jnp.float32)
    return (w * (2.0 / fan_in) ** 0.5).astype(dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c, groups=8):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _gn(p, x, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = ((xg - mean) ** 2).mean(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    out = xg.reshape(n, h, w, c) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def _block_init(key, cin, cout, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(k1, 3, cin, cout, dtype),
        "gn1": _gn_init(cout),
        "conv2": _conv_init(k2, 3, cout, cout, dtype),
        "gn2": _gn_init(cout),
    }
    if cin != cout:
        p["proj"] = _conv_init(k3, 1, cin, cout, dtype)
    return p


def _block(p, x, stride):
    h = jax.nn.relu(_gn(p["gn1"], _conv(x, p["conv1"], stride)))
    h = _gn(p["gn2"], _conv(h, p["conv2"]))
    sc = _conv(x, p["proj"], stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def resnet20_init(key, num_classes=10, width=16, dtype=jnp.float32):
    ks = jax.random.split(key, 11)
    p = {"stem": _conv_init(ks[0], 3, 3, width, dtype),
         "gn0": _gn_init(width)}
    widths = [width, 2 * width, 4 * width]
    i = 1
    for si, wo in enumerate(widths):
        cin = width if si == 0 else widths[si - 1]
        for bi in range(3):
            p[f"s{si}b{bi}"] = _block_init(
                ks[i], cin if bi == 0 else wo, wo, dtype)
            i += 1
    p["head"] = {
        "w": (jax.random.normal(ks[10], (4 * width, num_classes))
              * (4 * width) ** -0.5).astype(dtype),
        "b": jnp.zeros((num_classes,), dtype),
    }
    return p


def resnet20_apply(p, x):
    """x: (n, 32, 32, 3) -> logits (n, classes)."""
    h = jax.nn.relu(_gn(p["gn0"], _conv(x, p["stem"])))
    for si in range(3):
        for bi in range(3):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _block(p[f"s{si}b{bi}"], h, stride)
    h = h.mean(axis=(1, 2))
    return h.astype(jnp.float32) @ p["head"]["w"].astype(jnp.float32) \
        + p["head"]["b"].astype(jnp.float32)


def resnet20_loss(p, batch):
    logits = resnet20_apply(p, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = (logits.argmax(-1) == labels).astype(jnp.float32).mean()
    return nll.mean(), {"acc": acc}
