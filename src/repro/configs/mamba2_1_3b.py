"""mamba2-1.3b — Mamba-2 / SSD [arXiv:2405.21060].

48L, d_model 2048, attention-free, vocab 50280, ssm_state 128, headdim 64,
expand 2 (d_inner 4096, 64 SSD heads).  Pure SSM: O(1) decode state, no KV
cache — runs long_500k natively.
"""
from repro.configs.base import LayerSpec, ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="mamba2-1.3b", arch_type="ssm",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=0, vocab=50280,
        pattern=(LayerSpec("mamba", "none"),),
        ssm_state=128, ssm_headdim=64, ssm_expand=2,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2405.21060",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="A"),
                  optim=OptimCfg())
