"""Gossip communication backends.

Two implementations of the same mixing semantics ``x⁽ᵏ⁾ ← Σⱼ w_kj x⁽ʲ⁾``:

* :class:`DenseComm` — single-process simulation.  Every pytree leaf carries a
  leading worker dimension of size K and mixing is an einsum with the dense
  mixing matrix ``W``.  This is the mathematically-literal form of the paper's
  Eq. (4)/(17) and is what the convergence experiments and unit tests run on
  (CPU, any K).

* :class:`ShardedComm` — production backend, used *inside* ``shard_map``.
  Each device holds its worker's (model-parallel shard of the) parameters
  without a worker dimension; neighbour exchange is ``jax.lax.ppermute``
  (HLO ``collective-permute``) along the named worker mesh axes.  Circulant
  (ring) and Kronecker-of-circulant (torus) topologies map each weighted
  shift to one ppermute; the fully-connected topology maps to ``pmean``.

Both expose::

    mix(tree, r=None)        -> tree            # Σⱼ w_kj x⁽ʲ⁾ (round r's W)
    shift_views(tree)        -> {(axis,shift): tree}   # raw neighbour tensors
    weights()                -> {(axis,shift): w}

``shift_views`` / ``receive_payload`` are what CPD-SGDM uses to move the
*compressed* wire-codec payload (``repro.core.wire``) between neighbours:
a payload is a plain dict of arrays, and each array crosses the wire as
one ``ppermute`` — uint8 sign bits, int32 top-k indices, f32 values —
so the HLO collective carries exactly the codec's bytes, for every
compressor, not just sign.

Either backend can be built from a single :class:`Topology` (static graph)
or from a :class:`TopologySchedule` (time-varying graph): ``mix`` then
selects round ``r``'s mixing matrix *inside* the jitted computation —
DenseComm indexes a stacked ``(T, K, K)`` weight tensor with the traced
round index; ShardedComm precomputes every round's ppermute program and
selects it with ``lax.switch`` — so the fused round engine never retraces
as the graph changes.  ``backend.topology`` remains the round-0 topology
(shapes / worker count); per-round structure is ``backend.topology_at(r)``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import (MembershipSchedule, Topology,
                                 TopologySchedule, active_edge_count,
                                 hierarchical_inter_shifts,
                                 hierarchical_self_weight, masked_matrix)

__all__ = ["DenseComm", "ShardedComm", "HierarchicalComm", "CommBackend",
           "gossip_bytes_per_round", "hier_bytes_per_round",
           "worker_mask_like"]

ShiftKey = Tuple[int, int]  # (topology axis, shift)

# dtypes the gossip wire can ship the uncompressed payload in; decoding is
# always an f32 upcast before the weighted accumulation
_WIRE_DTYPES = ("float32", "bfloat16")


def worker_mask_like(mask, leaf):
    """Reshape a (K,) worker mask so it broadcasts against a worker-stacked
    leaf of shape (K, ...)."""
    return mask.reshape((mask.shape[0],) + (1,) * (leaf.ndim - 1))


def _inter_factor(top: Topology) -> np.ndarray:
    """The (n_nodes, n_nodes) inter-level factor of a hierarchical
    topology: W_hier = R ⊗ (1/m)11ᵀ, rebuilt from the axis-0 shifts."""
    n = int(top.axis_sizes[0])
    R = np.eye(n) * hierarchical_self_weight(top)
    for (sh, w) in hierarchical_inter_shifts(top):
        for i in range(n):
            R[i, (i + sh) % n] += w
    return R


class CommBackend:
    topology: Topology
    schedule: Optional[TopologySchedule] = None
    membership: Optional[MembershipSchedule] = None
    wire_dtype: str = "float32"

    @property
    def wire_itemsize(self) -> int:
        """Bytes per element of the uncompressed gossip payload."""
        return 2 if self.wire_dtype == "bfloat16" else 4

    def _check_wire_dtype(self):
        if self.wire_dtype not in _WIRE_DTYPES:
            raise ValueError(
                f"wire_dtype {self.wire_dtype!r} not in {_WIRE_DTYPES}")

    @property
    def period(self) -> int:
        """Schedule period T (1 for a static topology)."""
        return self.schedule.period if self.schedule is not None else 1

    @property
    def round_cycle(self) -> int:
        """Joint period of the topology schedule and the membership
        schedule — the number of rounds after which both the graph and the
        liveness pattern repeat.  Byte accounting and per-round mixing
        programs cycle over this, not ``period``."""
        M = self.membership.period if self.membership is not None else 1
        return math.lcm(self.period, M)

    def topology_at(self, r: int) -> Topology:
        """Topology of round ``r`` (python int; wraps modulo the period)."""
        if self.schedule is not None:
            return self.schedule.at(r)
        return self.topology

    def active_at(self, r: int) -> np.ndarray:
        """(K,) bool — workers exchanging in round ``r`` (all True without
        a membership schedule)."""
        if self.membership is None:
            return np.ones(self.topology.n_workers, dtype=bool)
        return self.membership.active_at(r)

    def effective_matrix(self, r: int) -> np.ndarray:
        """The K×K mixing matrix this backend executes in round ``r``,
        membership mask applied — what chaos tests and the jaxpr contract
        checker assert row-stochasticity / dead-column-zero against."""
        top = self.topology_at(r)
        act = self.active_at(r)
        if act.all():
            return np.asarray(top.W)   # host: introspection  # lint: allow
        return masked_matrix(top, act)

    def effective_stale_matrix(self, r: int) -> np.ndarray:
        """The K×K matrix the *overlapped* delivery of round ``r``'s payload
        executes: round ``r``'s topology masked by the liveness of the
        delivery round ``r+1`` — a payload from a worker that died while in
        flight is dropped and its mass renormalized back to the receivers'
        self-weight (dead receivers keep the identity row).  Equal to
        :meth:`effective_matrix` without a membership schedule."""
        top = self.topology_at(r)
        act = self.active_at(r + 1)
        if act.all():
            return np.asarray(top.W)   # host: introspection  # lint: allow
        return masked_matrix(top, act)

    def edges_per_worker(self, r: int = 0):
        """Mean directed exchanges per worker in round ``r``: the topology
        degree without membership (int — exact legacy accounting), else
        ``active_edge_count / K`` (float; dead edges ship zero bytes)."""
        top = self.topology_at(r)
        if self.membership is None:
            return top.degree
        act = self.active_at(r)
        if act.all():
            return top.degree
        return active_edge_count(top, act) / top.n_workers

    def mix(self, tree, r=None):
        raise NotImplementedError

    def stale_mix(self, tree, r=None):
        """Mix of a one-round-stale snapshot under round ``r``'s topology
        and the *delivery* round's (``r+1``) liveness — the overlapped-round
        counterpart of :meth:`mix` (see :meth:`effective_stale_matrix`).
        Identical to ``mix`` without a membership schedule."""
        raise NotImplementedError

    def shift_views(self, tree) -> Dict[ShiftKey, object]:
        raise NotImplementedError

    def weights(self) -> Dict[ShiftKey, float]:
        return {(ax, sh): w for (ax, sh, w) in self.topology.shifts}

    def nonself_shifts(self):
        return [(ax, sh, w) for (ax, sh, w) in self.topology.shifts if sh != 0]

    def self_weight(self) -> float:
        return float(sum(w for (_, sh, w) in self.topology.shifts if sh == 0))

    def _resolve(self, first):
        """Normalize the first constructor arg: a schedule sets both the
        schedule and the round-0 ``topology`` (shape/worker-count anchor)."""
        if isinstance(first, TopologySchedule):
            self.schedule = first
            self.topology = first.at(0)
        else:
            self.schedule = None
            self.topology = first


@dataclasses.dataclass
class DenseComm(CommBackend):
    """Simulation backend: leaves are worker-stacked, leading dim K.

    Accepts a ``Topology`` or a ``TopologySchedule``; with a schedule the
    per-round W is selected by indexing the stacked ``(T, K, K)`` weight
    tensor with the (traced) round index — one trace serves every round.
    """

    topology: Topology  # or a TopologySchedule at construction
    membership: Optional[MembershipSchedule] = None
    wire_dtype: str = "float32"

    def __post_init__(self):
        self._resolve(self.topology)
        self._check_wire_dtype()
        self._W = jnp.asarray(self.topology.W, dtype=jnp.float32)
        self._Ws = (jnp.asarray(self.schedule.stacked_W(), dtype=jnp.float32)
                    if self.schedule is not None else None)
        # Hierarchical rounds mix through the factored form — exact intra
        # mean, then the (n, n) inter factor — mirroring the sharded
        # execution (and its bf16 wire point) instead of the flat W matmul.
        tops = (self.schedule.topologies if self.schedule is not None
                else (self.topology,))
        if (all(t.name == "hierarchical" for t in tops)
                and self.membership is None):
            self._hier_m = int(self.topology.axis_sizes[1])
            self._hier_R = jnp.asarray(
                np.stack([_inter_factor(t) for t in tops]), jnp.float32)
        else:
            self._hier_m = 0
            self._hier_R = None
        if self.membership is not None:
            self.membership.validate()
            if self.membership.n_workers != self.topology.n_workers:
                raise ValueError(
                    f"membership K={self.membership.n_workers} != topology "
                    f"K={self.topology.n_workers}")
            # Stack the masked matrix of every round in the joint cycle so
            # a traced round index selects it — one trace serves every
            # liveness pattern.  All-active rounds reuse the topology's own
            # W bit-for-bit.
            Lc = self.round_cycle
            Wm, act = [], []
            for l in range(Lc):
                a = self.membership.active_at(l)
                top = self.topology_at(l)
                Wm.append(np.asarray(top.W) if a.all()   # lint: allow
                          else masked_matrix(top, a))
                act.append(a)
            self._Wm = jnp.asarray(np.stack(Wm), dtype=jnp.float32)
            self._act = jnp.asarray(np.stack(act))
            # Overlapped delivery: round l's payload exchanged under the
            # *next* round's liveness (a worker that died with a payload in
            # flight drops out of the mix, renormalized) — same joint cycle.
            self._Wov = jnp.asarray(
                np.stack([self.effective_stale_matrix(l)
                          for l in range(Lc)]), dtype=jnp.float32)
        else:
            self._Wm = None
            self._act = None
            self._Wov = None

    def _W_at(self, r):
        if self.membership is not None:
            if self._Wm.shape[0] == 1:
                return self._Wm[0]
            if r is None:
                raise ValueError(
                    "DenseComm with a MembershipSchedule needs the round "
                    "index: mix(tree, r=...)")
            return self._Wm[jnp.mod(jnp.asarray(r), self._Wm.shape[0])]
        if self.schedule is None or self.schedule.period == 1:
            return self._W
        if r is None:
            raise ValueError(
                "DenseComm with a TopologySchedule needs the round index: "
                "mix(tree, r=...)")
        return self._Ws[jnp.mod(jnp.asarray(r), self.schedule.period)]

    def active_mask(self, r):
        """(K,) bool under a traced round index; None without membership.
        Optimizers use it to pin a straggler's auxiliary state (e.g. MT's
        tracking variable) instead of applying a phantom self-exchange."""
        if self.membership is None:
            return None
        if self._act.shape[0] == 1:
            return self._act[0]
        if r is None:
            raise ValueError(
                "DenseComm with a MembershipSchedule needs the round "
                "index: active_mask(r=...)")
        return self._act[jnp.mod(jnp.asarray(r), self._act.shape[0])]

    def mix(self, tree, r=None):
        if self._hier_R is not None:
            return self._apply_hier(self._hier_R_at(r), tree)
        return self._apply_W(self._W_at(r), tree)

    def _hier_R_at(self, r):
        if self._hier_R.shape[0] == 1:
            return self._hier_R[0]
        if r is None:
            raise ValueError(
                "DenseComm with a TopologySchedule needs the round index: "
                "mix(tree, r=...)")
        return self._hier_R[jnp.mod(jnp.asarray(r), self._hier_R.shape[0])]

    def _apply_hier(self, R, tree):
        """Factored hierarchical round: exact intra mean, inter factor on
        the node means, result rebroadcast in-node — the same program the
        sharded backend executes (``pmean`` → leader gossip → ``psum``),
        so the bf16 wire point sits exactly where the slow link is."""
        m = self._hier_m

        def _mix(leaf):
            K = leaf.shape[0]
            assert K == self.topology.n_workers, (
                f"leaf worker dim {K} != K={self.topology.n_workers}")
            flat = leaf.reshape(K // m, m, -1).astype(jnp.float32)
            xa = flat.mean(axis=1)
            if self.wire_dtype == "bfloat16":
                diag = jnp.diagonal(R)
                wire = xa.astype(jnp.bfloat16).astype(jnp.float32)
                mixed = diag[:, None] * xa + (R - jnp.diag(diag)) @ wire
            else:
                mixed = R @ xa
            out = jnp.broadcast_to(mixed[:, None, :], flat.shape)
            return out.astype(leaf.dtype).reshape(leaf.shape)

        return jax.tree_util.tree_map(_mix, tree)

    def stale_mix(self, tree, r=None):
        if self.membership is None:
            return self.mix(tree, r=r)
        if self._Wov.shape[0] == 1:
            return self._apply_W(self._Wov[0], tree)
        if r is None:
            raise ValueError(
                "DenseComm with a MembershipSchedule needs the round "
                "index: stale_mix(tree, r=...)")
        W = self._Wov[jnp.mod(jnp.asarray(r), self._Wov.shape[0])]
        return self._apply_W(W, tree)

    def _apply_W(self, W, tree):
        def _mix(leaf):
            K = leaf.shape[0]
            assert K == self.topology.n_workers, (
                f"leaf worker dim {K} != K={self.topology.n_workers}")
            flat = leaf.reshape(K, -1).astype(jnp.float32)
            if self.wire_dtype == "bfloat16":
                # what ships is the off-diagonal payload: each worker keeps
                # its own value at full precision and receives neighbours'
                # values bf16-rounded, accumulating in f32 — the sharded
                # backend's wire semantics, simulated
                diag = jnp.diagonal(W)
                wire = flat.astype(jnp.bfloat16).astype(jnp.float32)
                out = diag[:, None] * flat + (W - jnp.diag(diag)) @ wire
            else:
                out = W @ flat
            return out.astype(leaf.dtype).reshape(leaf.shape)

        return jax.tree_util.tree_map(_mix, tree)

    def _roll(self, leaf, axis: int, shift: int):
        """Return the view where worker k sees worker (k+shift)'s value."""
        grid = self.topology.axis_sizes
        K = leaf.shape[0]
        g = leaf.reshape(grid + leaf.shape[1:])
        # worker index along `axis` receives from (idx + shift) -> roll by -shift
        g = jnp.roll(g, -shift, axis=axis)
        return g.reshape((K,) + leaf.shape[1:])

    def shift_views(self, tree) -> Dict[ShiftKey, object]:
        out = {}
        for (ax, sh, _w) in self.nonself_shifts():
            out[(ax, sh)] = jax.tree_util.tree_map(
                lambda leaf: self._roll(leaf, ax, sh), tree)
        return out


@dataclasses.dataclass
class ShardedComm(CommBackend):
    """Production backend: ppermute along named mesh axes, inside shard_map.

    ``axis_names[i]`` is the mesh axis carrying topology axis ``i``.

    Accepts a ``Topology`` or a ``TopologySchedule``.  With a schedule every
    round's ppermute program (source→dest pairs per weighted exchange) is
    precomputed at construction; ``mix(tree, r)`` selects the round's
    program with ``lax.switch`` on the traced round index, so all T
    collective patterns live in one compiled executable — no retracing as
    the graph changes round to round.
    """

    topology: Topology  # or a TopologySchedule at construction
    axis_names: Tuple[str, ...]
    membership: Optional[MembershipSchedule] = None
    wire_dtype: str = "float32"

    def __post_init__(self):
        self._resolve(self.topology)
        self._check_wire_dtype()
        for top in (self.schedule.topologies if self.schedule is not None
                    else (self.topology,)):
            # 'complete' mixes via pmean over all named axes — grid unused.
            if top.name != "complete" and (
                    len(self.axis_names) != len(top.axis_sizes)):
                raise ValueError(
                    f"axis_names {self.axis_names} vs grid {top.axis_sizes}")
        if self.membership is not None:
            self.membership.validate()
            if self.membership.n_workers != self.topology.n_workers:
                raise ValueError(
                    f"membership K={self.membership.n_workers} != topology "
                    f"K={self.topology.n_workers}")
            if len(self.axis_names) != 1:
                # a multi-axis ppermute applies one perm across every slice
                # of the other axes — per-worker edge pruning is not
                # expressible there.  Flatten the grid to one worker axis
                # to combine elastic membership with the sharded backend.
                raise ValueError(
                    "elastic membership on ShardedComm needs a single "
                    f"worker axis; got axis_names {self.axis_names}")

    def _receive_from(self, x, axis: int, shift: int):
        """Each worker receives the value held by worker (k+shift) on `axis`."""
        n = self.topology.axis_sizes[axis]
        name = self.axis_names[axis]
        perm = [(j, (j - shift) % n) for j in range(n)]
        return jax.lax.ppermute(x, name, perm)

    def _receive_perm(self, x, axis: int, recv_from):
        """Each worker ``j`` on `axis` receives the value of ``recv_from[j]``."""
        name = self.axis_names[axis]
        perm = [(int(src), j) for j, src in enumerate(recv_from)]
        return jax.lax.ppermute(x, name, perm)

    def receive_tree(self, tree, axis: int, shift: int):
        return jax.tree_util.tree_map(
            partial(self._receive_from, axis=axis, shift=shift), tree)

    def receive_payload(self, payload: Dict[str, object], axis: int,
                        shift: int) -> Dict[str, object]:
        """Ship one wire-codec payload from the (axis, shift) neighbour:
        one ``ppermute`` per payload array, dtypes preserved (this is
        where compression becomes real bytes on the interconnect)."""
        return {k: self._receive_from(v, axis, shift)
                for k, v in payload.items()}

    def _receive_from_committed(self, x, axis: int, shift: int, source_ok):
        """``ppermute`` pruned to sources with ``source_ok[s]`` (a static
        numpy bool mask).  Destinations whose source did not commit receive
        zeros — which every wire codec decodes to exactly 0, so a stored
        neighbour copy updated with the decoded payload stays put."""
        n = self.topology.axis_sizes[axis]
        name = self.axis_names[axis]
        ok = np.asarray(source_ok, dtype=bool)   # host: pair list  # lint: allow
        pairs = [(s, (s - shift) % n) for s in range(n) if ok[s]]
        if not pairs:
            return jnp.zeros_like(x)
        return jax.lax.ppermute(x, name, pairs)

    def receive_payload_committed(self, payload: Dict[str, object],
                                  axis: int, shift: int,
                                  source_ok) -> Dict[str, object]:
        """Like :meth:`receive_payload`, but edges from non-committing
        sources are pruned from the collective (dead edges ship zero
        bytes); their receivers get all-zero payload arrays."""
        return {k: self._receive_from_committed(v, axis, shift, source_ok)
                for k, v in payload.items()}

    def _mix_with(self, top: Topology, tree):
        """One gossip round under a specific topology (static trace)."""
        if top.name == "complete":
            return jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(x, self.axis_names), tree)
        if top.name == "disconnected":
            return tree

        # Kronecker factorization: apply the per-axis exchanges sequentially.
        per_axis: Dict[int, list] = {}
        for (ax, sh, w) in top.shifts:
            per_axis.setdefault(ax, []).append(("shift", sh, w))
        for (ax, recv, w) in top.perms:
            per_axis.setdefault(ax, []).append(("perm", recv, w))

        def mix_leaf(x):
            y = x
            for ax in sorted(per_axis):
                acc = None
                payload = self._wire_cast(y)
                for (kind, arg, w) in per_axis[ax]:
                    if kind == "shift" and arg == 0:
                        v = y.astype(jnp.float32)       # self term: no wire
                    elif kind == "shift":
                        v = self._unwire_cast(
                            self._receive_from(payload, ax, arg))
                    else:
                        v = self._unwire_cast(
                            self._receive_perm(payload, ax, arg))
                    term = v * jnp.float32(w)
                    acc = term if acc is None else acc + term
                y = acc.astype(x.dtype)
            return y

        return jax.tree_util.tree_map(mix_leaf, tree)

    def _wire_cast(self, x):
        """What actually ships: the neighbour payload in the wire dtype
        (the self term never crosses the wire and stays full precision).
        The bf16 payload ships bitcast to u16: XLA's convert mover happily
        slides a float down-cast past the ppermute (re-widening the wire
        to 4 B/elem), but never commutes converts across integer bitcasts,
        so the 2 B/elem wire is pinned on every backend."""
        if self.wire_dtype == "bfloat16":
            return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16),
                                                jnp.uint16)
        return x

    def _unwire_cast(self, v):
        """Received payload back to f32 for the mixing accumulation
        (inverse of :meth:`_wire_cast`)."""
        if self.wire_dtype == "bfloat16":
            return jax.lax.bitcast_convert_type(
                v, jnp.bfloat16).astype(jnp.float32)
        return v.astype(jnp.float32)

    def _mix_with_masked(self, top: Topology, act, tree):
        """One gossip round under a specific topology with only ``act``
        workers exchanging.  Each weighted shift/perm becomes a ppermute
        pruned to edges with both endpoints active; per-worker receive
        coefficients and the renormalized self-weight come from
        :func:`masked_matrix`'s factors, gathered at ``axis_index`` — so
        the executed matrix equals the dense backend's masked W exactly.
        """
        if act.all():
            return self._mix_with(top, tree)
        if top.name == "disconnected":
            return tree

        name = self.axis_names[0]
        n = top.axis_sizes[0]
        idx = jax.lax.axis_index(name)
        act = np.asarray(act, dtype=bool)   # host: program build  # lint: allow
        ks = np.arange(n)

        # Per-exchange pruned perms + per-receiver coefficient vectors.
        # Coefficients come from each (shift, w) entry directly — never
        # from reading the masked matrix, whose aliased entries (e.g. the
        # ±K/2 shifts of `exponential`) collapse into one cell.
        entries = []  # (coeff (n,) f32, pairs)
        off_diag = np.zeros(n)
        for (_ax, sh, w) in top.shifts:
            if sh % n == 0:  # self (possibly aliased) — absorbed in diag
                continue
            src = (ks + sh) % n
            coeff = np.where(act & act[src], w, 0.0)
            pairs = [(int(s), int((s - sh) % n)) for s in range(n)
                     if act[s] and act[(s - sh) % n]]
            off_diag += coeff
            entries.append((coeff.astype(np.float32), pairs))
        for (_ax, recv, w) in top.perms:
            src = np.asarray(recv)   # host: program build  # lint: allow
            coeff = np.where((src != ks) & act & act[src], w, 0.0)
            pairs = [(int(src[j]), int(j)) for j in range(n)
                     if src[j] != j and act[j] and act[src[j]]]
            off_diag += coeff
            entries.append((coeff.astype(np.float32), pairs))
        # Lost neighbour mass flows back to self: rows stay stochastic.
        diag = jnp.asarray((1.0 - off_diag).astype(np.float32))[idx]
        coeffs = [jnp.asarray(c)[idx] for (c, _p) in entries]

        def mix_leaf(x):
            acc = x.astype(jnp.float32) * diag
            payload = self._wire_cast(x)
            for c, (_coeff, pairs) in zip(coeffs, entries):
                if not pairs:
                    continue
                v = self._unwire_cast(jax.lax.ppermute(payload, name, pairs))
                acc = acc + v * c
            return acc.astype(x.dtype)

        return jax.tree_util.tree_map(mix_leaf, tree)

    def mix(self, tree, r=None):
        if self.membership is not None:
            Lc = self.round_cycle
            if Lc == 1:
                return self._mix_with_masked(
                    self.topology_at(0), self.active_at(0), tree)
            if r is None:
                raise ValueError(
                    "ShardedComm with a MembershipSchedule needs the round "
                    "index: mix(tree, r=...)")
            branches = [partial(self._mix_with_masked, self.topology_at(l),
                                self.active_at(l)) for l in range(Lc)]
            idx = jnp.mod(jnp.asarray(r, jnp.int32), Lc)
            return jax.lax.switch(idx, branches, tree)
        if self.schedule is None or self.period == 1:
            return self._mix_with(self.topology_at(0), tree)
        if r is None:
            raise ValueError(
                "ShardedComm with a TopologySchedule needs the round index: "
                "mix(tree, r=...)")
        branches = [partial(self._mix_with, top)
                    for top in self.schedule.topologies]
        idx = jnp.mod(jnp.asarray(r, jnp.int32), self.period)
        return jax.lax.switch(idx, branches, tree)

    def stale_mix(self, tree, r=None):
        if self.membership is None:
            return self.mix(tree, r=r)
        Lc = self.round_cycle
        if Lc == 1:
            return self._mix_with_masked(
                self.topology_at(0), self.active_at(1), tree)
        if r is None:
            raise ValueError(
                "ShardedComm with a MembershipSchedule needs the round "
                "index: stale_mix(tree, r=...)")
        branches = [partial(self._mix_with_masked, self.topology_at(l),
                            self.active_at(l + 1)) for l in range(Lc)]
        idx = jnp.mod(jnp.asarray(r, jnp.int32), Lc)
        return jax.lax.switch(idx, branches, tree)

    def shift_views(self, tree) -> Dict[ShiftKey, object]:
        out = {}
        for (ax, sh, _w) in self.nonself_shifts():
            out[(ax, sh)] = self.receive_tree(tree, ax, sh)
        return out


@dataclasses.dataclass
class HierarchicalComm(ShardedComm):
    """Two-level sharded backend: exact intra-node average + inter-node
    gossip between node leaders.

    Workers live on the ``(n_nodes, node_size)`` grid of a
    ``"hierarchical"`` topology (or a schedule of them — e.g.
    ``hierarchical_schedule``'s one-peer-exp inter rounds).  Each round
    executes the factored matrix ``W_inter ⊗ (1/m)11ᵀ`` as:

    1. **intra** — grouped ``pmean`` over the node's ``m`` workers (the
       only non-ppermute collective the round contract allows), on the
       fast in-host links;
    2. **inter** — ``ppermute`` of the node mean between node *leaders*
       only (pruned source→dest pairs), optionally bf16
       (``wire_dtype``) or codec-compressed (``inter_codec``) — the slow
       cross-host wire, amortized over the node's ``m`` workers;
    3. **rebroadcast** — grouped ``psum`` of the leader's mixed value
       back to its node (intra links again).

    Two mesh layouts are supported:

    * ``axis_names = (name,)`` — one flat worker axis of size
      ``n_nodes × node_size``; worker ``i·m + j`` is node ``i`` member
      ``j`` and member 0 is the leader.  Intra steps are
      ``axis_index_groups`` collectives, the inter ppermute is pruned to
      leaders.
    * ``axis_names = (inter, intra)`` — the node boundary *is* a mesh
      axis (e.g. ``("pod", "data")``); ``node_size`` must equal the
      intra-axis size.  Every device holds its node mean after the full-
      axis ``pmean``, so the inter ppermute runs unpruned (per-device
      bytes are the same; there is no leader amortization) and no
      rebroadcast is needed.

    ``inter_codec`` compresses the inter wire with any keyless
    :class:`repro.core.wire.WireCodec` (identity/sign/qsgd/topk; randk
    needs a shared key and is rejected).  The self term stays full
    precision, so a lossy codec makes this standard *biased* compressed
    gossip — identity is bit-exact with no codec.  Elastic membership is
    dense-only (a masked two-level program is not expressible as pruned
    grouped collectives); use ``DenseComm`` with a hierarchical topology
    to simulate churn.
    """

    inter_codec: Optional[object] = None   # keyless WireCodec or None

    def __post_init__(self):
        self._resolve(self.topology)
        self._check_wire_dtype()
        for top in (self.schedule.topologies if self.schedule is not None
                    else (self.topology,)):
            if top.name != "hierarchical" or len(top.axis_sizes) != 2:
                raise ValueError(
                    "HierarchicalComm needs hierarchical (n_nodes, "
                    f"node_size) topologies; got {top.name!r} with grid "
                    f"{top.axis_sizes}")
        if len(self.axis_names) not in (1, 2):
            raise ValueError(
                "HierarchicalComm maps onto one flat worker axis or an "
                f"(inter, intra) axis pair; got {self.axis_names}")
        if self.membership is not None:
            raise ValueError(
                "elastic membership on HierarchicalComm is not supported: "
                "masked two-level rounds are not expressible as pruned "
                "grouped collectives — run hierarchical churn on DenseComm")
        if self.inter_codec is not None:
            if getattr(self.inter_codec, "name", "") == "randk":
                raise ValueError(
                    "randk inter_codec needs a shared per-round key; use "
                    "identity/sign/qsgd/topk on the inter wire")
            if self.wire_dtype != "float32":
                raise ValueError(
                    "inter_codec already defines the wire encoding; "
                    "combine it with wire_dtype='float32'")

    @property
    def n_nodes(self) -> int:
        return int(self.topology.axis_sizes[0])

    @property
    def node_size(self) -> int:
        return int(self.topology.axis_sizes[1])

    @property
    def hier_leader_pruned(self) -> bool:
        """True when only node leaders ship the inter wire (flat-axis
        layout) — per-worker inter bytes amortize over ``node_size``."""
        return len(self.axis_names) == 1

    def inter_degree(self, r: int = 0) -> int:
        return len(hierarchical_inter_shifts(self.topology_at(r)))

    def _node_groups(self):
        m, n = self.node_size, self.n_nodes
        return [[i * m + j for j in range(m)] for i in range(n)]

    def _level_ops(self, top: Topology):
        """The three per-layout primitives of one two-level round:
        ``node_avg`` (exact intra mean, f32), ``recv(payload, shift)``
        (inter-node exchange of an arbitrary payload array) and
        ``rebroadcast`` (mixed leader value back to its node)."""
        n, m = int(top.axis_sizes[0]), int(top.axis_sizes[1])
        if len(self.axis_names) == 2:
            inter_name, intra_name = self.axis_names

            def node_avg(x):
                if m == 1:
                    return x.astype(jnp.float32)
                return jax.lax.pmean(x.astype(jnp.float32), intra_name)

            def recv(payload, sh):
                perm = [(j, (j - sh) % n) for j in range(n)]
                return jax.lax.ppermute(payload, inter_name, perm)

            # every device already holds its node mean post-pmean, so the
            # unpruned ppermute leaves all of them consistent — no step 3
            def rebroadcast(acc):
                return acc

            return node_avg, recv, rebroadcast

        name = self.axis_names[0]
        groups = self._node_groups()

        def node_avg(x):
            if m == 1:
                return x.astype(jnp.float32)
            return jax.lax.pmean(x.astype(jnp.float32), name,
                                 axis_index_groups=groups)

        def recv(payload, sh):
            # leaders only: non-paired destinations receive zeros, which
            # the rebroadcast below overwrites
            pairs = [(s * m, ((s - sh) % n) * m) for s in range(n)]
            return jax.lax.ppermute(payload, name, pairs)

        if m == 1:
            def rebroadcast(acc):
                return acc
        else:
            def rebroadcast(acc):
                is_leader = jnp.equal(
                    jnp.mod(jax.lax.axis_index(name), m), 0)
                only_leader = jnp.where(is_leader, acc,
                                        jnp.zeros_like(acc))
                return jax.lax.psum(only_leader, name,
                                    axis_index_groups=groups)

        return node_avg, recv, rebroadcast

    def _inter_mix(self, xa, top, recv, *, wire=None, unwire=None):
        """Weighted inter-node accumulation on a node mean ``xa`` (f32).
        ``wire``/``unwire`` optionally restrict what ships to a payload
        slice (kernel used_rows) and pad it back after decode."""
        inter = hierarchical_inter_shifts(top)
        ws = hierarchical_self_weight(top)
        if not inter:
            return xa
        if wire is None:
            wire = unwire = lambda v: v
        acc = xa * jnp.float32(ws)
        src = wire(xa)
        if self.inter_codec is not None:
            pay = self.inter_codec.pack(src)
            for (sh, w) in inter:
                got = {k: recv(v, sh) for k, v in pay.items()}
                dec = self.inter_codec.unpack(got, src.size, src.shape,
                                              jnp.float32)
                acc = acc + unwire(dec) * jnp.float32(w)
        else:
            payload = self._wire_cast(src)
            for (sh, w) in inter:
                v = self._unwire_cast(recv(payload, sh))
                acc = acc + unwire(v) * jnp.float32(w)
        return acc

    def _mix_with(self, top: Topology, tree):
        """One two-level round under a specific hierarchical topology."""
        node_avg, recv, rebroadcast = self._level_ops(top)

        def mix_leaf(x):
            xa = node_avg(x)
            acc = self._inter_mix(xa, top, recv)
            return rebroadcast(acc).astype(x.dtype)

        return jax.tree_util.tree_map(mix_leaf, tree)

    def mix_mat(self, x_mat, *, plan=None, r: int = 0):
        """Kernel-path round on the flatten-once ``(rows, LANE)`` matrix:
        the intra levels run on the full matrix (alignment-tail zeros
        average to zero and stay zero), while the inter wire ships only
        the plan's ``used_rows`` slice — accounted ≡ shipped.  Static
        topologies only (schedules go through :meth:`mix`)."""
        top = self.topology_at(r)
        node_avg, recv, rebroadcast = self._level_ops(top)
        u = None if plan is None else int(plan.used_rows)
        if u is None or u >= x_mat.shape[-2]:
            wire = unwire = None
        else:
            def wire(v):
                return v[..., :u, :]
            unwire = plan.pad_wire
        xa = node_avg(x_mat)
        acc = self._inter_mix(xa, top, recv, wire=wire, unwire=unwire)
        return rebroadcast(acc).astype(x_mat.dtype)

    def shift_views(self, tree):
        raise NotImplementedError(
            "HierarchicalComm has no flat per-shift views: the inter wire "
            "moves node means between leaders, not raw worker tensors")


def _wire_leaf_bytes(tree, backend: CommBackend) -> int:
    """Σ leaf bytes as they ship on the wire: leaf dtype, downshifted to
    the backend's wire dtype when that is narrower (bf16 x-wire)."""
    wi = getattr(backend, "wire_itemsize", 4)
    return sum(int(np.prod(l.shape)) * min(int(l.dtype.itemsize), wi)
               for l in jax.tree_util.tree_leaves(tree))


def gossip_bytes_per_round(tree, backend: CommBackend,
                           bits_per_element: float | None = None,
                           r: int = 0) -> int:
    """Per-worker bytes sent in communication round ``r`` (comm-cost model).

    Full precision: round-r degree × Σ leaf bytes (at the backend's wire
    dtype — bf16 halves the uncompressed payload).  With compression, pass
    the compressor's ``wire_bits_per_element``.  Under a time-varying
    schedule the degree — and hence the bytes — varies by round; under a
    membership schedule dead edges ship zero bytes, so the multiplier is
    the round's active-edge count averaged over workers (a float).
    Hierarchical topologies charge the slow-link level only (the headline
    figure): see :func:`hier_bytes_per_round` for the per-level split.
    The optimizer's ``bytes_per_round_cycle`` collects the joint cycle.
    """
    top = backend.topology_at(r)
    if top.name == "hierarchical" and backend.membership is None:
        return hier_bytes_per_round(tree, backend, r=r)["inter"]
    total_elems = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))
    deg = top.degree
    if backend.membership is not None:
        epw = backend.edges_per_worker(r)
        if bits_per_element is None:
            return epw * _wire_leaf_bytes(tree, backend)
        return float(epw * total_elems * bits_per_element / 8.0)
    if bits_per_element is None:
        return deg * _wire_leaf_bytes(tree, backend)
    return int(deg * total_elems * bits_per_element / 8.0)


def hier_bytes_per_round(tree, backend: CommBackend, r: int = 0) -> dict:
    """Per-level comm-cost split of one hierarchical round.

    Returns a dict of per-worker byte figures for round ``r``:

    * ``"inter"`` — slow-link bytes per *worker*: inter-degree × payload
      (codec wire bytes when ``inter_codec`` is set, else leaf bytes at
      the wire dtype), divided by ``node_size`` when only leaders ship
      (flat-axis layout / dense simulation) — the headline accounting.
    * ``"inter_site"`` — slow-link bytes at the collective-permute op
      site per participating device (no leader amortization): what the
      HLO byte check reads off the compiled program.
    * ``"intra_wire"`` — fast-link bytes per worker: ring all-reduce
      wire cost ``2(m−1)/m × f32 bytes`` per intra collective (average +
      rebroadcast on the flat-axis layout; average only on the two-axis
      layout, where no rebroadcast ships).
    * ``"intra_result"`` — Σ all-reduce *result* bytes (what the HLO
      parser reports per op), for accounted ≡ shipped per level.
    """
    top = backend.topology_at(r)
    if top.name != "hierarchical":
        raise ValueError(f"not a hierarchical topology: {top.name!r}")
    m = int(top.axis_sizes[1])
    leaves = jax.tree_util.tree_leaves(tree)
    elems = sum(int(np.prod(l.shape)) for l in leaves)
    ideg = len(hierarchical_inter_shifts(top))
    codec = getattr(backend, "inter_codec", None)
    if codec is not None:
        payload = sum(codec.wire_bytes(int(np.prod(l.shape)))
                      for l in leaves)
    else:
        payload = _wire_leaf_bytes(tree, backend)
    pruned = bool(getattr(backend, "hier_leader_pruned", True))
    site = ideg * payload
    n_intra = 0 if m == 1 else (2 if pruned else 1)
    return {
        "inter": site / m if pruned else float(site),
        "inter_site": site,
        "intra_wire": n_intra * (2.0 * (m - 1) / m) * 4 * elems,
        "intra_result": n_intra * 4 * elems,
    }
