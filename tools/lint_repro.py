"""Repo lint CLI — the AST rules of ``repro.analysis.astlint``.

    python tools/lint_repro.py            # lints src/ tools/ benchmarks/
    python tools/lint_repro.py src tests  # explicit roots

Exit 0 = clean, 1 = violations, 2 = bad invocation.  CI runs this as part
of the blocking ``static-analysis`` job; the rules themselves (and the
``# lint: allow`` pragma) are documented in the astlint module and in
ARCHITECTURE.md §"Static contracts".
"""
from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis.astlint import lint_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="repo-specific AST lint")
    ap.add_argument("roots", nargs="*", default=["src", "tools", "benchmarks"],
                    help="files or directories to lint (repo-relative)")
    args = ap.parse_args(argv)

    roots = [r if os.path.isabs(r) else os.path.join(_REPO, r)
             for r in args.roots]
    missing = [r for r in roots if not os.path.exists(r)]
    if missing:
        print(f"lint_repro: no such path(s): {missing}", file=sys.stderr)
        return 2

    errors = lint_paths(roots, base=_REPO)
    for e in errors:
        print(e)
    if errors:
        print(f"\nlint_repro: {len(errors)} violation(s)", file=sys.stderr)
        return 1
    print("lint_repro: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
