"""Fig. 1: PD-SGDM (p = 4, 8, 16) vs centralized momentum SGD (C-SGDM).

Paper claim: all converge to ≈ the same training loss; periodic
communication does not hurt convergence.  Derived column: final loss per
setting (and the max gap to C-SGDM).
"""
from benchmarks.common import STEPS, csv_row, make_opt, train_resnet


def main():
    results = {}
    for name, p in [("c_sgdm", 1), ("pd_sgdm", 4), ("pd_sgdm", 8),
                    ("pd_sgdm", 16)]:
        # fused round engine: log blocks aligned to whole rounds so the
        # device is synced once per block, not per step
        hist, s_per_step = train_resnet(make_opt(name, p=p), steps=STEPS,
                                        log_every=max(5, p))
        label = f"fig1/{name}_p{p}"
        results[label] = hist.loss[-1]
        csv_row(label, s_per_step * 1e6,
                f"final_loss={hist.loss[-1]:.4f};start={hist.loss[0]:.4f};"
                f"comm_mb={hist.comm_mb[-1]:.1f};rounds={STEPS // p}")
    base = results["fig1/c_sgdm_p1"]
    gap = max(abs(v - base) for v in results.values())
    csv_row("fig1/max_gap_to_csgdm", 0.0, f"gap={gap:.4f}")
    return results


if __name__ == "__main__":
    main()
