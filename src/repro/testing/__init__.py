"""Reusable test substrates.

``repro.testing.chaos`` is the fault-injection layer behind
``tests/test_chaos.py`` and ``benchmarks/elastic_sweep.py``: seeded
kill / revive / straggle scripts, a dense chaos driver that runs the
fused round engine under churn while recording survivor metrics, and an
independent wire-byte oracle for the accounted ≡ shipped invariant.
"""
from repro.testing.chaos import (ChaosEvent, ChaosRun, chaos_script,
                                 check_round_matrix, membership_for,
                                 oracle_fleet_bytes, revivals_by_round,
                                 run_dense_chaos)

__all__ = ["ChaosEvent", "ChaosRun", "chaos_script", "check_round_matrix",
           "membership_for", "oracle_fleet_bytes", "revivals_by_round",
           "run_dense_chaos"]
