"""CPD-SGDM — Communication-efficient PD-SGDM (paper Algorithm 2).

Local loop identical to PD-SGDM; at a communication round (mod(t+1,p)==0)::

    x⁽ᵏ⁾ₜ₊₁ = x⁽ᵏ⁾ₜ₊½ + γ Σⱼ w_kj (x̂⁽ʲ⁾ₜ − x̂⁽ᵏ⁾ₜ)        (line 6, consensus)
    q⁽ᵏ⁾ₜ   = Q(x⁽ᵏ⁾ₜ₊₁ − x̂⁽ᵏ⁾ₜ)                        (line 7, compress)
    send q⁽ᵏ⁾ / recv q⁽ʲ⁾ for j ∈ N_k                    (line 8)
    x̂⁽ʲ⁾ₜ₊₁ = x̂⁽ʲ⁾ₜ + q⁽ʲ⁾                              (line 9, error comp.)

Key TPU adaptation: with the sign compressor and the sharded backend the
payload crossing the interconnect is the *bit-packed* ``(uint8 signs, f32
block scales)`` pair — the HLO ``collective-permute`` genuinely moves ~1/16th
(bf16) of the raw bytes, so the dry-run roofline reflects the paper's
compression claim rather than modelling it.

Auxiliary copies: each worker stores x̂ for itself and for each neighbour
(``xhat_nbrs``), updated only from received compressed payloads — neighbours'
x̂ are never shipped at full precision (that would defeat the point).  In the
dense simulation backend all copies coincide, so only the canonical stacked
x̂ is stored.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (Compressor, SignCompressor, sign_pack,
                                    sign_unpack, sign_wire_bytes)
from repro.core.gossip import CommBackend, DenseComm, ShardedComm
from repro.core.pdsgdm import PDSGDM, PDSGDMConfig

__all__ = ["CPDSGDMConfig", "CPDSGDM"]

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class CPDSGDMConfig(PDSGDMConfig):
    gamma: float = 0.4               # consensus step size γ (paper: 0.4/0.5)
    packed_wire: bool = True         # bit-pack sign payloads for ppermute


class CPDSGDM(PDSGDM):
    """Algorithm 2.  Inherits the local momentum step from PD-SGDM."""

    def __init__(self, config: CPDSGDMConfig, comm: CommBackend,
                 compressor: Optional[Compressor] = None):
        super().__init__(config, comm)
        self.compressor = compressor if compressor is not None else SignCompressor()
        if isinstance(comm, ShardedComm) and comm.topology.name == "complete":
            raise ValueError(
                "CPD-SGDM sharded backend needs a shift-structured topology "
                "(ring/torus/exponential); 'complete' has no neighbour state.")
        if isinstance(comm, ShardedComm) and comm.period > 1:
            raise ValueError(
                "CPD-SGDM sharded backend requires a static topology: the "
                "xhat_nbrs error-compensation copies track a fixed neighbour "
                "set (Alg. 2 line 9).  Time-varying schedules run on the "
                "dense backend, or use PD-SGDM on the sharded one.")

    # -- state -----------------------------------------------------------------
    def init(self, params):
        state = super().init(params)
        f32 = lambda t: tmap(lambda x: x.astype(jnp.float32), t)
        # x̂₀ = x₀: the first round's q then encodes only the local drift.
        state["xhat"] = f32(params)
        if isinstance(self.comm, ShardedComm):
            state["xhat_nbrs"] = {
                self._key(ax, sh): f32(params)
                for (ax, sh, _w) in self.comm.nonself_shifts()
            }
        return state

    @staticmethod
    def _key(ax: int, sh: int) -> str:
        return f"ax{ax}_sh{sh:+d}"

    # -- compression helpers -----------------------------------------------------
    def _apply_Q(self, tree, step):
        """Q leaf-wise; per-worker under the dense (worker-stacked) backend."""
        comp = self.compressor
        base = jax.random.PRNGKey(17)

        def per_leaf(i, leaf):
            key = jax.random.fold_in(jax.random.fold_in(base, i), step)
            if isinstance(self.comm, DenseComm):
                K = leaf.shape[0]
                keys = jax.random.split(key, K)
                return jax.vmap(comp.apply)(leaf, keys)
            return comp.apply(leaf, key)

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        q = [per_leaf(i, l) for i, l in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(treedef, q)

    def _kernel_wire(self) -> bool:
        """Whether the wire payload is produced by the Pallas sign kernels on
        the flatten-once (rows, 1024) layout — the production wire format on
        *both* backends (DenseComm simulates the exchange; ShardedComm ships
        the packed pair through ``ppermute``).  Requires the compressor's
        scale block to equal the kernel lane width so the kernel blocks are
        identical to the per-leaf jnp oracle's blocks."""
        from repro.kernels import ops as kops
        return (self.config.packed_wire
                and isinstance(self.compressor, SignCompressor)
                and self.compressor.block == kops.LANE)

    def _use_packed(self) -> bool:
        """Per-leaf jnp bit-packed wire: the fallback for sharded sign
        compressors whose block width differs from the kernel lane."""
        return (self.config.packed_wire
                and isinstance(self.compressor, SignCompressor)
                and isinstance(self.comm, ShardedComm))

    # -- communication round (Alg. 2 lines 6-9) ------------------------------------
    def comm_round(self, state, params):
        cfg = self.config
        gamma = jnp.float32(cfg.gamma)
        xhat = state["xhat"]

        # line 6: consensus from *locally stored* copies — zero communication.
        if isinstance(self.comm, ShardedComm):
            mixhat = tmap(lambda x: x * jnp.float32(self.comm.self_weight()), xhat)
            for (ax, sh, w) in self.comm.nonself_shifts():
                nbr = state["xhat_nbrs"][self._key(ax, sh)]
                mixhat = tmap(lambda a, b: a + jnp.float32(w) * b, mixhat, nbr)
        else:
            mixhat = self.comm.mix(xhat, r=self.round_index(state))
        params_new = tmap(
            lambda x, mh, h: (x.astype(jnp.float32) + gamma * (mh - h)).astype(x.dtype),
            params, mixhat, xhat)

        diff = tmap(lambda x, h: x.astype(jnp.float32) - h, params_new, xhat)

        new_state = dict(state)
        if self._kernel_wire():
            # lines 7-9 on the flatten-once kernel layout: one Pallas pack,
            # one (uint8, f32-scales) payload per neighbour exchange.
            from repro.kernels import ops as kops
            plan = kops.KernelPlan.for_tree(diff, worker_dim=True)
            interp = self.config.kernel_interpret
            packed, scales = kops.sign_pack(
                plan.flatten(diff), counts=plan.row_counts(),
                interpret=interp)
            q_self = plan.unflatten(
                kops.sign_unpack(packed, scales, interpret=interp),
                dtype=jnp.float32)
            new_state["xhat"] = tmap(lambda h, q: h + q, xhat, q_self)
            if isinstance(self.comm, ShardedComm):
                # ship only the rows that carry data: the wire bytes then
                # equal the accounted Σ ceil(size/1024) blocks exactly
                u = plan.used_rows
                wire_p, wire_s = packed[..., :u, :], scales[..., :u, :]
                nbrs = dict(state["xhat_nbrs"])
                for (ax, sh, _w) in self.comm.nonself_shifts():
                    k = self._key(ax, sh)
                    q_recv = plan.unflatten(
                        kops.sign_unpack(
                            plan.pad_wire(
                                self.comm._receive_from(wire_p, ax, sh)),
                            plan.pad_wire(
                                self.comm._receive_from(wire_s, ax, sh)),
                            interpret=interp),
                        dtype=jnp.float32)
                    nbrs[k] = tmap(lambda h, q: h + q, nbrs[k], q_recv)
                new_state["xhat_nbrs"] = nbrs
        elif self._use_packed():
            # lines 7-9 with bit-packed wire format (the TPU-native path).
            block = self.compressor.block
            leaves, treedef = jax.tree_util.tree_flatten(diff)
            packs = [sign_pack(l, block) for l in leaves]
            q_self = [
                sign_unpack(p, s, l.size, l.shape, jnp.float32, block)
                for (p, s), l in zip(packs, leaves)
            ]
            new_state["xhat"] = jax.tree_util.tree_unflatten(
                treedef, [h + q for h, q in zip(
                    jax.tree_util.tree_leaves(xhat), q_self)])
            nbrs = dict(state["xhat_nbrs"])
            for (ax, sh, _w) in self.comm.nonself_shifts():
                k = self._key(ax, sh)
                recv = [
                    (self.comm._receive_from(p, ax, sh),
                     self.comm._receive_from(s, ax, sh))
                    for (p, s) in packs
                ]
                q_recv = [
                    sign_unpack(p, s, l.size, l.shape, jnp.float32, block)
                    for (p, s), l in zip(recv, leaves)
                ]
                nbrs[k] = jax.tree_util.tree_unflatten(
                    treedef, [h + q for h, q in zip(
                        jax.tree_util.tree_leaves(nbrs[k]), q_recv)])
            new_state["xhat_nbrs"] = nbrs
        else:
            q = self._apply_Q(diff, state["step"])
            new_state["xhat"] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                                     xhat, q)
            if isinstance(self.comm, ShardedComm):
                nbrs = dict(state["xhat_nbrs"])
                for (ax, sh, _w) in self.comm.nonself_shifts():
                    k = self._key(ax, sh)
                    q_recv = self.comm.receive_tree(q, ax, sh)
                    nbrs[k] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                                   nbrs[k], q_recv)
                new_state["xhat_nbrs"] = nbrs

        return params_new, new_state

    # -- kernel round (flatten-once matrix domain) --------------------------------
    @property
    def kernel_comm_supported(self) -> bool:
        """Matrix-domain comm needs the kernel wire format; other
        compressors fall back to the tree comm at the round boundary."""
        return self._kernel_wire()

    def mat_state(self, plan, state) -> dict:
        mats = super().mat_state(plan, state)
        if self._kernel_wire():
            mats["xhat"] = plan.flatten(state["xhat"])
            if isinstance(self.comm, ShardedComm):
                mats["xhat_nbrs"] = {k: plan.flatten(v)
                                     for k, v in state["xhat_nbrs"].items()}
        return mats

    def unmat_state(self, plan, mats, state, step) -> dict:
        new_state = super().unmat_state(plan, mats, state, step)
        if "xhat" in mats:
            new_state["xhat"] = plan.unflatten(mats["xhat"],
                                               dtype=jnp.float32)
        if "xhat_nbrs" in mats:
            new_state["xhat_nbrs"] = {
                k: plan.unflatten(v, dtype=jnp.float32)
                for k, v in mats["xhat_nbrs"].items()}
        return new_state

    def comm_round_mat(self, x_mat, mats, counts, r, *, plan=None):
        """Alg. 2 lines 6-9 entirely on the kernel layout: consensus from
        stored copies, one Pallas sign pack, the packed pair through the
        wire (sliced to ``plan.used_rows`` so alignment padding never
        ships), error-compensation updates — no tree rematerialization."""
        from repro.kernels import ops as kops
        assert plan is not None, "CPD-SGDM matrix comm needs the KernelPlan"
        cfg = self.config
        gamma = jnp.float32(cfg.gamma)
        interp = cfg.kernel_interpret
        xhat = mats["xhat"]

        # line 6: consensus — zero communication (stored copies / dense W).
        if isinstance(self.comm, ShardedComm):
            mixhat = jnp.float32(self.comm.self_weight()) * xhat
            for (ax, sh, w) in self.comm.nonself_shifts():
                mixhat = mixhat + jnp.float32(w) * mats["xhat_nbrs"][
                    self._key(ax, sh)]
        else:
            mixhat = self.comm.mix(xhat, r=r)
        x_new = x_mat + gamma * (mixhat - xhat)

        # lines 7-9: Q on the matrix, packed payload on the wire.
        packed, scales = kops.sign_pack(x_new - xhat, counts=counts,
                                        interpret=interp)
        new_mats = dict(mats)
        new_mats["xhat"] = xhat + kops.sign_unpack(packed, scales,
                                                   interpret=interp)
        if isinstance(self.comm, ShardedComm):
            u = plan.used_rows
            wire_p, wire_s = packed[..., :u, :], scales[..., :u, :]
            nbrs = dict(mats["xhat_nbrs"])
            for (ax, sh, _w) in self.comm.nonself_shifts():
                k = self._key(ax, sh)
                q_recv = kops.sign_unpack(
                    plan.pad_wire(self.comm._receive_from(wire_p, ax, sh)),
                    plan.pad_wire(self.comm._receive_from(wire_s, ax, sh)),
                    interpret=interp)
                nbrs[k] = nbrs[k] + q_recv
            new_mats["xhat_nbrs"] = nbrs
        return x_new, new_mats

    # -- comm-cost model --------------------------------------------------------------
    def bytes_per_comm_round(self, params, r: int = 0) -> int:
        """Per-worker wire bytes for communication round ``r``.

        Packed sign wire: the *exact* payload — per leaf,
        ``ceil(size/block)`` blocks of ``block/8`` sign bytes + one f32
        scale each (padding included), × the round's topology degree
        (≈ 1/16.5 of raw f32, ≈ 1/15.5 of bf16).  Other compressors keep
        the per-element ``wire_bits_per_element`` model."""
        from repro.core.gossip import gossip_bytes_per_round
        comp = self.compressor
        if self.config.packed_wire and isinstance(comp, SignCompressor):
            payload = sum(
                sign_wire_bytes(int(np.prod(l.shape)), comp.block)
                for l in jax.tree_util.tree_leaves(params))
            return self.comm.topology_at(r).degree * payload
        bits = comp.wire_bits_per_element(
            jax.tree_util.tree_leaves(params)[0].dtype)
        return gossip_bytes_per_round(params, self.comm,
                                      bits_per_element=bits, r=r)
