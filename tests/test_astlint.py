"""AST lint rules: each fires on its seeded snippet, stays quiet on the
compliant variant, honors the pragma — and the repo itself lints clean."""
import os

from repro.analysis.astlint import lint_paths, lint_source

REPO = os.path.join(os.path.dirname(__file__), "..")


def _rules(src, rel):
    return [e.rule for e in lint_source(src, rel)]


# ------------------------------------------------------------------ RPR001
def test_host_sync_in_core():
    src = "def f(x):\n    return x.block_until_ready()\n"
    assert _rules(src, "src/repro/core/pdsgdm.py") == ["RPR001"]
    # outside core/ it's fine
    assert _rules(src, "src/repro/launch/train.py") == []
    # topology.py is host-side by design
    assert _rules(src, "src/repro/core/topology.py") == []


def test_np_asarray_in_core():
    src = "import numpy as np\ndef f(x):\n    return np.asarray(x)\n"
    assert _rules(src, "src/repro/core/gossip.py") == ["RPR001"]
    assert _rules(src, "src/repro/core/topology.py") == []


# ------------------------------------------------------------------ RPR002
def test_compressor_isinstance_dispatch():
    src = ("def f(c):\n"
           "    if isinstance(c, SignCompressor):\n"
           "        return 1\n")
    assert _rules(src, "src/repro/core/cpdsgdm.py") == ["RPR002"]
    # the one allowed home
    assert _rules(src, "src/repro/core/wire.py") == []
    # tuple form is caught too
    tup = "ok = isinstance(c, (TopKCompressor, int))\n"
    assert _rules(tup, "src/repro/train/trainer.py") == ["RPR002"]
    # non-compressor isinstance is fine
    assert _rules("ok = isinstance(c, int)\n",
                  "src/repro/core/cpdsgdm.py") == []


# ------------------------------------------------------------------ RPR003
def test_lane_literal():
    src = "x = y.reshape(-1, 1024)\n"
    assert _rules(src, "src/repro/core/compression.py") == ["RPR003"]
    # kernels/ owns the lane
    assert _rules(src, "src/repro/kernels/ops.py") == []
    # a documented non-lane 1024 carries the pragma
    ok = "n_patches = 1024  # ViT patches  # lint: allow\n"
    assert _rules(ok, "src/repro/configs/base.py") == []
    # other ints don't fire
    assert _rules("x = 1023\n", "src/repro/core/compression.py") == []


# ------------------------------------------------------------------ RPR004
def test_config_at_import():
    src = "import jax\njax.config.update('jax_enable_x64', True)\n"
    assert _rules(src, "src/repro/launch/train.py") == ["RPR004"]
    # repro/__init__.py is the one allowed site
    assert _rules(src, "src/repro/__init__.py") == []
    # inside a function it's runtime, not import-time
    fn = ("import jax\n"
          "def enable():\n"
          "    jax.config.update('jax_enable_x64', True)\n")
    assert _rules(fn, "src/repro/launch/train.py") == []
    # unrelated .update() calls don't fire
    assert _rules("self._config.update(d)\n",
                  "src/repro/launch/train.py") == []


def test_pragma_suppresses_any_rule():
    src = "def f(x):\n    return x.block_until_ready()  # lint: allow\n"
    assert _rules(src, "src/repro/core/pdsgdm.py") == []


def test_syntax_error_reported():
    out = lint_source("def f(:\n", "src/broken.py")
    assert out and out[0].rule == "RPR000"


# ------------------------------------------------------------------ the repo
def test_repo_lints_clean():
    """src/ + tools/ + benchmarks/ carry zero violations at HEAD — the
    blocking CI gate, asserted here so `pytest` alone also catches it."""
    roots = [os.path.join(REPO, d) for d in ("src", "tools", "benchmarks")]
    errors = lint_paths(roots, base=REPO)
    assert errors == [], "\n".join(str(e) for e in errors)


def _load_cli():
    import importlib.util
    path = os.path.join(REPO, "tools", "lint_repro.py")
    spec = importlib.util.spec_from_file_location("lint_repro_cli", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_cli_exit_codes(tmp_path):
    main = _load_cli().main
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean)]) == 0
    dirty = tmp_path / "repro" / "core" / "dirty.py"
    dirty.parent.mkdir(parents=True)
    dirty.write_text("def f(x):\n    return x.block_until_ready()\n")
    assert main([str(dirty)]) == 1
    assert main([str(tmp_path / "missing_dir")]) == 2
