"""CPD-SGDM — Communication-efficient PD-SGDM (paper Algorithm 2).

Local loop identical to PD-SGDM; at a communication round (mod(t+1,p)==0)::

    x⁽ᵏ⁾ₜ₊₁ = x⁽ᵏ⁾ₜ₊½ + γ Σⱼ w_kj (x̂⁽ʲ⁾ₜ − x̂⁽ᵏ⁾ₜ)        (line 6, consensus)
    q⁽ᵏ⁾ₜ   = Q(x⁽ᵏ⁾ₜ₊₁ − x̂⁽ᵏ⁾ₜ)                        (line 7, compress)
    send q⁽ᵏ⁾ / recv q⁽ʲ⁾ for j ∈ N_k                    (line 8)
    x̂⁽ʲ⁾ₜ₊₁ = x̂⁽ʲ⁾ₜ + q⁽ʲ⁾                              (line 9, error comp.)

Key TPU adaptation: with the sign compressor and the sharded backend the
payload crossing the interconnect is the *bit-packed* ``(uint8 signs, f32
block scales)`` pair — the HLO ``collective-permute`` genuinely moves ~1/16th
(bf16) of the raw bytes, so the dry-run roofline reflects the paper's
compression claim rather than modelling it.

Auxiliary copies: each worker stores x̂ for itself and for each neighbour
(``xhat_nbrs``), updated only from received compressed payloads — neighbours'
x̂ are never shipped at full precision (that would defeat the point).  In the
dense simulation backend all copies coincide, so only the canonical stacked
x̂ is stored.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.compression import (Compressor, SignCompressor, sign_pack,
                                    sign_unpack)
from repro.core.gossip import CommBackend, DenseComm, ShardedComm
from repro.core.pdsgdm import PDSGDM, PDSGDMConfig

__all__ = ["CPDSGDMConfig", "CPDSGDM"]

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class CPDSGDMConfig(PDSGDMConfig):
    gamma: float = 0.4               # consensus step size γ (paper: 0.4/0.5)
    packed_wire: bool = True         # bit-pack sign payloads for ppermute


class CPDSGDM(PDSGDM):
    """Algorithm 2.  Inherits the local momentum step from PD-SGDM."""

    def __init__(self, config: CPDSGDMConfig, comm: CommBackend,
                 compressor: Optional[Compressor] = None):
        super().__init__(config, comm)
        self.compressor = compressor if compressor is not None else SignCompressor()
        if isinstance(comm, ShardedComm) and comm.topology.name == "complete":
            raise ValueError(
                "CPD-SGDM sharded backend needs a shift-structured topology "
                "(ring/torus/exponential); 'complete' has no neighbour state.")
        if isinstance(comm, ShardedComm) and comm.period > 1:
            raise ValueError(
                "CPD-SGDM sharded backend requires a static topology: the "
                "xhat_nbrs error-compensation copies track a fixed neighbour "
                "set (Alg. 2 line 9).  Time-varying schedules run on the "
                "dense backend, or use PD-SGDM on the sharded one.")

    # -- state -----------------------------------------------------------------
    def init(self, params):
        state = super().init(params)
        f32 = lambda t: tmap(lambda x: x.astype(jnp.float32), t)
        # x̂₀ = x₀: the first round's q then encodes only the local drift.
        state["xhat"] = f32(params)
        if isinstance(self.comm, ShardedComm):
            state["xhat_nbrs"] = {
                self._key(ax, sh): f32(params)
                for (ax, sh, _w) in self.comm.nonself_shifts()
            }
        return state

    @staticmethod
    def _key(ax: int, sh: int) -> str:
        return f"ax{ax}_sh{sh:+d}"

    # -- compression helpers -----------------------------------------------------
    def _apply_Q(self, tree, step):
        """Q leaf-wise; per-worker under the dense (worker-stacked) backend."""
        comp = self.compressor
        base = jax.random.PRNGKey(17)

        def per_leaf(i, leaf):
            key = jax.random.fold_in(jax.random.fold_in(base, i), step)
            if isinstance(self.comm, DenseComm):
                K = leaf.shape[0]
                keys = jax.random.split(key, K)
                return jax.vmap(comp.apply)(leaf, keys)
            return comp.apply(leaf, key)

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        q = [per_leaf(i, l) for i, l in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(treedef, q)

    def _use_packed(self) -> bool:
        return (self.config.packed_wire
                and isinstance(self.compressor, SignCompressor)
                and isinstance(self.comm, ShardedComm))

    # -- communication round (Alg. 2 lines 6-9) ------------------------------------
    def comm_round(self, state, params):
        cfg = self.config
        gamma = jnp.float32(cfg.gamma)
        xhat = state["xhat"]

        # line 6: consensus from *locally stored* copies — zero communication.
        if isinstance(self.comm, ShardedComm):
            mixhat = tmap(lambda x: x * jnp.float32(self.comm.self_weight()), xhat)
            for (ax, sh, w) in self.comm.nonself_shifts():
                nbr = state["xhat_nbrs"][self._key(ax, sh)]
                mixhat = tmap(lambda a, b: a + jnp.float32(w) * b, mixhat, nbr)
        else:
            mixhat = self.comm.mix(xhat, r=self.round_index(state))
        params_new = tmap(
            lambda x, mh, h: (x.astype(jnp.float32) + gamma * (mh - h)).astype(x.dtype),
            params, mixhat, xhat)

        diff = tmap(lambda x, h: x.astype(jnp.float32) - h, params_new, xhat)

        new_state = dict(state)
        if self._use_packed():
            # lines 7-9 with bit-packed wire format (the TPU-native path).
            block = self.compressor.block
            leaves, treedef = jax.tree_util.tree_flatten(diff)
            packs = [sign_pack(l, block) for l in leaves]
            q_self = [
                sign_unpack(p, s, l.size, l.shape, jnp.float32, block)
                for (p, s), l in zip(packs, leaves)
            ]
            new_state["xhat"] = jax.tree_util.tree_unflatten(
                treedef, [h + q for h, q in zip(
                    jax.tree_util.tree_leaves(xhat), q_self)])
            nbrs = dict(state["xhat_nbrs"])
            for (ax, sh, _w) in self.comm.nonself_shifts():
                k = self._key(ax, sh)
                recv = [
                    (self.comm._receive_from(p, ax, sh),
                     self.comm._receive_from(s, ax, sh))
                    for (p, s) in packs
                ]
                q_recv = [
                    sign_unpack(p, s, l.size, l.shape, jnp.float32, block)
                    for (p, s), l in zip(recv, leaves)
                ]
                nbrs[k] = jax.tree_util.tree_unflatten(
                    treedef, [h + q for h, q in zip(
                        jax.tree_util.tree_leaves(nbrs[k]), q_recv)])
            new_state["xhat_nbrs"] = nbrs
        else:
            q = self._apply_Q(diff, state["step"])
            new_state["xhat"] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                                     xhat, q)
            if isinstance(self.comm, ShardedComm):
                nbrs = dict(state["xhat_nbrs"])
                for (ax, sh, _w) in self.comm.nonself_shifts():
                    k = self._key(ax, sh)
                    q_recv = self.comm.receive_tree(q, ax, sh)
                    nbrs[k] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                                   nbrs[k], q_recv)
                new_state["xhat_nbrs"] = nbrs

        return params_new, new_state

    # -- comm-cost model --------------------------------------------------------------
    def bytes_per_comm_round(self, params, r: int = 0) -> int:
        from repro.core.gossip import gossip_bytes_per_round
        bits = self.compressor.wire_bits_per_element(
            jax.tree_util.tree_leaves(params)[0].dtype)
        return gossip_bytes_per_round(params, self.comm,
                                      bits_per_element=bits, r=r)
