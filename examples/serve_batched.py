"""Serve a small model with batched requests: prefill + streaming decode.

Demonstrates the production serving path (prefill_fast builds the KV/SSM
cache in one pass; decode_step advances every sequence one token) across
three cache families: dense GQA, sliding-window ring buffer, and O(1) SSM
state.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models import make_model
from repro.serve.serving import generate

BATCH, PROMPT, NEW = 4, 24, 24

for arch in ["olmo-1b", "mixtral-8x7b", "mamba2-1.3b"]:
    run = get_smoke_config(arch)
    model = make_model(run.model)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT),
                                 0, run.model.vocab)
    t0 = time.time()
    out = generate(model, params, prompts, NEW, temperature=0.8,
                   key=jax.random.PRNGKey(2))
    dt = time.time() - t0
    kind = {"olmo-1b": "dense KV cache",
            "mixtral-8x7b": "sliding-window ring cache + MoE",
            "mamba2-1.3b": "O(1) SSM state"}[arch]
    print(f"{arch:14s} [{kind}] -> {out.shape}, "
          f"{BATCH*NEW/dt:6.1f} tok/s (incl. compile)")
    assert out.shape == (BATCH, PROMPT + NEW)
print("served all three cache families")
