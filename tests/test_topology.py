"""Topology invariants (paper Assumption 1 / Lemma 1)."""
import numpy as np
import pytest

from repro.core.topology import (complete, disconnected, exponential,
                                 is_doubly_stochastic, make_topology, ring,
                                 spectral_gap, torus)

TOPOLOGIES = [
    ring(2), ring(3), ring(8), ring(16),
    torus((2, 8)), torus((2, 16)), torus((4, 4)),
    complete(8), complete(5), exponential(16), exponential(8),
    disconnected(4),
]


@pytest.mark.parametrize("top", TOPOLOGIES, ids=lambda t: f"{t.name}{t.n_workers}")
def test_doubly_stochastic(top):
    top.validate()
    assert is_doubly_stochastic(top.W)


@pytest.mark.parametrize("top", TOPOLOGIES, ids=lambda t: f"{t.name}{t.n_workers}")
def test_spectral_gap_range(top):
    rho = top.rho
    if top.name == "disconnected":
        assert rho == pytest.approx(0.0, abs=1e-12)
    else:
        assert 0.0 < rho <= 1.0 + 1e-12


@pytest.mark.parametrize("top", [ring(8), torus((2, 8)), complete(8),
                                 exponential(16)],
                         ids=lambda t: f"{t.name}{t.n_workers}")
def test_lemma1_operator_norm(top):
    """‖W − 11ᵀ/K‖₂ = 1 − ρ  (Lemma 1)."""
    K = top.n_workers
    M = top.W - np.ones((K, K)) / K
    opnorm = np.linalg.norm(M, 2)
    assert opnorm == pytest.approx(1.0 - top.rho, abs=1e-8)


def test_shifts_reconstruct_w():
    """The shift decomposition must reproduce the dense circulant W."""
    for top in [ring(8), torus((2, 8)), exponential(8)]:
        K = top.n_workers
        grid = top.axis_sizes
        W = np.zeros((K, K))
        import itertools
        for idx in itertools.product(*[range(s) for s in grid]):
            k = np.ravel_multi_index(idx, grid)
            acc = {k: 1.0}
            for ax in range(len(grid)):
                new = {}
                for j, wj in acc.items():
                    jidx = list(np.unravel_index(j, grid))
                    for (a, sh, w) in top.shifts:
                        if a != ax:
                            continue
                        t = jidx.copy()
                        t[ax] = (t[ax] + sh) % grid[ax]
                        jj = np.ravel_multi_index(t, grid)
                        new[jj] = new.get(jj, 0.0) + wj * w
                if any(a == ax for (a, _s, _w) in top.shifts):
                    acc = new
            for j, w in acc.items():
                W[k, j] += w
        assert np.allclose(W, top.W, atol=1e-9), top.name


def test_make_topology():
    assert make_topology("ring", (8,)).n_workers == 8
    assert make_topology("torus", (2, 16)).n_workers == 32
    assert make_topology("complete", (4,)).rho == pytest.approx(1.0)
    with pytest.raises(ValueError):
        make_topology("nope", (4,))


def test_torus_beats_long_ring():
    """Hierarchical pod×ring mixing has a larger spectral gap than one ring
    of the same size — the reason the multi-pod layout uses it."""
    assert torus((2, 16)).rho > ring(32).rho
