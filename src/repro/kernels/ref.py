"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compression import sign_pack as _sign_pack
from repro.core.compression import sign_unpack as _sign_unpack
# the canonical jnp rows implementations in core.wire double as the
# oracles for the top-k select and QSGD quantize kernels: kernel vs these
# must be bit-exact (tests/test_kernels.py)
from repro.core.wire import qsgd_rows as qsgd_rows_ref
from repro.core.wire import qsgd_rows_unpack as qsgd_rows_unpack_ref
from repro.core.wire import topk_rows as topk_rows_ref
from repro.core.wire import topk_rows_unpack as topk_rows_unpack_ref
from repro.kernels import LANE

__all__ = ["momentum_update_ref", "sign_pack_ref", "sign_pack_rows_ref",
           "sign_unpack_ref", "gossip_mix_ref", "topk_rows_ref",
           "topk_rows_unpack_ref", "qsgd_rows_ref", "qsgd_rows_unpack_ref",
           "row_gather_ref", "row_scatter_ref"]


def momentum_update_ref(x, m, g, lr, *, mu, wd=0.0, nesterov=False):
    x = x.astype(jnp.float32)
    m = m.astype(jnp.float32)
    g = g.astype(jnp.float32) + wd * x
    m_new = mu * m + g
    d = (g + mu * m_new) if nesterov else m_new
    return x - lr * d, m_new


def sign_pack_ref(x, block: int = LANE):
    """(rows, block) → (packed (rows, block//8) u8, scales (rows,) f32)."""
    rows = x.shape[0]
    packed, scales = jax.vmap(lambda r: _sign_pack(r, block))(x)
    return packed.reshape(rows, block // 8), scales.reshape(rows)


def sign_pack_rows_ref(x, counts=None, block: int = LANE):
    """Counts-aware matrix oracle for ``sign_pack_pallas``.

    Same padding-masked scale the per-leaf oracle computes — ``counts`` is
    each row's true length (``KernelPlan.row_counts``); padding entries are
    assumed zero, exactly as the flatten-once layout guarantees.
    """
    rows = x.shape[0]
    x = x.astype(jnp.float32)
    if counts is None:
        counts = jnp.full((rows,), float(block), jnp.float32)
    counts = jnp.asarray(counts, jnp.float32).reshape(rows)
    scales = jnp.sum(jnp.abs(x), axis=1) / jnp.maximum(counts, 1.0)
    bits = (x >= 0).astype(jnp.uint8).reshape(rows, block // 8, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    packed = jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)
    return packed, scales.reshape(rows, 1)


def sign_unpack_ref(packed, scales, block: int = LANE):
    rows = packed.shape[0]
    return jax.vmap(
        lambda p, s: _sign_unpack(p.reshape(1, block // 8), s.reshape(1),
                                  block, (block,), jnp.float32, block)
    )(packed, scales.reshape(rows))


def row_gather_ref(x, idx, counts=None):
    """Oracle for ``row_gather_pallas``: out[j] = x[idx[j]] with lanes ≥
    the row's true length (``counts``) zeroed.  Pure data movement — the
    kernel must be bit-exact against this."""
    x = x.astype(jnp.float32)
    rows, lane = x.shape
    g = jnp.take(x, idx, axis=0)
    if counts is None:
        return g
    cnt = jnp.take(jnp.asarray(counts, jnp.float32).reshape(rows), idx)
    lanes = jnp.arange(lane, dtype=jnp.float32)[None, :]
    return jnp.where(lanes < cnt[:, None], g, 0.0)


def row_scatter_ref(idx, vals, *, rows: int):
    """Oracle for ``row_scatter_pallas``: zeros.at[idx].add(vals) — with
    the distinct-indices contract this is a pure permutation write."""
    return jnp.zeros((rows, vals.shape[-1]),
                     jnp.float32).at[idx].add(vals.astype(jnp.float32))


def gossip_mix_ref(tensors, weights):
    acc = jnp.zeros_like(tensors[0], dtype=jnp.float32)
    for w, t in zip(weights, tensors):
        acc = acc + jnp.float32(w) * t.astype(jnp.float32)
    return acc
