"""Sparse embedding-row wire: gather/scatter kernels vs the jnp oracles
(bit-exact), the lossless touched-within-budget property, empty-touch
zeros, composed inner codecs, byte-scaling shape, and the power-law
embedding workload's determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_codec
from repro.core.compression import SparseRowsCompressor
from repro.core.wire import payload_nbytes, sparse_row_select
from repro.data.synthetic import (EmbedStreamCfg, embed_batch,
                                  touched_row_mask)
from repro.kernels import LANE
from repro.kernels import ops as kops
from repro.kernels.ref import row_gather_ref, row_scatter_ref


def _rows_matrix(key, rows):
    return jax.random.normal(key, (rows, LANE), jnp.float32) * 1.7


def test_row_gather_kernel_matches_oracle():
    """Counts-aware gather: compacted payload bit-equal to the jnp oracle,
    including the masked tail lanes of partially-used rows."""
    rows, s = 8, 3
    x = _rows_matrix(jax.random.PRNGKey(0), rows)
    idx = jnp.asarray([1, 4, 7], jnp.int32)
    counts = jnp.asarray([LANE, 13, LANE, LANE, 500, LANE, LANE, 1],
                         jnp.float32)
    got = kops.row_gather(x, idx, counts=counts)
    want = row_gather_ref(x, idx, counts=counts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (s, LANE)
    # masked lanes really are exact zeros, kept lanes untouched values
    np.testing.assert_array_equal(np.asarray(got[1, 500:]),
                                  np.zeros(LANE - 500, np.float32))
    np.testing.assert_array_equal(np.asarray(got[1, :500]),
                                  np.asarray(x[4, :500]))
    np.testing.assert_array_equal(np.asarray(got[0, :13]),
                                  np.asarray(x[1, :13]))
    np.testing.assert_array_equal(np.asarray(got[0, 13:]),
                                  np.zeros(LANE - 13, np.float32))
    # counts=None gathers raw rows
    np.testing.assert_array_equal(
        np.asarray(kops.row_gather(x, idx)),
        np.asarray(row_gather_ref(x, idx)))


def test_row_scatter_kernel_matches_oracle():
    rows = 8
    idx = jnp.asarray([0, 2, 5], jnp.int32)
    vals = _rows_matrix(jax.random.PRNGKey(1), 3)
    got = kops.row_scatter(idx, vals, rows=rows)
    want = row_scatter_ref(idx, vals, rows=rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # unselected rows are exact zeros; zero payload decodes to exact zeros
    np.testing.assert_array_equal(np.asarray(got[1]),
                                  np.zeros(LANE, np.float32))
    z = kops.row_scatter(idx, jnp.zeros_like(vals), rows=rows)
    np.testing.assert_array_equal(np.asarray(z),
                                  np.zeros((rows, LANE), np.float32))


def test_row_gather_scatter_stacked_lead_dim():
    """The ops wrappers loop the scalar-prefetch kernels over a lead worker
    dim (grids with scalar prefetch cannot vmap) — results must match the
    oracle per slice."""
    k_lead, rows, s = 3, 6, 2
    x = jax.random.normal(jax.random.PRNGKey(2), (k_lead, rows, LANE))
    idx = jnp.stack([jnp.sort(jax.random.choice(
        jax.random.PRNGKey(10 + i), rows, (s,), replace=False)).astype(
            jnp.int32) for i in range(k_lead)])
    counts = jnp.full((rows,), LANE, jnp.float32).at[0].set(37.0)
    g = kops.row_gather(x, idx, counts=counts)
    assert g.shape == (k_lead, s, LANE)
    for i in range(k_lead):
        np.testing.assert_array_equal(
            np.asarray(g[i]),
            np.asarray(row_gather_ref(x[i], idx[i], counts=counts)))
    sc = kops.row_scatter(idx, g, rows=rows)
    assert sc.shape == (k_lead, rows, LANE)
    for i in range(k_lead):
        np.testing.assert_array_equal(
            np.asarray(sc[i]),
            np.asarray(row_scatter_ref(idx[i], g[i], rows=rows)))


def test_scatter_of_gather_reconstructs_selected_rows():
    rows = 10
    x = _rows_matrix(jax.random.PRNGKey(3), rows)
    idx = jnp.asarray([2, 3, 9], jnp.int32)
    back = kops.row_scatter(idx, kops.row_gather(x, idx), rows=rows)
    np.testing.assert_array_equal(np.asarray(back[np.asarray(idx)]),
                                  np.asarray(x[np.asarray(idx)]))
    untouched = np.setdiff1d(np.arange(rows), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(back[untouched]),
                                  np.zeros((len(untouched), LANE)))


def test_sparse_row_select_picks_top_norm_rows_sorted():
    x = _rows_matrix(jax.random.PRNGKey(4), 12)
    x = x.at[jnp.asarray([1, 6, 10])].mul(100.0)   # dominant rows
    idx = np.asarray(sparse_row_select(x, 3))
    np.testing.assert_array_equal(idx, [1, 6, 10])  # sorted ascending
    assert idx.dtype == np.int32


def test_sparse_f32_lossless_when_touched_within_budget():
    """The embedding-regime guarantee: when at most ``max_rows`` blocks of
    the leaf are non-zero, the f32-inner sparse wire satisfies Q(x) = x
    bit-exactly — on a ragged leaf (last block partial) too."""
    comp = SparseRowsCompressor(max_rows=4)
    codec = make_codec(comp)
    n = 10 * LANE + 37
    x = np.zeros(n, np.float32)
    rng = np.random.default_rng(0)
    for b in (0, 4, 10):                  # block 10 is the 37-element tail
        lo, hi = b * LANE, min((b + 1) * LANE, n)
        x[lo:hi] = rng.normal(size=hi - lo)
    x = jnp.asarray(x)
    q = codec.unpack(codec.pack(x, None), n, x.shape, x.dtype)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))


@pytest.mark.parametrize("inner", ["f32", "sign", "qsgd"])
def test_sparse_empty_touch_ships_exact_zero(inner):
    comp = SparseRowsCompressor(max_rows=4, inner=inner)
    codec = make_codec(comp)
    n = 6 * LANE + 5
    x = jnp.zeros((n,), jnp.float32)
    q = codec.unpack(codec.pack(x, None), n, x.shape, x.dtype)
    np.testing.assert_array_equal(np.asarray(q), np.zeros(n, np.float32))


def test_sparse_qsgd_composed_roundtrip():
    """sparse+qsgd: untouched rows exact zero, touched rows within the
    inner quantizer's step size."""
    levels = 7
    comp = SparseRowsCompressor(max_rows=3, inner="qsgd", levels=levels)
    codec = make_codec(comp)
    n = 8 * LANE
    x = np.zeros(n, np.float32)
    rng = np.random.default_rng(1)
    for b in (2, 5):
        x[b * LANE:(b + 1) * LANE] = rng.normal(size=LANE)
    x = jnp.asarray(x)
    q = np.asarray(codec.unpack(codec.pack(x, None), n, x.shape, x.dtype))
    xr = np.asarray(x).reshape(8, LANE)
    qr = q.reshape(8, LANE)
    for b in (0, 1, 3, 4, 6, 7):
        np.testing.assert_array_equal(qr[b], np.zeros(LANE, np.float32))
    for b in (2, 5):
        step = np.linalg.norm(xr[b]) / levels
        assert np.abs(qr[b] - xr[b]).max() <= step + 1e-6


def test_sparse_wire_bytes_flat_in_leaf_size():
    """Accounted bytes scale with the row budget, not the leaf size — the
    whole point of the codec — and match the shipped payload exactly."""
    codec = make_codec(SparseRowsCompressor(max_rows=64))
    big = [codec.wire_bytes(n * LANE) for n in (256, 1024, 4096)]
    assert big[0] == big[1] == big[2]            # flat past the budget
    assert (make_codec(SparseRowsCompressor(max_rows=128)).wire_bytes(
        4096 * LANE) == 2 * big[0])              # linear in the budget
    n = 300 * LANE
    wire = jax.eval_shape(
        lambda a: codec.wire(codec.pack(a, None)),
        jax.ShapeDtypeStruct((n,), jnp.float32))
    assert payload_nbytes(wire) == codec.wire_bytes(n)


def test_embed_batch_deterministic_and_power_law():
    cfg = EmbedStreamCfg(n_rows=4096, dim=32, batch=64, n_workers=4,
                         seed=5, zipf_a=1.2)
    b1 = embed_batch(cfg, step=3)
    b2 = embed_batch(cfg, step=3)
    np.testing.assert_array_equal(np.asarray(b1["ids"]),
                                  np.asarray(b2["ids"]))
    np.testing.assert_array_equal(np.asarray(b1["targets"]),
                                  np.asarray(b2["targets"]))
    b3 = embed_batch(cfg, step=4)
    assert not np.array_equal(np.asarray(b1["ids"]), np.asarray(b3["ids"]))
    ids = np.asarray(b1["ids"])
    assert ids.shape == (4, 64) and ids.dtype == np.int32
    assert ids.min() >= 0 and ids.max() < cfg.n_rows
    # Zipf head: the hottest row takes far more than the uniform share
    # (uniform would give 256/4096 = 0.0625 lookups per row)
    _, counts = np.unique(ids, return_counts=True)
    assert counts.max() >= 20
    # the sparse regime: far fewer distinct rows touched than the table
    mask = np.asarray(touched_row_mask(b1["ids"], cfg.n_rows))
    assert mask.sum() == len(np.unique(ids))
    assert mask.sum() < 0.1 * cfg.n_rows
