"""δ-contraction property tests (paper Definition 1) — hypothesis-driven."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# CI installs the test extras (``pip install -e .[test]``), which pin
# hypothesis>=6; environments without it skip this module instead of
# silently downgrading to canned examples.
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.compression import (IdentityCompressor, QSGDCompressor,
                                    RandKCompressor, SignCompressor,
                                    TopKCompressor, contraction_ratio,
                                    make_compressor, sign_pack, sign_unpack)

# deterministic δ-contractions: the guarantee holds per realization.
# Blocks both smaller and larger than the generated vectors (n ≤ 3000)
# are covered, so single-block and multi-block (tail-padded) paths run.
COMPRESSORS = [
    IdentityCompressor(),
    SignCompressor(block=64),
    SignCompressor(block=1024),
    TopKCompressor(fraction=0.1),
    TopKCompressor(fraction=0.01),
    TopKCompressor(fraction=0.1, block=64),
    QSGDCompressor(levels=7),
    QSGDCompressor(levels=16),
    QSGDCompressor(levels=1),
    QSGDCompressor(levels=7, block=64),
]
# ... plus rand-k, whose δ holds in expectation only (tested separately):
# together these are all five operators of make_compressor.
ALL_FIVE = COMPRESSORS + [RandKCompressor(fraction=0.25)]


def test_all_five_operators_covered():
    assert {c.name for c in ALL_FIVE} == {
        "identity", "sign", "topk", "randk", "qsgd"}


@st.composite
def vectors(draw):
    n = draw(st.integers(min_value=1, max_value=3000))
    seed = draw(st.integers(min_value=0, max_value=2 ** 31 - 1))
    scale = draw(st.floats(min_value=1e-3, max_value=1e3))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


@pytest.mark.parametrize(
    "comp", COMPRESSORS,
    ids=lambda c: f"{c.name}-{getattr(c, 'block', getattr(c, 'levels', ''))}"
    if c.name in ("sign",) else
    f"{c.name}-{getattr(c, 'fraction', getattr(c, 'levels', ''))}"
    f"-{getattr(c, 'block', '')}" if c.name in ("topk", "qsgd")
    else c.name)
@given(x=vectors())
@settings(max_examples=25, deadline=None)
def test_delta_contraction(comp, x):
    """Definition 1: ‖x − Q(x)‖² ≤ (1 − δ)‖x‖² with the operator's own
    guaranteed δ = delta_lower_bound(d), over random shapes and scales."""
    xj = jnp.asarray(x)
    q = comp.apply(xj, jax.random.PRNGKey(0))
    ratio = float(contraction_ratio(xj, q))
    delta = comp.delta_lower_bound(x.size)
    assert 0.0 < delta <= 1.0, (comp.name, delta)
    assert ratio <= (1.0 - delta) + 1e-4, (comp.name, ratio, delta)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@given(x=vectors())
@settings(max_examples=10, deadline=None)
def test_delta_contraction_dtypes(dtype, x):
    """The contraction property survives the leaf dtype round-trip (Q
    returns the input dtype; the bound is measured in f32)."""
    xj = jnp.asarray(x).astype(dtype)
    for comp in [SignCompressor(), TopKCompressor(fraction=0.1),
                 QSGDCompressor(levels=7)]:
        q = comp.apply(xj, jax.random.PRNGKey(0))
        assert q.dtype == xj.dtype and q.shape == xj.shape
        ratio = float(contraction_ratio(xj, q))
        delta = comp.delta_lower_bound(x.size)
        # bf16 rounding of Q(x) costs a little slack on top of Def. 1
        slack = 1e-4 if dtype == jnp.float32 else 2e-2
        assert ratio <= (1.0 - delta) + slack, (comp.name, ratio, delta)


@given(x=vectors())
@settings(max_examples=25, deadline=None)
def test_randk_contraction_in_expectation(x):
    comp = RandKCompressor(fraction=0.25)
    xj = jnp.asarray(x)
    ratios = []
    for i in range(8):
        q = comp.apply(xj, jax.random.PRNGKey(i))
        ratios.append(float(contraction_ratio(xj, q)))
        assert ratios[-1] <= 1.0 + 1e-5   # never expands
    # E[ratio] = 1 - k/d; allow generous sampling slack
    assert np.mean(ratios) <= 1.0 - 0.25 * 0.4


def test_sign_pack_roundtrip_exact():
    """unpack(pack(x)) must equal blockwise scale · sign exactly."""
    key = jax.random.PRNGKey(3)
    for n in [1, 5, 63, 64, 100, 1024, 5000]:
        x = jax.random.normal(key, (n,))
        packed, scales = sign_pack(x, block=64)
        q = sign_unpack(packed, scales, n, (n,), jnp.float32, block=64)
        # manual oracle
        xf = np.asarray(x)
        nb = -(-n // 64)
        pad = np.zeros(nb * 64, np.float32)
        pad[:n] = xf
        blocks = pad.reshape(nb, 64)
        valid = (np.arange(nb * 64).reshape(nb, 64) < n)
        sc = (np.abs(blocks) * valid).sum(1) / np.maximum(valid.sum(1), 1)
        want = (np.where(blocks >= 0, 1.0, -1.0)
                * sc[:, None]).reshape(-1)[:n]
        np.testing.assert_allclose(np.asarray(q), want, rtol=1e-6)


def test_sign_wire_bytes_16x_smaller():
    comp = SignCompressor()
    x = jnp.zeros((1 << 20,), jnp.float32)
    full = x.size * 4
    assert comp.wire_bytes(x) < full / 15.0


def test_topk_keeps_largest():
    comp = TopKCompressor(fraction=0.5)
    x = jnp.asarray([1.0, -5.0, 0.1, 3.0])
    q = np.asarray(comp.apply(x))
    np.testing.assert_allclose(q, [0.0, -5.0, 0.0, 3.0])


def test_make_compressor():
    assert make_compressor("sign").name == "sign"
    assert make_compressor("identity").name == "identity"
    with pytest.raises(ValueError):
        make_compressor("zstd")


def test_zero_vector_safe():
    for comp in ALL_FIVE:
        q = comp.apply(jnp.zeros((128,)), jax.random.PRNGKey(0))
        assert bool(jnp.isfinite(q).all())
