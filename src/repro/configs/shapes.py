"""The four assigned input shapes + ShapeDtypeStruct builders for dry-runs."""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg

__all__ = ["InputShape", "SHAPES", "train_batch_specs", "train_batch_arrays"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _batch_struct(cfg: ModelCfg, batch: int, seq: int, with_labels: bool):
    """Per-worker batch ShapeDtypeStructs honouring the input modality."""
    cd = jnp.dtype(cfg.compute_dtype)
    out = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    elif cfg.input_mode == "embeds":
        out["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cd)
    elif cfg.input_mode == "vlm":
        npatch = min(cfg.n_patches, seq // 2)
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, npatch, cfg.d_model), cd)
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq - npatch), jnp.int32)
    if with_labels:
        ls = seq if cfg.input_mode != "vlm" else seq - min(cfg.n_patches,
                                                           seq // 2)
        out["labels"] = jax.ShapeDtypeStruct((batch, ls), jnp.int32)
    return out


def train_batch_specs(cfg: ModelCfg, shape: InputShape, n_workers: int):
    """Stacked (n_workers, per_worker_batch, ...) batch specs."""
    assert shape.global_batch % n_workers == 0, (
        f"global_batch {shape.global_batch} % workers {n_workers}")
    per = shape.global_batch // n_workers
    base = _batch_struct(cfg, per, shape.seq_len,
                         with_labels=shape.kind == "train")

    def stack(sds):
        return jax.ShapeDtypeStruct((n_workers,) + sds.shape, sds.dtype)

    return {k: stack(v) for k, v in base.items()}


def train_batch_arrays(cfg: ModelCfg, n_workers: int, per_batch: int,
                       seq: int, key, with_labels: bool = True):
    """Concrete random batch with the same structure (for smoke/examples)."""
    cd = jnp.dtype(cfg.compute_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    out = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.random.randint(
            k1, (n_workers, per_batch, seq), 0, cfg.vocab)
    elif cfg.input_mode == "embeds":
        out["embeds"] = jax.random.normal(
            k1, (n_workers, per_batch, seq, cfg.d_model), cd)
    elif cfg.input_mode == "vlm":
        npatch = min(cfg.n_patches, seq // 2)
        out["patch_embeds"] = jax.random.normal(
            k1, (n_workers, per_batch, npatch, cfg.d_model), cd)
        out["tokens"] = jax.random.randint(
            k2, (n_workers, per_batch, seq - npatch), 0, cfg.vocab)
    if with_labels:
        ls = seq if cfg.input_mode != "vlm" else seq - min(cfg.n_patches,
                                                           seq // 2)
        out["labels"] = jax.random.randint(
            k3, (n_workers, per_batch, ls), 0, cfg.vocab)
    return out
