"""stablelm-12b — Stable LM 2 family [hf:stabilityai/stablelm-2-1_6b].

40L, d_model 5120, 32 heads (GQA kv=8), d_ff 13824, vocab 100352.
LayerNorm (with bias) per the Stable LM 2 architecture.
"""
from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="stablelm-12b", arch_type="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab=100352, norm="layernorm",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="hf:stabilityai/stablelm-2-1_6b",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="A"),
                  optim=OptimCfg())
