"""CPD-SGDM (Algorithm 2): compressed consensus with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPDSGDM, CPDSGDMConfig, IdentityCompressor, PDSGDM,
                        PDSGDMConfig, SignCompressor, TopKCompressor)
from repro.core.gossip import DenseComm
from repro.core.topology import ring


def quad_grad(params):
    return jax.tree_util.tree_map(lambda x: 2.0 * x, params)


def run(opt, params, steps, gradf=quad_grad):
    state = opt.init(params)
    step = jax.jit(lambda s, p: opt.step(s, p, gradf(p)))
    for _ in range(steps):
        params, state = step(state, params)
    return params, state


@pytest.mark.parametrize("comp,gamma", [
    (SignCompressor(block=64), 0.4),
    # aggressive compression needs a smaller consensus step (paper §7.2:
    # γ scales with ρ²δ — a large γ with small δ oscillates)
    (TopKCompressor(fraction=0.25), 0.1),
    (IdentityCompressor(), 0.4),
], ids=lambda c: getattr(c, "name", str(c)))
def test_converges_with_any_contraction(comp, gamma):
    K = 8
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=4, gamma=gamma),
                  DenseComm(ring(K)), comp)
    params = {"w": jnp.arange(K * 4, dtype=jnp.float32).reshape(K, 4)}
    params, _ = run(opt, params, 300)
    assert float(jnp.abs(params["w"]).max()) < 5e-3, comp.name


def test_consensus_without_gradients():
    """Pure gossip (zero gradients): workers contract toward the initial
    average despite sign-compressed communication (the CHOCO property)."""
    K = 8
    opt = CPDSGDM(CPDSGDMConfig(eta=0.0, mu=0.0, p=1, gamma=0.4),
                  DenseComm(ring(K)), SignCompressor(block=64))
    key = jax.random.PRNGKey(0)
    w0 = jax.random.normal(key, (K, 16))
    mean0 = w0.mean(0)
    params = {"w": w0}
    state = opt.init(params)
    zero = {"w": jnp.zeros_like(w0)}
    step = jax.jit(lambda s, p: opt.step(s, p, zero))
    d0 = float(jnp.abs(w0 - mean0[None]).max())
    for _ in range(200):
        params, state = step(state, params)
    d1 = float(jnp.abs(params["w"] - mean0[None]).max())
    # average is preserved and disagreement shrinks substantially
    np.testing.assert_allclose(np.asarray(params["w"].mean(0)),
                               np.asarray(mean0), atol=1e-4)
    assert d1 < 0.05 * d0, (d0, d1)


def test_average_preserved_by_comm_round():
    """Eq. 44: the consensus+compress round never moves the worker mean."""
    K = 8
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4),
                  DenseComm(ring(K)), SignCompressor(block=64))
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (K, 32))}
    state = opt.init(params)
    before = np.asarray(params["w"].mean(0))
    new_params, _ = opt.comm_round(state, params)
    after = np.asarray(new_params["w"].mean(0))
    np.testing.assert_allclose(after, before, atol=1e-5)


def test_xhat_tracks_params():
    """Error feedback: x̂ converges toward x as rounds accumulate."""
    K = 4
    opt = CPDSGDM(CPDSGDMConfig(eta=0.01, mu=0.9, p=2, gamma=0.4),
                  DenseComm(ring(K)), SignCompressor(block=64))
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (K, 64))}
    state = opt.init(params)
    step = jax.jit(lambda s, p: opt.step(s, p, quad_grad(p)))
    for _ in range(100):
        params, state = step(state, params)
    err = float(jnp.abs(state["xhat"]["w"] - params["w"]).mean())
    scale = float(jnp.abs(params["w"]).mean()) + 1e-6
    assert err < 5 * scale  # bounded compression error, not divergence


def test_sharded_needs_shift_topology():
    from repro.core.gossip import ShardedComm
    from repro.core.topology import complete
    with pytest.raises(ValueError):
        CPDSGDM(CPDSGDMConfig(), ShardedComm(complete(4), ("data",)),
                SignCompressor())


def test_packed_wire_bytes_accounting():
    """The packed-wire cost model charges uint8 signs + f32 block scales —
    the exact ppermute payload including padded tail blocks — not
    full-precision leaf bytes (≈ 1/15.5 of bf16 raw)."""
    from repro.core.compression import sign_wire_bytes
    K = 8
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=4, gamma=0.4),
                  DenseComm(ring(K)), SignCompressor())
    n = 100 * 1024 + 300                       # padded tail block
    params = {"w": jnp.zeros((n,), jnp.bfloat16)}
    got = opt.bytes_per_comm_round(params)
    deg = ring(K).degree                       # 2 neighbours
    blocks = -(-n // 1024)
    want = deg * blocks * (1024 // 8 + 4)      # 128 sign bytes + 1 f32 scale
    assert got == want
    assert sign_wire_bytes(n) == blocks * (1024 // 8 + 4)
    # the kernel wire ships exactly the accounted extent: payloads are
    # sliced to plan.used_rows before ppermute (alignment rows never ship)
    from repro.kernels import ops as kops
    plan = kops.KernelPlan.for_tree(params)
    assert plan.used_rows * (1024 // 8 + 4) == sign_wire_bytes(n)
    raw_bf16 = deg * n * 2
    assert 14.0 < raw_bf16 / got < 16.0        # the ~1/16th-of-bf16 claim
    # identity compressor: CPD's q is the f32 drift x − x̂ — that is what
    # ships, so that is what is charged (accounted ≡ shipped), even for
    # bf16 params
    full = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=4, gamma=0.4),
                   DenseComm(ring(K)), IdentityCompressor())
    assert full.bytes_per_comm_round(params) == deg * n * 4


def test_packed_wire_schedule_degree_accounting():
    """PR 2's per-round-degree accounting must hold under compression: each
    round of a time-varying schedule charges that round's degree × the
    packed payload, and the cycle accumulates round-robin."""
    from repro.core.compression import sign_wire_bytes
    from repro.core.topology import make_schedule
    K = 8
    sched = make_schedule("one_peer_exp", (K,))
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=4, gamma=0.4),
                  DenseComm(sched), SignCompressor())
    n = 3 * 1024 + 17
    params = {"w": jnp.zeros((n,), jnp.float32)}
    payload = sign_wire_bytes(n)
    cycle = opt.bytes_per_round_cycle(params)
    assert len(cycle) == sched.period
    for r, b in enumerate(cycle):
        assert b == sched.at(r).degree * payload, r
    # one-peer rounds (degree 1) cost half a ring round (degree 2)
    ring_opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=4, gamma=0.4),
                       DenseComm(ring(K)), SignCompressor())
    assert ring_opt.bytes_per_comm_round(params) == 2 * payload
    assert all(b == payload for b in cycle)
