"""PD-SGDM (Algorithm 1) semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPDSGDM, CPDSGDMConfig, PDSGDM, PDSGDMConfig,
                        SignCompressor, make_optimizer)
from repro.core.gossip import DenseComm
from repro.core.topology import complete, disconnected, ring


def quad_grad(params):
    return jax.tree_util.tree_map(lambda x: 2.0 * x, params)


def run_opt(opt, params, steps, gradf=quad_grad):
    state = opt.init(params)
    step = jax.jit(lambda s, p: opt.step(s, p, gradf(p)))
    for _ in range(steps):
        params, state = step(state, params)
    return params, state


def test_p1_complete_equals_centralized():
    """With p=1 and the complete graph, PD-SGDM's trajectory of the worker
    average equals single-worker momentum SGD (identical data)."""
    K = 4
    x0 = jnp.ones((K, 8)) * 3.0            # identical init
    opt = PDSGDM(PDSGDMConfig(eta=0.03, mu=0.9, p=1),
                 DenseComm(complete(K)))
    pk, _ = run_opt(opt, {"w": x0}, 30)

    ref = PDSGDM(PDSGDMConfig(eta=0.03, mu=0.9, p=1),
                 DenseComm(disconnected(1)))
    pr, _ = run_opt(ref, {"w": jnp.ones((1, 8)) * 3.0}, 30)
    np.testing.assert_allclose(np.asarray(pk["w"][0]),
                               np.asarray(pr["w"][0]), rtol=1e-5)


def test_momentum_matches_pytorch_semantics():
    """m ← μm + (g + λx); x ← x − ηm (paper Eq. 8 + PyTorch wd folding)."""
    opt = PDSGDM(PDSGDMConfig(eta=0.1, mu=0.9, p=10, weight_decay=0.01),
                 DenseComm(disconnected(1)))
    x = jnp.asarray([[2.0]])
    g = jnp.asarray([[0.5]])
    state = opt.init({"w": x})
    p1, s1 = opt.local_step(state, {"w": x}, {"w": g})
    m1 = 0.9 * 0.0 + (0.5 + 0.01 * 2.0)
    assert float(p1["w"][0, 0]) == pytest.approx(2.0 - 0.1 * m1)
    p2, s2 = opt.local_step(s1, p1, {"w": g})
    m2 = 0.9 * m1 + (0.5 + 0.01 * float(p1["w"][0, 0]))
    assert float(p2["w"][0, 0]) == pytest.approx(
        float(p1["w"][0, 0]) - 0.1 * m2, rel=1e-6)


def test_communication_happens_exactly_every_p():
    """Workers' params coincide right after a gossip round with the complete
    graph, and drift in between (mod(t+1, p) == 0 schedule)."""
    K, p = 4, 3
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=p), DenseComm(complete(K)))
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (K, 6))}

    def gradf(params):  # heterogeneous gradients -> drift between rounds
        return {"w": 2 * params["w"]
                + jnp.arange(K, dtype=jnp.float32)[:, None]}

    state = opt.init(params)
    step = jax.jit(lambda s, pp: opt.step(s, pp, gradf(pp)))
    for t in range(12):
        params, state = step(state, params)
        spread = float(jnp.abs(params["w"] - params["w"].mean(0)).max())
        if (t + 1) % p == 0:
            assert spread < 1e-6, (t, spread)
        else:
            assert spread > 1e-4, (t, spread)


def test_convergence_and_consensus_on_ring():
    K = 8
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=4), DenseComm(ring(K)))
    params = {"w": jnp.arange(K * 4, dtype=jnp.float32).reshape(K, 4)}
    params, _ = run_opt(opt, params, 200)
    assert float(jnp.abs(params["w"]).max()) < 1e-3


def test_schedule_decay():
    from repro.core.schedules import step_decay
    opt = PDSGDM(PDSGDMConfig(eta=1.0, mu=0.0, p=10,
                              lr_schedule=step_decay([5], 0.1)),
                 DenseComm(disconnected(1)))
    assert float(opt.config.lr(jnp.int32(0))) == pytest.approx(1.0)
    assert float(opt.config.lr(jnp.int32(5))) == pytest.approx(0.1)


def test_factory_names():
    comm = DenseComm(ring(4))
    for name in ["pd_sgdm", "cpd_sgdm", "c_sgdm", "d_sgd", "pd_sgd",
                 "choco_sgd"]:
        opt = make_optimizer(name, comm, eta=0.1)
        assert opt is not None
    with pytest.raises(ValueError):
        make_optimizer("adam", comm)


def test_invalid_config():
    with pytest.raises(ValueError):
        PDSGDM(PDSGDMConfig(mu=1.5), DenseComm(ring(2)))
    with pytest.raises(ValueError):
        PDSGDM(PDSGDMConfig(p=0), DenseComm(ring(2)))
