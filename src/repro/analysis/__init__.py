"""Static analysis of the round contract — no training step executed.

The performance story of this reproduction rests on structural invariants
(one gossip exchange per fused round, no host syncs in the hot path,
donation honored, schedule switching without retraces, accounted ≡ shipped
wire bytes).  This package machine-checks them at three levels:

jaxpr_check   — structural invariants on ``jax.make_jaxpr`` traces
hlo_check     — compiled-HLO invariants (donation aliasing, collective
                allowlist, wire bytes ≡ ``bytes_per_comm_round``)
retrace       — compilation-counting guard (schedules must not retrace)
astlint       — source-level repo rules (``tools/lint_repro.py`` CLI)
hlo_parse     — the post-SPMD HLO text parser the checks are built on
                (shared with ``launch.hlo_analysis``'s roofline path)
run           — the CLI driver CI executes: ``python -m repro.analysis.run``

Import note: this module stays import-light (no jax) so the lint CLI can
load ``astlint`` without initializing a backend.
"""
