"""paper-resnet20 — the paper's own CIFAR-10 experimental model (He '16).

Used by the faithful-reproduction benchmarks (Fig. 1-3): ring of 8 workers,
PD-SGDM/CPD-SGDM vs C-SGDM, momentum 0.9, weight decay 1e-4, sign
compression, consensus step 0.4.
"""
from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    # ModelCfg fields are mostly unused for the CNN; kept for registry shape.
    model = ModelCfg(
        name="paper-resnet20", arch_type="cnn",
        n_layers=20, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=10,
        source="He et al. 2016 (paper §5.1)",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="A"),
                  optim=OptimCfg(eta=0.1, mu=0.9, p=4, gamma=0.4,
                                 weight_decay=1e-4))
