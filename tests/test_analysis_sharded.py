"""Sharded static-analysis checks on the real 8-device mesh (subprocess,
slow tier): the contract holds at HEAD, and each seeded violation — an
injected all-gather in the round, a dropped donation — is caught."""
import os
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.analysis import hlo_check as hc
    from repro.analysis import jaxpr_check as jc
    from repro.analysis.hlo_parse import parse_collectives
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)

    def pack_for(opt_name, use_kernel=False, compressor="sign"):
        run = RunCfg(model=mcfg,
                     parallel=ParallelCfg(profile="A", remat="none"),
                     optim=OptimCfg(name=opt_name, p=2,
                                    compressor=compressor,
                                    use_kernel=use_kernel,
                                    kernel_interpret=True))
        mesh = make_debug_mesh(8, 1)
        return build_train(run, mesh, InputShape("t", 16, 8, "train"))
""")

_SCRIPT_GREEN = _PRELUDE + textwrap.dedent("""
    for opt_name, use_kernel in [("pd_sgdm", False), ("pd_sgdm", True),
                                 ("cpd_sgdm", False)]:
        pack = pack_for(opt_name, use_kernel)
        v = hc.check_sharded_round(pack, label=opt_name)
        jx = jax.make_jaxpr(pack.train_round)(
            pack.params_struct, pack.state_struct, pack.round_batch_struct)
        v += jc.check_no_host_callbacks(jx)
        v += jc.check_round_scan(jx, 2)
        v += jc.check_gossip_boundary(jx)
        assert v == [], (opt_name, use_kernel, v)
    print("SHARDED_CONTRACT_OK")
""")

_SCRIPT_SEEDED_ALLGATHER = _PRELUDE + textwrap.dedent("""
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map

    pack = pack_for("pd_sgdm")
    mesh = pack.layout.mesh
    ax = pack.layout.worker_axes[0]
    inner = pack.train_round

    def sabotaged(params, state, batches):
        params, state, losses = inner(params, state, batches)
        # the regression the allowlist exists for: an accidental
        # full-param all-gather riding the round
        leaf = jax.tree_util.tree_leaves(params)[0]
        extra = shard_map(
            lambda s: jax.lax.all_gather(s, ax),
            mesh=mesh, in_specs=P(ax),
            out_specs=P(None, ax))(leaf)
        losses = losses + extra.sum() * 0.0
        return params, state, losses

    txt = jax.jit(sabotaged).lower(
        pack.params_struct, pack.state_struct,
        pack.round_batch_struct).compile().as_text()
    stats = parse_collectives(txt)
    v = hc.check_collectives_allowed(stats)
    assert v, "seeded all-gather was not caught"
    assert any("all-gather" in s for s in v), v
    print("SEEDED_ALLGATHER_CAUGHT")
""")

_SCRIPT_SEEDED_NO_DONATE = _PRELUDE + textwrap.dedent("""
    pack = pack_for("pd_sgdm")
    # recompile the same round WITHOUT donate_argnums: the alias map
    # disappears and check_donation must flag it
    bare = jax.jit(pack.train_round.__wrapped__
                   if hasattr(pack.train_round, "__wrapped__")
                   else lambda p, s, b: pack.train_round(p, s, b))
    txt = bare.lower(pack.params_struct, pack.state_struct,
                     pack.round_batch_struct).compile().as_text()
    n = sum(len(jax.tree_util.tree_leaves(t))
            for t in (pack.params_struct, pack.state_struct))
    v = hc.check_donation(txt, n)
    assert v, "dropped donation was not caught"
    assert "donation" in v[0], v
    # and the donating executable passes
    good = hc.compile_round_text(pack)
    assert hc.check_donation(good, n) == []
    print("SEEDED_NO_DONATE_CAUGHT")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_contract_green_at_head():
    assert "SHARDED_CONTRACT_OK" in _run(_SCRIPT_GREEN)


@pytest.mark.slow
def test_seeded_allgather_caught():
    assert "SEEDED_ALLGATHER_CAUGHT" in _run(_SCRIPT_SEEDED_ALLGATHER)


@pytest.mark.slow
def test_seeded_dropped_donation_caught():
    assert "SEEDED_NO_DONATE_CAUGHT" in _run(_SCRIPT_SEEDED_NO_DONATE)
