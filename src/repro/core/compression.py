"""δ-contraction compression operators (paper Definition 1).

An operator ``Q`` is a δ-contraction if ``‖x − Q(x)‖² ≤ (1 − δ)‖x‖²`` for some
δ ∈ (0, 1].  CPD-SGDM (Alg. 2) sends ``q = Q(x_{t+1} − x̂_t)`` over the wire.

Everything here is pure ``jnp`` and doubles as the oracle for the Pallas
``sign_compress`` kernel (see ``repro.kernels.ref``).  The sign operator uses
*blockwise* scales and 8-signs-per-byte bit packing so that the simulated
semantics, the kernel semantics, and the bytes-on-wire accounting all agree.

All operators are deterministic given the PRNG key; stochastic ones (rand-k)
thread the key explicitly so every worker can reproduce its neighbour's
decompression without extra communication.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Compressor",
    "IdentityCompressor",
    "SignCompressor",
    "TopKCompressor",
    "RandKCompressor",
    "QSGDCompressor",
    "make_compressor",
    "sign_pack",
    "sign_unpack",
    "sign_wire_bytes",
    "contraction_ratio",
    "SIGN_BLOCK",
]

SIGN_BLOCK = 1024  # elements per scale block (multiple of 8 and of 128 lanes)


def _pad_to(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def sign_pack(x: jnp.ndarray, block: int = SIGN_BLOCK):
    """Blockwise scaled-sign compress + bit-pack.

    Returns ``(packed, scales)`` where ``packed`` is uint8 of shape
    (nblocks, block//8) holding sign bits (1 = non-negative) and ``scales``
    is float32 (nblocks,) = mean |x| over each block.  Padding contributes
    zeros (sign bit arbitrary; scale ignores pad via true-length masking).
    The true length ``n`` is static (``x.size``) so it is not returned —
    pass it to :func:`sign_unpack` (keeps this function vmap-able).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    flat, _ = _pad_to(flat, block)
    nb = flat.shape[0] // block
    blocks = flat.reshape(nb, block)
    # mask out padding in the scale so Q(x) matches the unpadded semantics
    idx = jnp.arange(nb * block).reshape(nb, block)
    valid = (idx < n).astype(jnp.float32)
    counts = jnp.maximum(valid.sum(axis=1), 1.0)
    scales = (jnp.abs(blocks) * valid).sum(axis=1) / counts
    bits = (blocks >= 0).astype(jnp.uint8).reshape(nb, block // 8, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8)).astype(jnp.uint8)
    packed = (bits * weights).sum(axis=-1).astype(jnp.uint8)
    return packed, scales.astype(jnp.float32)


def sign_unpack(packed: jnp.ndarray, scales: jnp.ndarray, n: int, shape, dtype,
                block: int = SIGN_BLOCK) -> jnp.ndarray:
    """Inverse of :func:`sign_pack`: Q(x) = scaleᵦ · sign(xᵦ)."""
    nb = packed.shape[0]
    bytes_ = packed.reshape(nb, block // 8, 1)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bytes_ >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.float32) * 2.0 - 1.0  # {0,1} -> {-1,+1}
    vals = signs.reshape(nb, block) * scales[:, None]
    flat = vals.reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def sign_wire_bytes(n: int, block: int = SIGN_BLOCK) -> int:
    """Exact packed-wire payload for an ``n``-element leaf: per block,
    ``block/8`` sign bytes + one f32 scale — *including* the padded tail
    block, which really crosses the wire (``(uint8, f32)`` pair per
    ``ppermute``).  This is the cost model behind
    ``CPDSGDM.bytes_per_comm_round`` on the packed path."""
    nblocks = -(-int(n) // block)
    return nblocks * (block // 8 + 4)


def contraction_ratio(x: jnp.ndarray, qx: jnp.ndarray) -> jnp.ndarray:
    """‖x − Q(x)‖² / ‖x‖² — must be ≤ 1 − δ (Definition 1)."""
    num = jnp.sum((x.astype(jnp.float32) - qx.astype(jnp.float32)) ** 2)
    den = jnp.maximum(jnp.sum(x.astype(jnp.float32) ** 2), 1e-30)
    return num / den


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base δ-contraction operator.

    ``apply(x, key)`` returns Q(x) with the same shape/dtype as x.
    ``wire_bits_per_element`` is the on-the-wire cost model used by the
    comm-cost accounting (Fig. 2 reproduction) and by the packed sharded
    exchange where applicable.
    """

    name: str = "identity"

    def apply(self, x: jnp.ndarray, key: jax.Array | None = None) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bits_per_element(self, dtype=jnp.float32) -> float:
        raise NotImplementedError

    def delta_lower_bound(self, d: int) -> float:
        """A guaranteed δ for dimension d (may be loose)."""
        raise NotImplementedError

    def wire_bytes(self, x: jnp.ndarray) -> int:
        return int(np.ceil(x.size * self.wire_bits_per_element(x.dtype) / 8.0))


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(Compressor):
    name: str = "identity"

    def apply(self, x, key=None):
        return x

    def wire_bits_per_element(self, dtype=jnp.float32):
        return float(jnp.dtype(dtype).itemsize * 8)

    def delta_lower_bound(self, d):
        return 1.0


@dataclasses.dataclass(frozen=True)
class SignCompressor(Compressor):
    """Blockwise scaled sign (paper's experimental choice, ref [5] signSGD).

    Q(x)ᵦ = mean(|xᵦ|) · sign(xᵦ) per block of ``block`` elements.
    δ = ‖x‖₁²/(d‖x‖₂²) ≥ 1/d per block; in practice ≈ 0.5–0.8 for dense grads.
    Wire cost: 1 bit/element + one f32 scale per block.
    """

    name: str = "sign"
    block: int = SIGN_BLOCK

    def apply(self, x, key=None):
        packed, scales = sign_pack(x, self.block)
        return sign_unpack(packed, scales, x.size, x.shape, x.dtype, self.block)

    def wire_bits_per_element(self, dtype=jnp.float32):
        return 1.0 + 32.0 / self.block

    def delta_lower_bound(self, d):
        return 1.0 / min(d, self.block)


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Keep the top ``fraction`` of entries by magnitude.  δ = k/d exactly."""

    name: str = "topk"
    fraction: float = 0.01

    def _k(self, d: int) -> int:
        return max(1, int(np.ceil(self.fraction * d)))

    def apply(self, x, key=None):
        flat = x.reshape(-1)
        k = self._k(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        mask = jnp.zeros_like(flat).at[idx].set(1.0)
        return (flat * mask).reshape(x.shape)

    def wire_bits_per_element(self, dtype=jnp.float32):
        # k values + k int32 indices
        return self.fraction * (jnp.dtype(dtype).itemsize * 8 + 32)

    def delta_lower_bound(self, d):
        return self._k(d) / d


@dataclasses.dataclass(frozen=True)
class RandKCompressor(Compressor):
    """Keep a uniformly random fraction (unscaled).  E‖x−Q‖² = (1−k/d)‖x‖²."""

    name: str = "randk"
    fraction: float = 0.01

    def apply(self, x, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        flat = x.reshape(-1)
        d = flat.shape[0]
        k = max(1, int(np.ceil(self.fraction * d)))
        idx = jax.random.choice(key, d, shape=(k,), replace=False)
        mask = jnp.zeros_like(flat).at[idx].set(1.0)
        return (flat * mask).reshape(x.shape)

    def wire_bits_per_element(self, dtype=jnp.float32):
        # indices reproducible from the shared key: only k values on the wire
        return self.fraction * jnp.dtype(dtype).itemsize * 8

    def delta_lower_bound(self, d):
        return max(1.0 / d, self.fraction)  # in expectation


@dataclasses.dataclass(frozen=True)
class QSGDCompressor:
    """QSGD-style s-level stochastic quantization, norm-scaled (ref [3]).

    Deterministic rounding variant (nearest level) so it is a contraction
    (stochastic QSGD is unbiased but not a contraction without scaling).
    """

    name: str = "qsgd"
    levels: int = 16  # 4-bit

    def apply(self, x, key=None):
        flat = x.reshape(-1).astype(jnp.float32)
        norm = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-30)
        q = jnp.round(flat / norm * self.levels) / self.levels * norm
        return q.reshape(x.shape).astype(x.dtype)

    def wire_bits_per_element(self, dtype=jnp.float32):
        return float(np.ceil(np.log2(2 * self.levels + 1)))

    def delta_lower_bound(self, d):
        # |x - q| <= norm/(2s) elementwise -> ratio <= d/(4 s^2) … loose;
        # guarantee only the trivial bound here.
        return 1.0 / d

    def wire_bytes(self, x: jnp.ndarray) -> int:
        return int(np.ceil(x.size * self.wire_bits_per_element(x.dtype) / 8.0))


def make_compressor(name: str, **kw) -> Compressor:
    name = name.lower()
    if name in ("identity", "none", "full"):
        return IdentityCompressor()
    if name == "sign":
        return SignCompressor(**kw)
    if name == "topk":
        return TopKCompressor(**kw)
    if name == "randk":
        return RandKCompressor(**kw)
    if name == "qsgd":
        return QSGDCompressor(**kw)
    raise ValueError(f"unknown compressor {name!r}")
