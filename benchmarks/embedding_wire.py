"""Embedding sparse-wire benchmark: bytes on the wire as a function of
rows *touched*, not table size.

The power-law (Zipf) embedding workload (``repro.data.synthetic.
embed_batch``) looks up ``batch`` rows of an ``n_rows × dim`` table per
worker per step; the gradient — and so the CPD drift a sparse wire ships —
is non-zero only on the touched rows.  Three measurements:

  * batch sweep (fixed table): the shipped-row budget is set from the
    *measured* touched kernel rows (distinct ids → distinct 1024-lane
    blocks of the flattened leaf), so ``bytes_per_leaf`` must grow
    monotonically with the batch.
  * table sweep (fixed budget): ``wire_bytes`` at the same row budget
    across 4k/16k/64k-row tables must be *identical* — the codec's whole
    point.  The measured touched-block count per table is reported
    alongside (it stays within the budget).
  * a fused CPD-SGDM round timed end-to-end on the embedding tree with
    the sparse codec (embedding-style scatter gradients, zero weight
    decay so the drift stays on the touched support).

All byte columns are payload arithmetic — exact on any host; the claim
row derives ``bytes_scale_with_touched`` (monotone in batch AND flat in
table size) and ``sparse_vs_dense_x`` (reduction vs a dense f32 wire at a
1% touch fraction), which ``tools/bench_compare.py`` gates against the
committed ``BENCH_embedding.json``.

``BENCH_REPEATS`` / ``BENCH_ROUNDS`` trim the timing loop for CI smoke
runs; byte columns are measurement-free and stay exact.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import CPDSGDM, CPDSGDMConfig, make_codec
from repro.core.compression import SparseRowsCompressor
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.data.synthetic import EmbedStreamCfg, embed_batch
from repro.kernels import LANE

K = 4
P = 4
DIM = 64                 # table rows per kernel block = LANE // DIM = 16
REPEATS = int(os.environ.get("BENCH_REPEATS", "3"))
ROUNDS = int(os.environ.get("BENCH_ROUNDS", "8"))


def _touched_blocks(cfg: EmbedStreamCfg, step: int = 0) -> int:
    """Distinct 1024-lane blocks of the flattened (n_rows·dim,) leaf that
    one worker's batch touches.  Ids are passed through a fixed random
    permutation first — real tables are not rank-sorted, so the Zipf head
    must not collapse into one block for free."""
    ids = np.asarray(embed_batch(cfg, step)["ids"][0])
    perm = np.asarray(jax.random.permutation(
        jax.random.PRNGKey(99), cfg.n_rows))
    blocks = (perm[ids] * cfg.dim) // LANE
    return max(len(np.unique(blocks)), 1)


def _codec(budget_rows: int):
    return make_codec(SparseRowsCompressor(max_rows=int(budget_rows)))


def _time_sparse_round(n_rows: int, budget: int) -> float:
    """Fused CPD rounds/sec with the sparse wire on the embedding tree."""
    comp = SparseRowsCompressor(max_rows=int(budget))
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=P, gamma=0.4,
                                weight_decay=0.0),
                  DenseComm(ring(K)), comp)
    cfg = EmbedStreamCfg(n_rows=n_rows, dim=DIM, batch=64, n_workers=K,
                         seed=0)
    params = {"table": jax.random.normal(
        jax.random.PRNGKey(1), (K, n_rows, DIM)) * 0.1}
    batches = jnp.stack([embed_batch(cfg, t)["ids"] for t in range(P)])

    def grads_fn(p, ids):
        # embedding-style gradient: non-zero exactly on the looked-up rows
        g = jax.vmap(lambda x, i: jnp.zeros_like(x).at[i].add(0.01))(
            p["table"], ids)
        return jnp.zeros(()), {"table": g}

    round_fn = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    state = opt.init(params)

    def run():
        p_, s_ = params, state
        for _ in range(ROUNDS):
            p_, s_, _losses = round_fn(s_, p_, batches)
        jax.block_until_ready(p_)
    run()
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return ROUNDS / best


def main():
    results = {}

    # --- batch sweep: budget follows measured touched rows -------------
    n_rows = 16384
    leaf = n_rows * DIM
    dense_f32 = 4 * leaf
    batch_bytes = []
    for batch in (16, 64, 256):
        cfg = EmbedStreamCfg(n_rows=n_rows, dim=DIM, batch=batch,
                             n_workers=K, seed=0)
        tb = _touched_blocks(cfg)
        bpl = _codec(tb).wire_bytes(leaf)
        batch_bytes.append(bpl)
        results[f"batch{batch}"] = (tb, bpl)
        csv_row(f"embedding/batch{batch}", 0.0,
                f"touched_blocks={tb};bytes_per_leaf={bpl};"
                f"dense_f32={dense_f32};x_dense={dense_f32 / bpl:.2f}")

    # --- table sweep: bytes flat at a fixed budget ---------------------
    budget = 64
    table_bytes = []
    for rows in (4096, 16384, 65536):
        cfg = EmbedStreamCfg(n_rows=rows, dim=DIM, batch=64,
                             n_workers=K, seed=0)
        tb = _touched_blocks(cfg)
        bpl = _codec(budget).wire_bytes(rows * DIM)
        table_bytes.append(bpl)
        results[f"table{rows}"] = (tb, bpl)
        csv_row(f"embedding/table{rows}", 0.0,
                f"touched_blocks={tb};budget={budget};bytes_per_leaf={bpl};"
                f"dense_f32={4 * rows * DIM}")

    # --- fused-round timing (host-dependent; not gated) ----------------
    rps = _time_sparse_round(4096, budget=64)
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=P, gamma=0.4,
                                weight_decay=0.0),
                  DenseComm(ring(K)),
                  SparseRowsCompressor(max_rows=64))
    bpr = opt.bytes_per_comm_round(
        {"table": jax.ShapeDtypeStruct((4096, DIM), jnp.float32)})
    csv_row("embedding/round_sparse", 1e6 / rps,
            f"rounds_per_s={rps:.2f};bytes_per_round={bpr}")

    # --- claim row (gated by tools/bench_compare.py) -------------------
    monotone = (batch_bytes == sorted(batch_bytes)
                and batch_bytes[-1] > batch_bytes[0])
    flat = len(set(table_bytes)) == 1
    # dense-wire reduction at a 1% touch fraction of the biggest table
    big = 65536 * DIM
    nb = -(-big // LANE)
    one_pct = -(-nb // 100)
    x_dense = (4 * big) / _codec(one_pct).wire_bytes(big)
    ok = 1.0 if (monotone and flat and x_dense >= 4.0) else 0.0
    results["claim"] = (ok, x_dense)
    csv_row("embedding/claim_bytes_scale", 0.0,
            f"bytes_scale_with_touched={ok};"
            f"sparse_vs_dense_x={x_dense:.2f};"
            f"bytes_flat_in_table={1.0 if flat else 0.0}")
    return results


def _write_json(results) -> str:
    from benchmarks.common import collected_rows
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_embedding.json")
    rows = [r for r in collected_rows()
            if r["name"].startswith("embedding/")]
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": ["embedding"],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = main()
    print(f"bench_json,0.0,path={os.path.relpath(_write_json(res))}")
