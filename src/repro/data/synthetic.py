"""Synthetic data streams (offline container: no real corpora).

Two generators, both deterministic in (seed, worker, step) so every run —
and every worker — is exactly reproducible:

* ``lm_stream``: Zipf-ish token sequences with a planted bigram structure so
  the LM loss has learnable signal (loss decreases well below uniform
  entropy).
* ``classification_stream``: CIFAR-shaped mixture-of-Gaussians images for
  the ResNet20 paper-reproduction experiments.  Per-worker heterogeneity
  (non-IID splits) is controlled by ``dirichlet_alpha`` — decentralized
  methods are sensitive to it, so Fig. 1-3 use the paper-like IID setting
  and the ablations exercise non-IID.
* ``embed_batch``: power-law (Zipf) embedding-row lookups with a planted
  regression table — the sparse-wire regime, where each step's gradient
  touches a handful of rows of a huge table and the interesting quantity
  is bytes-on-the-wire as a function of rows *touched*, not table size.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LMStreamCfg", "lm_batch", "ClassStreamCfg", "class_batch",
           "worker_class_probs", "EmbedStreamCfg", "embed_batch",
           "touched_row_mask"]


@dataclasses.dataclass(frozen=True)
class LMStreamCfg:
    vocab: int
    seq_len: int
    batch: int           # per worker
    n_workers: int
    seed: int = 0
    n_clusters: int = 64  # planted bigram clusters (learnable structure)


def lm_batch(cfg: LMStreamCfg, step: int):
    """(n_workers, batch, seq) tokens + next-token labels."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    kw = jax.random.split(key, cfg.n_workers)

    def one_worker(k):
        k1, k2 = jax.random.split(k)
        # markov chain over clusters; token = cluster base + noise
        n_c = cfg.n_clusters
        span = max(cfg.vocab // n_c, 1)
        clusters = jax.random.randint(k1, (cfg.batch, cfg.seq_len + 1),
                                      0, n_c)
        # make it predictable: next cluster = (cluster + 1) % n_c w.p. .8
        stay = jax.random.bernoulli(k2, 0.8,
                                    (cfg.batch, cfg.seq_len + 1))
        base = clusters[:, :1]
        idx = jnp.arange(cfg.seq_len + 1)[None, :]
        chain = (base + idx) % n_c
        clusters = jnp.where(stay, chain, clusters)
        noise = jax.random.randint(jax.random.fold_in(k, 7),
                                   (cfg.batch, cfg.seq_len + 1), 0, span)
        toks = jnp.minimum(clusters * span + noise, cfg.vocab - 1)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}

    return jax.vmap(one_worker)(kw)


@dataclasses.dataclass(frozen=True)
class ClassStreamCfg:
    n_classes: int = 10
    image: tuple = (32, 32, 3)
    batch: int = 16              # per worker (paper: 16 for CIFAR-10)
    n_workers: int = 8
    seed: int = 0
    noise: float = 0.8
    dirichlet_alpha: Optional[float] = None  # None = IID


def _class_means(cfg: ClassStreamCfg):
    key = jax.random.PRNGKey(cfg.seed + 1000)
    return jax.random.normal(key, (cfg.n_classes,) + cfg.image) * 1.5


def worker_class_probs(cfg: ClassStreamCfg) -> jnp.ndarray:
    """(n_workers, n_classes) per-worker label marginal.

    The Dirichlet(α) partitioner: each worker's class distribution is one
    draw from Dirichlet(α·1) — small α concentrates mass on few classes
    (strongly non-IID), large α approaches uniform, ``alpha=None`` is the
    exact uniform (IID) marginal.  Deterministic in ``cfg.seed`` alone —
    the partition is fixed for a run, only the sampled batches vary with
    the step.
    """
    if cfg.dirichlet_alpha is not None:
        dkey = jax.random.PRNGKey(cfg.seed + 2000)
        return jax.random.dirichlet(
            dkey, jnp.full((cfg.n_classes,), cfg.dirichlet_alpha),
            (cfg.n_workers,))
    return jnp.full((cfg.n_workers, cfg.n_classes), 1.0 / cfg.n_classes)


def class_batch(cfg: ClassStreamCfg, step: int):
    """(n_workers, batch, 32, 32, 3) images + labels."""
    means = _class_means(cfg)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    kw = jax.random.split(key, cfg.n_workers)
    probs = worker_class_probs(cfg)

    def one_worker(k, p):
        k1, k2 = jax.random.split(k)
        labels = jax.random.categorical(
            k1, jnp.log(p + 1e-9)[None, :].repeat(cfg.batch, 0))
        imgs = means[labels] + cfg.noise * jax.random.normal(
            k2, (cfg.batch,) + cfg.image)
        return {"images": imgs, "labels": labels.astype(jnp.int32)}

    return jax.vmap(one_worker)(kw, probs)


@dataclasses.dataclass(frozen=True)
class EmbedStreamCfg:
    """Zipf embedding lookups: ``batch`` row ids per worker per step, row
    popularity ∝ rank^(-zipf_a) — a few hot rows take most of the traffic,
    so each step touches far fewer distinct rows than the table holds."""
    n_rows: int = 16384      # embedding-table rows
    dim: int = 64            # embedding dimension
    batch: int = 64          # lookups per worker per step
    n_workers: int = 8
    seed: int = 0
    zipf_a: float = 1.1      # power-law exponent over row ranks
    noise: float = 0.1       # target observation noise


def _zipf_logits(cfg: EmbedStreamCfg) -> jnp.ndarray:
    ranks = jnp.arange(1, cfg.n_rows + 1, dtype=jnp.float32)
    return -cfg.zipf_a * jnp.log(ranks)


def _planted_embed_table(cfg: EmbedStreamCfg) -> jnp.ndarray:
    """The ground-truth table the regression targets are read from —
    deterministic in ``cfg.seed`` alone (fixed for a run)."""
    key = jax.random.PRNGKey(cfg.seed + 3000)
    return jax.random.normal(key, (cfg.n_rows, cfg.dim)) * 0.5


def embed_batch(cfg: EmbedStreamCfg, step: int):
    """(n_workers, batch) int32 row ids + (n_workers, batch) f32 targets.

    target = Σ_dim planted_table[id] + noise: a linear readout of the true
    row, so an embedding-table regression has learnable signal and its
    gradient w.r.t. the table is non-zero exactly on the touched rows.
    Deterministic in (seed, worker, step), like ``lm_batch``.
    """
    table = _planted_embed_table(cfg)
    logits = _zipf_logits(cfg)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    kw = jax.random.split(key, cfg.n_workers)

    def one_worker(k):
        k1, k2 = jax.random.split(k)
        ids = jax.random.categorical(k1, logits, shape=(cfg.batch,))
        targets = (jnp.sum(table[ids], axis=-1)
                   + cfg.noise * jax.random.normal(k2, (cfg.batch,)))
        return {"ids": ids.astype(jnp.int32),
                "targets": targets.astype(jnp.float32)}

    return jax.vmap(one_worker)(kw)


def touched_row_mask(ids: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """(n_rows,) bool: the table rows a batch of lookups touches — exactly
    the rows an embedding gradient (and so the sparse wire) is non-zero
    on."""
    return jnp.zeros((n_rows,), bool).at[ids.reshape(-1)].set(True)
