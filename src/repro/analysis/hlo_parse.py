"""Post-SPMD HLO text parsing: collectives, loop nesting, donation aliases.

``cost_analysis`` gives per-device FLOPs / bytes-accessed but no collective
traffic, so we parse the compiled (post-partitioning) HLO text and sum the
operand sizes of every collective op, converted to effective bytes-on-wire
per device with the standard ring-algorithm factors.

This module is the single home of that parser; ``repro.launch.hlo_analysis``
re-exports it for the roofline path and ``repro.analysis.hlo_check`` builds
the round-contract assertions on top of it.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

__all__ = ["CollectiveCall", "CollectiveStats", "parse_collectives",
           "computation_loop_depths", "donated_aliases"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass(frozen=True)
class CollectiveCall:
    """One collective op site (per compiled call, before loop multiplicity)."""
    op: str
    result_bytes: int      # operand/result payload of one execution
    wire_bytes: float      # ring-algorithm effective bytes on the wire
    group: int             # replica-group size
    mult: int              # loop-trip multiplier applied by parse_collectives
    line: str              # the (truncated) HLO line, for reporting


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]     # per device, per call, summed
    wire_bytes: Dict[str, float]     # effective ring-algorithm bytes/device
    lines: List[str]
    calls: List[CollectiveCall] = dataclasses.field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


# computation definition header; param lists may contain nested parens
# (tuple-typed while-body params), so only anchor on name + '(' + '... {'
_COMP_DEF_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"%?([\w\.\-]+)")


def computation_loop_depths(hlo_text: str) -> Dict[str, int]:
    """while-nesting depth of every computation (ENTRY = 0).

    A collective inside a scan body executes once *per trip*; the caller
    supplies the known trip counts per depth (our scans: train-round steps,
    layer repeats) to recover true per-call traffic.
    """
    comp_lines: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_DEF_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comp_lines[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comp_lines[cur].append(line)

    # edges: computation -> (callee, via_while)
    edges: Dict[str, List] = {}
    for name, lines in comp_lines.items():
        edges[name] = []
        for line in lines:
            wm = _WHILE_RE.search(line)
            body = wm.group(1) if wm else None
            for callee in _CALL_RE.findall(line):
                if callee in comp_lines:
                    edges[name].append((callee, callee == body))

    depths = {entry: 0} if entry else {}
    stack = [entry] if entry else []
    while stack:
        c = stack.pop()
        for callee, via_while in edges.get(c, []):
            d = depths[c] + (1 if via_while else 0)
            if callee not in depths or d > depths[callee]:
                depths[callee] = d
                stack.append(callee)
    return depths


# the deprecated private name, kept so older call sites keep working
_computation_loop_depths = computation_loop_depths


def parse_collectives(hlo_text: str, loop_trips=()) -> CollectiveStats:
    """Sum collective traffic; ops at while-depth d are multiplied by
    prod(loop_trips[:d]) (deeper unknown loops contribute ×1)."""
    counts: Dict[str, int] = {}
    rbytes: Dict[str, int] = {}
    wbytes: Dict[str, float] = {}
    lines: List[str] = []
    calls: List[CollectiveCall] = []
    depths = computation_loop_depths(hlo_text) if loop_trips else {}

    def multiplier(depth: int) -> int:
        m = 1
        for t in list(loop_trips)[:depth]:
            m *= int(t)
        return m

    cur_comp = None
    for line in hlo_text.splitlines():
        dm = _COMP_DEF_RE.match(line.strip())
        if dm and line.rstrip().endswith("{"):
            cur_comp = dm.group(1)
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # async pairs: count -start only (the -done carries the same tensor)
        if "-done(" in line:
            continue
        size = _type_bytes(m.group("type"))
        n = _group_size(line)
        mult = multiplier(depths.get(cur_comp, 0)) if loop_trips else 1
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op == "all-gather":
            wire = (n - 1) / n * size          # size = gathered result
        elif op == "reduce-scatter":
            wire = (n - 1) * size              # size = scattered result
        elif op == "all-to-all":
            wire = (n - 1) / n * size
        else:                                   # collective-permute
            wire = float(size)
        counts[op] = counts.get(op, 0) + mult
        rbytes[op] = rbytes.get(op, 0) + size * mult
        wbytes[op] = wbytes.get(op, 0.0) + wire * mult
        lines.append(f"x{mult} " + line.strip()[:180])
        calls.append(CollectiveCall(op=op, result_bytes=size, wire_bytes=wire,
                                    group=n, mult=mult,
                                    line=line.strip()[:180]))
    return CollectiveStats(counts, rbytes, wbytes, lines, calls)


# donation: the HloModule header carries the honoured aliases, e.g.
#   input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }
# one "(argno, {tuple-index...}, kind)" entry per aliased (donated) buffer.
_ALIAS_ENTRY_RE = re.compile(
    r"\{(?P<out>[0-9, ]*)\}:\s*\((?P<arg>\d+),\s*\{(?P<idx>[0-9, ]*)\}"
    r"(?:,\s*(?P<kind>[\w-]+))?\)")


def donated_aliases(hlo_text: str) -> List[dict]:
    """Parse the honoured input→output aliases from the module header.

    Returns one dict per alias entry: ``{"output_index": tuple,
    "param_number": int, "param_index": tuple, "kind": str}``.  An empty
    list means XLA honoured **no** donation — the check that catches a
    dropped ``donate_argnums``.
    """
    header = next((l for l in hlo_text.splitlines()
                   if l.startswith("HloModule")), "")
    m = re.search(r"input_output_alias=\{", header)
    if not m:
        return []
    # the alias map is brace-nested; scan to the matching close brace
    depth, i = 0, m.end() - 1
    while i < len(header):
        if header[i] == "{":
            depth += 1
        elif header[i] == "}":
            depth -= 1
            if depth == 0:
                break
        i += 1
    block = header[m.end():i]
    out = []
    for em in _ALIAS_ENTRY_RE.finditer(block):
        to_tuple = lambda s: tuple(int(x) for x in s.split(",") if x.strip())
        out.append({"output_index": to_tuple(em.group("out")),
                    "param_number": int(em.group("arg")),
                    "param_index": to_tuple(em.group("idx")),
                    "kind": em.group("kind") or "may-alias"})
    return out
