"""Round-engine micro-benchmark: per-step dispatch vs fused-round scan.

The *per-step* driver is the seed implementation: one jitted step per
Python iteration (the gossip hidden behind a traced ``lax.cond``) and a
host sync on the loss every step.  The *fused* driver is the round engine
the trainers now use: one jitted ``lax.scan`` over whole rounds with a
single host sync per log block.  The model is deliberately small so
dispatch/sync overhead — the thing the round engine removes — dominates.

Derived: steps/sec for both drivers and the fused/per-step speedup at each
communication period p, plus a time-varying-topology variant (one-peer
exponential schedule) that must run the same fused path at the same rate —
the per-round W is selected *inside* the jitted scan, so the schedule may
not add dispatch overhead.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import make_optimizer
from repro.core.gossip import DenseComm
from repro.core.topology import one_peer_exponential_schedule, ring
from repro.train.trainer import SimTrainer

K, D, STEPS, REPEATS = 8, 64, 512, 3


def loss_fn(params, batch):
    h = jnp.tanh(batch @ params["w1"])
    return 0.5 * jnp.mean((h @ params["w2"] - batch) ** 2), {}


def stacked_params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    one = {"w1": jax.random.normal(k1, (D, D)) * 0.1,
           "w2": jax.random.normal(k2, (D, D)) * 0.1}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), one)


_BATCHES = None


def batch_fn(t):
    return _BATCHES[t]


def _precompute_batches(steps):
    """Host-side batch generation stays outside the clock for both drivers."""
    global _BATCHES
    _BATCHES = [
        jax.device_put(jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(5), t), (K, 4, D)))
        for t in range(steps)]
    jax.block_until_ready(_BATCHES)


def _best_of(run, steps):
    """Compile on the first call, then report the best of REPEATS — the
    shared-CPU container is noisy and we want the dispatch floor."""
    run()
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return steps / best


def _time_per_step(opt, steps=STEPS):
    """Seed-style loop: jitted opt.step per iteration + float(loss) sync."""
    grad = jax.vmap(jax.value_and_grad(lambda p, b: loss_fn(p, b)[0]))

    def step_fn(state, params, batch):
        losses, grads = grad(params, batch)
        params, state = opt.step(state, params, grads)
        return params, state, losses.mean()

    stepj = jax.jit(step_fn)

    def run():
        params = stacked_params()
        state = opt.init(params)
        for t in range(steps):
            params, state, loss = stepj(state, params, batch_fn(t))
            float(loss)                        # the per-step host sync
    return _best_of(run, steps)


def _time_fused(opt, steps=STEPS):
    """Round engine: SimTrainer block scan, one host sync per log block."""
    trainer = SimTrainer(loss_fn, opt)

    def run():
        trainer.train(stacked_params(), batch_fn, steps, log_every=steps,
                      verbose=False)
    return _best_of(run, steps)


def main():
    results = {}
    _precompute_batches(STEPS)
    for p in [1, 4, 8, 16]:
        opt = make_optimizer("pd_sgdm", DenseComm(ring(K)), eta=0.05,
                             mu=0.9, p=p)
        per_step = _time_per_step(opt)
        fused = _time_fused(opt)
        speedup = fused / per_step
        results[p] = (per_step, fused, speedup)
        csv_row(f"round_engine/per_step_p{p}", 1e6 / per_step,
                f"steps_per_s={per_step:.1f}")
        csv_row(f"round_engine/fused_round_p{p}", 1e6 / fused,
                f"steps_per_s={fused:.1f};speedup_vs_per_step={speedup:.2f}")
    best = max(v[2] for pp, v in results.items() if pp >= 4)
    csv_row("round_engine/max_speedup_p_ge_4", 0.0, f"speedup={best:.2f}")

    # scheduled topology through the identical fused path: round-indexed
    # (T, K, K) weight select inside the scan, no retrace, no extra dispatch
    opt_sched = make_optimizer(
        "pd_sgdm", DenseComm(one_peer_exponential_schedule(K)),
        eta=0.05, mu=0.9, p=4)
    fused_sched = _time_fused(opt_sched)
    static_fused = results[4][1]
    ratio = fused_sched / static_fused
    csv_row("round_engine/fused_round_sched_p4", 1e6 / fused_sched,
            f"steps_per_s={fused_sched:.1f};vs_static_ring={ratio:.2f}")
    results["sched"] = (None, fused_sched, ratio)
    return results


if __name__ == "__main__":
    main()
