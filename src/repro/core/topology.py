"""Gossip topologies and mixing matrices (paper §3.2, Assumption 1).

A topology yields a symmetric doubly-stochastic mixing matrix ``W`` over K
workers.  ``W 1 = 1``, ``1ᵀ W = 1ᵀ``, eigenvalues ``1 = λ₁ ≥ |λ₂| ≥ ...``;
the spectral gap ``ρ = 1 - |λ₂|`` controls the topology term in Theorems 1/2.

Besides the dense matrix (used by the single-process simulation backend and
by the tests), each topology exposes its *neighbour structure*
(``edges(k) -> [(offset_or_index, weight), ...]``) which the sharded backend
turns into ``jax.lax.ppermute`` schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "ring",
    "torus",
    "complete",
    "exponential",
    "disconnected",
    "spectral_gap",
    "is_doubly_stochastic",
    "make_topology",
]


def is_doubly_stochastic(W: np.ndarray, atol: float = 1e-8) -> bool:
    """Check Assumption 1: symmetric, rows/cols sum to one, entries in [0,1]."""
    W = np.asarray(W, dtype=np.float64)
    if W.ndim != 2 or W.shape[0] != W.shape[1]:
        return False
    ones = np.ones(W.shape[0])
    return (
        np.allclose(W, W.T, atol=atol)
        and np.allclose(W @ ones, ones, atol=atol)
        and np.allclose(ones @ W, ones, atol=atol)
        and bool(np.all(W >= -atol))
        and bool(np.all(W <= 1 + atol))
    )


def spectral_gap(W: np.ndarray) -> float:
    """ρ = 1 - |λ₂|  (Lemma 1).  ρ ∈ (0, 1] for connected non-bipartite W."""
    W = np.asarray(W, dtype=np.float64)
    eig = np.sort(np.abs(np.linalg.eigvalsh(W)))[::-1]
    if len(eig) == 1:
        return 1.0
    return float(1.0 - eig[1])


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph over ``n_workers`` with doubly-stochastic weights.

    Attributes:
      name: identifier ("ring", "torus", ...).
      W: dense (K, K) mixing matrix, numpy float64.
      shifts: for shift-structured (circulant / Kronecker-of-circulant)
        topologies, the list of (axis, shift, weight) triples describing the
        neighbour exchange pattern used by the ppermute backend.  ``axis``
        indexes into ``axis_sizes``.  ``shift`` of 0 denotes the self weight.
      axis_sizes: worker-grid shape whose product is K (1-d for ring, 2-d
        for torus). The sharded backend maps these onto mesh axes.
    """

    name: str
    W: np.ndarray
    shifts: tuple  # ((axis, shift, weight), ...)
    axis_sizes: tuple

    @property
    def n_workers(self) -> int:
        return int(self.W.shape[0])

    @property
    def rho(self) -> float:
        return spectral_gap(self.W)

    @property
    def degree(self) -> int:
        """Number of non-self neighbours per worker (bytes-on-wire driver)."""
        return sum(1 for (_, s, _) in self.shifts if s != 0)

    def self_weight(self) -> float:
        return float(self.W[0, 0])

    def validate(self) -> None:
        if not is_doubly_stochastic(self.W):
            raise ValueError(f"topology {self.name}: W is not doubly stochastic")
        if int(np.prod(self.axis_sizes)) != self.n_workers:
            raise ValueError(f"topology {self.name}: axis_sizes {self.axis_sizes} != K")


def _circulant(K: int, offsets_weights: dict) -> np.ndarray:
    W = np.zeros((K, K), dtype=np.float64)
    for off, w in offsets_weights.items():
        for i in range(K):
            W[i, (i + off) % K] += w
    return W


def ring(K: int, self_weight: float | None = None) -> Topology:
    """Ring of K workers (the paper's experimental topology, K=8).

    Default weights: 1/3 self, 1/3 each neighbour (Metropolis for a cycle);
    for K=2 the ring degenerates to a pair-average; K=1 is identity.
    """
    if K == 1:
        return Topology("ring", np.ones((1, 1)), ((0, 0, 1.0),), (1,))
    if K == 2:
        W = np.array([[0.5, 0.5], [0.5, 0.5]])
        return Topology("ring", W, ((0, 0, 0.5), (0, 1, 0.5)), (2,))
    ws = 1.0 / 3.0 if self_weight is None else float(self_weight)
    wn = (1.0 - ws) / 2.0
    W = _circulant(K, {0: ws, 1: wn, -1: wn})
    shifts = ((0, 0, ws), (0, 1, wn), (0, -1, wn))
    return Topology("ring", W, shifts, (K,))


def torus(shape: Sequence[int], self_weight: float | None = None) -> Topology:
    """Kronecker torus W = W_ring(shape[0]) ⊗ … — hierarchical pod×ring mixing.

    Applied by the sharded backend as sequential per-axis ring mixings (the
    Kronecker structure factorizes); ρ(W) = 1 - max_i |λ₂(W_i)| ... computed
    exactly from the dense product here.
    """
    shape = tuple(int(s) for s in shape)
    mats = [ring(s, self_weight).W for s in shape]
    W = mats[0]
    for M in mats[1:]:
        W = np.kron(W, M)
    shifts = []
    for ax, s in enumerate(shape):
        sub = ring(s, self_weight)
        for (_, sh, w) in sub.shifts:
            shifts.append((ax, sh, w))
    return Topology("torus", W, tuple(shifts), shape)


def complete(K: int) -> Topology:
    """Fully connected: W = (1/K) 11ᵀ — gossip == exact global average.

    Used by tests to show PD-SGDM(p=1, complete) ≡ centralized momentum SGD.
    """
    W = np.full((K, K), 1.0 / K)
    shifts = tuple((0, s, 1.0 / K) for s in range(K))
    return Topology("complete", W, shifts, (K,))


def exponential(K: int) -> Topology:
    """One-peer-per-power-of-two expander (hypercube-like), good ρ at low degree."""
    offs = [0]
    s = 1
    while s < K:
        offs.append(s)
        offs.append(-s)
        s *= 2
    w = 1.0 / len(offs)
    W = _circulant(K, {o: w for o in offs})
    # symmetrize (offsets come in ± pairs except when 2s == K aliases)
    W = (W + W.T) / 2.0
    shifts = tuple((0, o, w) for o in offs)
    top = Topology("exponential", W, shifts, (K,))
    return top


def disconnected(K: int) -> Topology:
    """W = I: no communication at all (lower bound / ablation)."""
    return Topology("disconnected", np.eye(K), ((0, 0, 1.0),), (K,))


def make_topology(name: str, worker_grid: Sequence[int]) -> Topology:
    """Build topology by name for a worker grid (product = K)."""
    worker_grid = tuple(int(g) for g in worker_grid)
    K = int(np.prod(worker_grid)) if worker_grid else 1
    if name == "ring":
        return ring(K)
    if name == "torus":
        grid = worker_grid if len(worker_grid) > 1 else (K,)
        return torus(grid)
    if name == "complete":
        return complete(K)
    if name == "exponential":
        return exponential(K)
    if name == "disconnected":
        return disconnected(K)
    raise ValueError(f"unknown topology {name!r}")
