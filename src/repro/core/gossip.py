"""Gossip communication backends.

Two implementations of the same mixing semantics ``x⁽ᵏ⁾ ← Σⱼ w_kj x⁽ʲ⁾``:

* :class:`DenseComm` — single-process simulation.  Every pytree leaf carries a
  leading worker dimension of size K and mixing is an einsum with the dense
  mixing matrix ``W``.  This is the mathematically-literal form of the paper's
  Eq. (4)/(17) and is what the convergence experiments and unit tests run on
  (CPU, any K).

* :class:`ShardedComm` — production backend, used *inside* ``shard_map``.
  Each device holds its worker's (model-parallel shard of the) parameters
  without a worker dimension; neighbour exchange is ``jax.lax.ppermute``
  (HLO ``collective-permute``) along the named worker mesh axes.  Circulant
  (ring) and Kronecker-of-circulant (torus) topologies map each weighted
  shift to one ppermute; the fully-connected topology maps to ``pmean``.

Both expose::

    mix(tree)                -> tree            # Σⱼ w_kj x⁽ʲ⁾
    shift_views(tree)        -> {(axis,shift): tree}   # raw neighbour tensors
    weights()                -> {(axis,shift): w}

``shift_views`` is what CPD-SGDM uses to move the *compressed, packed*
payload ``q`` between neighbours.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

__all__ = ["DenseComm", "ShardedComm", "CommBackend"]

ShiftKey = Tuple[int, int]  # (topology axis, shift)


class CommBackend:
    topology: Topology

    def mix(self, tree):
        raise NotImplementedError

    def shift_views(self, tree) -> Dict[ShiftKey, object]:
        raise NotImplementedError

    def weights(self) -> Dict[ShiftKey, float]:
        return {(ax, sh): w for (ax, sh, w) in self.topology.shifts}

    def nonself_shifts(self):
        return [(ax, sh, w) for (ax, sh, w) in self.topology.shifts if sh != 0]

    def self_weight(self) -> float:
        return float(sum(w for (_, sh, w) in self.topology.shifts if sh == 0))


@dataclasses.dataclass
class DenseComm(CommBackend):
    """Simulation backend: leaves are worker-stacked, leading dim K."""

    topology: Topology

    def __post_init__(self):
        self._W = jnp.asarray(self.topology.W, dtype=jnp.float32)

    def mix(self, tree):
        W = self._W

        def _mix(leaf):
            K = leaf.shape[0]
            assert K == self.topology.n_workers, (
                f"leaf worker dim {K} != K={self.topology.n_workers}")
            flat = leaf.reshape(K, -1)
            out = (W @ flat.astype(jnp.float32)).astype(leaf.dtype)
            return out.reshape(leaf.shape)

        return jax.tree_util.tree_map(_mix, tree)

    def _roll(self, leaf, axis: int, shift: int):
        """Return the view where worker k sees worker (k+shift)'s value."""
        grid = self.topology.axis_sizes
        K = leaf.shape[0]
        g = leaf.reshape(grid + leaf.shape[1:])
        # worker index along `axis` receives from (idx + shift) -> roll by -shift
        g = jnp.roll(g, -shift, axis=axis)
        return g.reshape((K,) + leaf.shape[1:])

    def shift_views(self, tree) -> Dict[ShiftKey, object]:
        out = {}
        for (ax, sh, _w) in self.nonself_shifts():
            out[(ax, sh)] = jax.tree_util.tree_map(
                lambda leaf: self._roll(leaf, ax, sh), tree)
        return out


@dataclasses.dataclass
class ShardedComm(CommBackend):
    """Production backend: ppermute along named mesh axes, inside shard_map.

    ``axis_names[i]`` is the mesh axis carrying topology axis ``i``.
    """

    topology: Topology
    axis_names: Tuple[str, ...]

    def __post_init__(self):
        # 'complete' mixes via pmean over all named axes — grid shape unused.
        if self.topology.name != "complete" and (
                len(self.axis_names) != len(self.topology.axis_sizes)):
            raise ValueError(
                f"axis_names {self.axis_names} vs grid {self.topology.axis_sizes}")

    def _receive_from(self, x, axis: int, shift: int):
        """Each worker receives the value held by worker (k+shift) on `axis`."""
        n = self.topology.axis_sizes[axis]
        name = self.axis_names[axis]
        perm = [(j, (j - shift) % n) for j in range(n)]
        return jax.lax.ppermute(x, name, perm)

    def receive_tree(self, tree, axis: int, shift: int):
        return jax.tree_util.tree_map(
            partial(self._receive_from, axis=axis, shift=shift), tree)

    def mix(self, tree):
        if self.topology.name == "complete":
            return jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(x, self.axis_names), tree)
        if self.topology.name == "disconnected":
            return tree

        # Kronecker factorization: apply the per-axis circulant sequentially.
        grid = self.topology.axis_sizes
        per_axis: Dict[int, list] = {}
        for (ax, sh, w) in self.topology.shifts:
            per_axis.setdefault(ax, []).append((sh, w))

        def mix_leaf(x):
            y = x
            for ax in sorted(per_axis):
                acc = None
                for (sh, w) in per_axis[ax]:
                    v = y if sh == 0 else self._receive_from(y, ax, sh)
                    term = v.astype(jnp.float32) * jnp.float32(w)
                    acc = term if acc is None else acc + term
                y = acc.astype(x.dtype)
            return y

        return jax.tree_util.tree_map(mix_leaf, tree)

    def shift_views(self, tree) -> Dict[ShiftKey, object]:
        out = {}
        for (ax, sh, _w) in self.nonself_shifts():
            out[(ax, sh)] = self.receive_tree(tree, ax, sh)
        return out


def gossip_bytes_per_round(tree, backend: CommBackend,
                           bits_per_element: float | None = None) -> int:
    """Per-worker bytes sent in one communication round (comm-cost model).

    Full precision: degree × Σ leaf bytes.  With compression, pass the
    compressor's ``wire_bits_per_element``.
    """
    total_elems = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))
    deg = len(backend.nonself_shifts())
    if bits_per_element is None:
        bytes_ = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(tree))
        return deg * bytes_
    return int(deg * total_elems * bits_per_element / 8.0)
