"""Render the §Roofline markdown table from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.render_roofline [--mesh 16x16] \
      >> EXPERIMENTS.md
"""
import argparse
import glob
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()

    recs = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        r = json.load(open(path))
        if r.get("skipped") or r.get("mesh") != args.mesh or r.get("tag"):
            continue
        recs.append(r)

    print(f"\n### Baseline roofline table ({args.mesh}, "
          f"{len(recs)} pairs; terms in ms per compiled call)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful | wire GB/dev |")
    print("|---|---|---:|---:|---:|---|---:|---:|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        t = r["terms"]
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} | "
              f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
              f"{t['dominant']} | {r['useful_flops_ratio']:.2f} | "
              f"{r['wire_bytes_per_device']/1e9:.2f} |")
    doms = {}
    for r in recs:
        d = r["terms"]["dominant"]
        doms[d] = doms.get(d, 0) + 1
    print(f"\nDominant-term census: {doms}.")


if __name__ == "__main__":
    main()
