"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.gossip import DenseComm
from repro.core.topology import complete, ring
from repro.core import make_optimizer
from repro.data.synthetic import ClassStreamCfg, class_batch
from repro.models.resnet import resnet20_init, resnet20_loss
from repro.train.trainer import SimTrainer

K = 8          # paper: ring of 8 workers
WIDTH = 4      # reduced ResNet20 width for CPU benchmark scale
STEPS = 90   # enough for PD-SGDM to close the gap to C-SGDM (paper Fig.1)


def stacked_resnet(K=K, width=WIDTH, seed=0):
    p = resnet20_init(jax.random.PRNGKey(seed), width=width)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), p)


def make_opt(name, k=K, p=4, eta=0.1, gamma=0.4, compressor=None):
    comm = DenseComm(complete(k) if name == "c_sgdm" else ring(k))
    return make_optimizer(name, comm, eta=eta, mu=0.9, p=p, gamma=gamma,
                          weight_decay=1e-4, compressor=compressor)


def train_resnet(opt, k=K, steps=STEPS, seed=0, batch=16, log_every=5,
                 rounds_per_log=None):
    """Train through the fused round engine; one host sync per log block
    (``rounds_per_log`` rounds, default ⌈log_every / p⌉)."""
    cfg = ClassStreamCfg(batch=batch, n_workers=k, seed=seed)
    trainer = SimTrainer(resnet20_loss, opt, rounds_per_log=rounds_per_log)
    params = stacked_resnet(k)
    t0 = time.time()
    params, state, hist = trainer.train(
        params, lambda t: class_batch(cfg, t), steps, log_every=log_every)
    return hist, (time.time() - t0) / steps


# rows accumulated for the machine-readable BENCH_*.json written by
# ``benchmarks.run`` (see its docstring for the schema)
_ROWS = []


def _parse_derived(derived) -> dict:
    """Split the ``k1=v1;k2=v2`` derived string into a dict (floats where
    possible); free-form fragments land under ``"note"``."""
    out = {}
    for part in str(derived).split(";"):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
        else:
            out["note"] = (out["note"] + ";" + part
                           if "note" in out else part)
    return out


def csv_row(name, us_per_call, derived):
    """Emit one benchmark result: CSV to stdout + structured row recorded
    for the BENCH_*.json artifact."""
    print(f"{name},{us_per_call:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": float(us_per_call),
                  "derived": _parse_derived(derived)})


def collected_rows():
    return list(_ROWS)
