"""Sign compression kernels: blockwise scaled-sign + 8-signs/byte bit-pack.

CPD-SGDM's per-round hot spot.  Two kernels:

  * ``sign_pack_kernel``   — x (rows, 1024) f32 → packed (rows, 128) uint8
                             + scales (rows, 1) f32 (mean |x| per row).
  * ``sign_unpack_kernel`` — inverse: Q(x) = scale · sign(x).

One *row* is one scale block (= ``compression.SIGN_BLOCK`` = 1024 elements =
8 f32 vregs), so the kernel's row dim maps directly onto the pure-jnp
oracle's block dim and the packed row is exactly one 128-lane uint8 vreg.

Padding contract: the flatten-once layout (``ops.KernelPlan``) zero-pads
each leaf's tail row, so a row may hold fewer than 1024 *valid* elements.
The per-row true length is threaded in as the ``counts`` operand ((rows, 1)
f32) and divides the |x| sum — giving exactly the padding-masked scale the
jnp oracle (``repro.core.compression.sign_pack``) computes.  Without it the
tail block's scale would be deflated by ``n_valid/1024``.  Rows that are
pure alignment padding carry count 0 and produce scale 0.

TPU adaptation note: the bit-gather uses an in-register reshape
(rows, 128, 8) → weighted sum over the last (sublane-contiguous) axis; on
real hardware this lowers to lane shifts within a vreg, not an HBM
round-trip.  Validated in interpret mode against ``repro.core.compression``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import LANE, default_interpret

__all__ = ["sign_pack_pallas", "sign_unpack_pallas", "LANE", "PACKED",
           "BLOCK_ROWS"]

# LANE elements per scale block (== compression.SIGN_BLOCK)
PACKED = LANE // 8   # bytes per packed row
BLOCK_ROWS = 256


def _pack_kernel(x_ref, cnt_ref, packed_ref, scale_ref):
    x = x_ref[...]                                   # (BR, 1024) f32
    cnt = cnt_ref[...]                               # (BR, 1) f32 valid count
    br = x.shape[0]
    # padded entries are exactly 0, so the |x| row sum already excludes
    # them; only the divisor needs the true length (bit-exact vs the
    # padding-masked oracle)
    scale_ref[...] = (jnp.sum(jnp.abs(x), axis=1, keepdims=True)
                      / jnp.maximum(cnt, 1.0))
    bits = (x >= 0).astype(jnp.uint8).reshape(br, PACKED, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    packed_ref[...] = jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def _unpack_kernel(packed_ref, scale_ref, out_ref):
    pk = packed_ref[...]                             # (BR, 128) uint8
    br = pk.shape[0]
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (pk[:, :, None] >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    out_ref[...] = signs.reshape(br, LANE) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_pack_pallas(x, counts=None, *, interpret: bool | None = None):
    """x: (rows, 1024) f32 → (packed (rows,128) u8, scales (rows,1) f32).

    ``counts`` ((rows,) or (rows, 1) f32) is the number of *valid* (non-
    padding) elements per row; omitted means every row is full.
    """
    if interpret is None:
        interpret = default_interpret()
    rows, lane = x.shape
    assert lane == LANE and rows % BLOCK_ROWS == 0, (rows, lane)
    if counts is None:
        counts = jnp.full((rows, 1), float(LANE), jnp.float32)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, PACKED), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, PACKED), jnp.uint8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), counts.reshape(rows, 1).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_unpack_pallas(packed, scales, *, interpret: bool | None = None):
    """(rows,128) u8 + (rows,1) f32 → Q(x) (rows, 1024) f32."""
    if interpret is None:
        interpret = default_interpret()
    rows = packed.shape[0]
    assert packed.shape[1] == PACKED and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, PACKED), lambda i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)],
        interpret=interpret,
    )(packed, scales.reshape(rows, 1).astype(jnp.float32))[0]
