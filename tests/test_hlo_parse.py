"""Unit tests for the extracted HLO parser (repro.analysis.hlo_parse):
collective parsing on canned HLO text, loop-depth multiplicity, and the
input_output_alias (donation) parser.  No jax tracing — pure text."""
import pytest

from repro.analysis.hlo_parse import (computation_loop_depths,
                                      donated_aliases, parse_collectives)

CANNED = """
HloModule jit_round, input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }

%body (p: f32[8]) -> f32[8] {
  %ar = f32[256]{0} all-reduce(%x), replica_groups=[8,8]<=[64]
}
%cond (p: f32[8]) -> pred[] {
  %lt = pred[] compare(%i, %n)
}
ENTRY %main (a: f32[16]) -> f32[16] {
  %w = f32[8] while(%a), condition=%cond, body=%body
  %cp = bf16[512]{0} collective-permute(%y), channel_id=3
  %ag = f32[64,32]{1,0} all-gather(%z), replica_groups=[4,16]<=[64]
}
"""


def test_parse_counts_and_bytes():
    st = parse_collectives(CANNED)
    assert st.counts == {"all-reduce": 1, "collective-permute": 1,
                         "all-gather": 1}
    assert st.result_bytes["collective-permute"] == 512 * 2
    assert st.result_bytes["all-reduce"] == 256 * 4
    # collective-permute wire = result bytes (point-to-point)
    assert st.wire_bytes["collective-permute"] == 512 * 2
    # ring all-reduce wire = 2(n-1)/n × size, n = 8
    assert st.wire_bytes["all-reduce"] == pytest.approx(
        2 * 7 / 8 * 256 * 4)


def test_calls_records():
    """parse_collectives records one CollectiveCall per HLO call site."""
    st = parse_collectives(CANNED)
    assert len(st.calls) == 3
    by_op = {c.op: c for c in st.calls}
    assert by_op["collective-permute"].result_bytes == 512 * 2
    assert by_op["all-gather"].result_bytes == 64 * 32 * 4
    assert "collective-permute" in by_op["collective-permute"].line


def test_loop_multiplicity():
    st = parse_collectives(CANNED, loop_trips=(4,))
    assert st.counts["all-reduce"] == 4          # inside %body (depth 1)
    assert st.counts["collective-permute"] == 1  # top level
    assert st.calls and any(c.mult == 4 for c in st.calls)


def test_computation_loop_depths():
    depths = computation_loop_depths(CANNED)
    assert depths.get("body") == 1
    assert depths.get("main", 0) == 0


def test_donated_aliases():
    aliases = donated_aliases(CANNED)
    assert len(aliases) == 2
    assert aliases[0]["param_number"] == 0
    assert aliases[1]["param_number"] == 1
    assert aliases[0]["kind"] == "may-alias"


def test_donated_aliases_empty():
    """A module without the alias map — i.e. a dropped donation — parses
    to an empty list (what check_donation flags)."""
    txt = "HloModule jit_round\nENTRY %main (a: f32[4]) -> f32[4] {\n}\n"
    assert donated_aliases(txt) == []


def test_check_donation_flags_empty_map():
    from repro.analysis.hlo_check import check_donation
    txt = "HloModule jit_round\nENTRY %main (a: f32[4]) -> f32[4] {\n}\n"
    assert check_donation(txt, n_donated=10)      # dropped → violation
    assert check_donation(CANNED, n_donated=2) == []


def test_check_collectives_allowed_canned():
    """The allowlist catches the canned all-gather but exempts a tiny
    scalar all-reduce."""
    from repro.analysis.hlo_check import check_collectives_allowed
    st = parse_collectives(CANNED)
    out = check_collectives_allowed(st)
    assert any("all-gather" in v for v in out)
    # the 1 KiB all-reduce is above the scalar exemption → also flagged
    assert any("all-reduce" in v for v in out)
    scalar = parse_collectives("""
ENTRY %main (a: f32[4]) -> f32[4] {
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[8,8]<=[64]
  %cp = f32[128]{0} collective-permute(%y)
}
""")
    assert check_collectives_allowed(scalar) == []


def test_wire_bytes_equality_check():
    from repro.analysis.hlo_check import check_wire_bytes
    st = parse_collectives(CANNED)
    assert check_wire_bytes(st, 512 * 2) == []
    bad = check_wire_bytes(st, 512 * 2 + 1, label="combo")
    assert bad and "combo" in bad[0]


def test_legacy_reexports():
    """launch.hlo_analysis keeps the parser names diagnose.py imports."""
    from repro.launch import hlo_analysis as legacy
    for name in ("_COLL_RE", "_COMP_DEF_RE", "_computation_loop_depths",
                 "_DTYPE_BYTES", "_group_size", "_type_bytes",
                 "parse_collectives", "donated_aliases", "CollectiveCall"):
        assert hasattr(legacy, name), name
