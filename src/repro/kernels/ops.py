"""The flatten-once kernel layout (``KernelPlan``) + jit'd Pallas wrappers.

Every Pallas kernel in this package operates on one canonical layout: an
f32 matrix of shape ``(rows, 1024)`` (optionally with a leading worker dim,
``(K, rows, 1024)``).  ``KernelPlan`` is the bidirectional mapping between
an arbitrary pytree and that layout:

  * **per-leaf row alignment** — every leaf starts on a fresh row and its
    tail row is zero-padded, so a 1024-row never spans two leaves.  This
    makes the kernel sign-compression *blocks* identical to the per-leaf
    jnp oracle's blocks (``repro.core.compression``, block = 1024), and the
    zero tail keeps elementwise kernels (momentum, gossip AXPY) exact.
  * **flatten once per round** — the fused round engine flattens the
    param/momentum trees at the round boundary, runs the ``lax.scan`` of p
    momentum updates, the gossip mix, and CPD-SGDM's sign pack/unpack all
    on the matrix, and unflattens once at the end (``PDSGDM.kernel_round``).
  * ``row_counts()`` carries each row's true (non-padding) length into the
    sign kernel so tail-block scales match the padding-masked oracle.

``interpret`` defaults to :func:`repro.kernels.default_interpret` —
lazily evaluated, True off-TPU (this container is CPU-only: TPU is the
*target*, interpret mode is the correctness harness).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels import gossip_mix as gm
from repro.kernels import momentum as mom
from repro.kernels import qsgd_quant as qq
from repro.kernels import sign_compress as sc
from repro.kernels import topk_select as tk

__all__ = ["KernelPlan", "PLAN_BLOCK_ROWS", "LANE", "default_interpret",
           "momentum_update_mat", "gossip_mix_mat", "sign_pack",
           "sign_unpack", "topk_pack", "topk_unpack", "qsgd_pack",
           "qsgd_unpack", "row_gather", "row_scatter",
           "momentum_update_tree", "gossip_mix_tree"]

from repro.kernels import LANE  # noqa: E402  (the single lane definition)

# one layout serves every kernel: lcm of the kernels' BLOCK_ROWS
PLAN_BLOCK_ROWS = int(np.lcm.reduce(
    [mom.BLOCK_ROWS, gm.BLOCK_ROWS, sc.BLOCK_ROWS, tk.BLOCK_ROWS,
     qq.BLOCK_ROWS]))


@dataclasses.dataclass(frozen=True)
class _Slot:
    """Where one leaf lives in the (rows, 1024) matrix."""
    shape: Tuple[int, ...]     # per-worker shape (worker dim stripped)
    dtype: object
    size: int                  # prod(shape)
    row_start: int
    n_rows: int                # ceil(size / 1024)


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Flatten-once mapping: pytree ⇄ zero-padded (rows, 1024) f32 matrix.

    ``worker_dim=True`` treats each leaf's leading axis as a stacked worker
    dim that is preserved: ``flatten`` returns ``(K, rows, 1024)`` and the
    per-worker row layout is identical for every worker (this is what the
    DenseComm simulation and the GSPMD-level sharded round both use; inside
    ``shard_map`` the same plan sees K = 1).
    """
    treedef: object
    slots: Tuple[_Slot, ...]
    rows: int
    block_rows: int
    worker_dim: bool

    @classmethod
    def for_tree(cls, tree, *, worker_dim: bool = False,
                 block_rows: int = PLAN_BLOCK_ROWS) -> "KernelPlan":
        """Build a plan from a concrete tree or a ShapeDtypeStruct tree."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        slots = []
        row = 0
        for leaf in leaves:
            shape = tuple(leaf.shape[1:] if worker_dim else leaf.shape)
            size = int(np.prod(shape)) if shape else 1
            assert size > 0, f"empty leaf {leaf.shape} has no kernel rows"
            n_rows = -(-size // LANE)
            slots.append(_Slot(shape, jnp.dtype(leaf.dtype), size, row,
                               n_rows))
            row += n_rows
        rows = -(-row // block_rows) * block_rows
        return cls(treedef, tuple(slots), rows, block_rows, worker_dim)

    # -- geometry ----------------------------------------------------------
    @property
    def n_valid(self) -> int:
        """Total real (non-padding) elements per worker."""
        return sum(s.size for s in self.slots)

    @property
    def used_rows(self) -> int:
        """Rows that carry leaf data (excludes the block-alignment tail).
        This is the wire extent: payloads are sliced to ``used_rows`` before
        a neighbour exchange so alignment padding never ships, keeping the
        actual ppermute bytes equal to the accounted
        ``Σ ceil(size/1024)`` blocks."""
        last = self.slots[-1]
        return last.row_start + last.n_rows

    def pad_wire(self, mat) -> jnp.ndarray:
        """Re-pad a wire-sliced (..., used_rows, d) payload back to the
        kernel row extent (..., rows, d) for the unpack kernel."""
        width = [(0, 0)] * mat.ndim
        width[-2] = (0, self.rows - mat.shape[-2])
        return jnp.pad(mat, width)

    def wire(self, mat) -> jnp.ndarray:
        """Slice a kernel matrix to the ``used_rows`` wire extent before a
        neighbour exchange (inverse of :meth:`pad_wire`).  Identity when
        the block-alignment tail is empty.  The tail is zero on every
        worker and row-local mixing keeps it zero, so the slice is exact —
        overlapped rounds ship their in-flight payload through the same
        extent, keeping stale and synchronous bytes identical."""
        if self.used_rows >= self.rows:
            return mat
        return mat[..., :self.used_rows, :]

    def row_counts(self) -> jnp.ndarray:
        """(rows, 1) f32: valid elements per row (the sign-scale divisor)."""
        c = np.zeros((self.rows,), np.float32)
        for s in self.slots:
            c[s.row_start:s.row_start + s.n_rows] = float(LANE)
            c[s.row_start + s.n_rows - 1] = float(
                s.size - (s.n_rows - 1) * LANE)
        return jnp.asarray(c).reshape(self.rows, 1)

    # -- tree ⇄ matrix -----------------------------------------------------
    def flatten(self, tree) -> jnp.ndarray:
        """(rows, 1024) f32 — or (K, rows, 1024) when ``worker_dim``."""
        leaves = self.treedef.flatten_up_to(tree)
        axis = 1 if self.worker_dim else 0
        parts = []
        for slot, leaf in zip(self.slots, leaves):
            pad = slot.n_rows * LANE - slot.size
            if self.worker_dim:
                flat = jnp.reshape(leaf, (leaf.shape[0], -1)).astype(
                    jnp.float32)
                flat = jnp.pad(flat, ((0, 0), (0, pad)))
                parts.append(flat.reshape(leaf.shape[0], slot.n_rows, LANE))
            else:
                flat = jnp.reshape(leaf, (-1,)).astype(jnp.float32)
                flat = jnp.pad(flat, (0, pad))
                parts.append(flat.reshape(slot.n_rows, LANE))
        mat = jnp.concatenate(parts, axis=axis) if len(parts) > 1 else parts[0]
        tail = self.rows - mat.shape[axis]
        if tail:
            width = [(0, 0)] * mat.ndim
            width[axis] = (0, tail)
            mat = jnp.pad(mat, width)
        return mat

    def unflatten(self, mat, dtype=None):
        """Inverse of :meth:`flatten`; ``dtype`` overrides the recorded
        per-leaf dtypes (e.g. force f32 for momentum/x̂ state trees)."""
        leaves = []
        for slot in self.slots:
            if self.worker_dim:
                block = mat[:, slot.row_start:slot.row_start + slot.n_rows]
                flat = block.reshape(mat.shape[0], -1)[:, :slot.size]
                shape = (mat.shape[0],) + slot.shape
            else:
                block = mat[slot.row_start:slot.row_start + slot.n_rows]
                flat = block.reshape(-1)[:slot.size]
                shape = slot.shape
            leaves.append(flat.reshape(shape).astype(dtype or slot.dtype))
        return self.treedef.unflatten(leaves)


def _rows2d(mat) -> jnp.ndarray:
    """Collapse any leading worker dims onto the row axis: (..., R, 1024) →
    (N·R, 1024).  Valid because R is a multiple of every kernel's
    BLOCK_ROWS, so blocks never straddle two workers."""
    return mat.reshape(-1, LANE)


# --------------------------------------------------------------------- mat ops
def momentum_update_mat(x_mat, m_mat, g_mat, *, mu: float, lr,
                        weight_decay: float = 0.0, nesterov: bool = False,
                        interpret: bool | None = None):
    """Fused SGDM on the kernel layout; accepts (..., rows, 1024)."""
    shape = x_mat.shape
    x_new, m_new = mom.momentum_update(
        _rows2d(x_mat), _rows2d(m_mat), _rows2d(g_mat), lr, mu=mu,
        wd=weight_decay, nesterov=nesterov, interpret=interpret)
    return x_new.reshape(shape), m_new.reshape(shape)


def gossip_mix_mat(mats, weights, *, interpret: bool | None = None):
    """Fused W-row AXPY of n aligned matrices; accepts (..., rows, 1024)."""
    shape = mats[0].shape
    out = gm.gossip_mix(tuple(_rows2d(m) for m in mats),
                        weights=tuple(float(w) for w in weights),
                        interpret=interpret)
    return out.reshape(shape)


def delayed_mix_mat(x_mat, dx_mat, *, interpret: bool | None = None):
    """Land an overlapped round's one-round-stale gossip correction
    matrix-to-matrix on the flatten-once layout: ``x + dx`` as the fused
    W-row AXPY, where ``dx = gate·(W̃·buf − buf)`` was formed at round
    start from the in-flight payload.  The staleness gate is folded into
    ``dx`` by an elementwise multiply because the AXPY kernel's weights
    must stay static floats."""
    return gossip_mix_mat((x_mat, dx_mat), (1.0, 1.0), interpret=interpret)


def sign_pack(x_mat, counts=None, *, interpret: bool | None = None):
    """(..., rows, 1024) → (packed (..., rows, 128) u8, scales (..., rows, 1)).

    ``counts``: per-row valid lengths from :meth:`KernelPlan.row_counts`,
    tiled across any leading worker dims automatically.
    """
    lead, rows = x_mat.shape[:-2], x_mat.shape[-2]
    packed, scales = sc.sign_pack_pallas(_rows2d(x_mat),
                                         _tile_counts(counts, rows, lead),
                                         interpret=interpret)
    return (packed.reshape(lead + (rows, sc.PACKED)),
            scales.reshape(lead + (rows, 1)))


def sign_unpack(packed, scales, *, interpret: bool | None = None):
    """Inverse of :func:`sign_pack`: (..., rows, 1024) f32 = scale·sign."""
    lead, rows = packed.shape[:-2], packed.shape[-2]
    out = sc.sign_unpack_pallas(packed.reshape(-1, sc.PACKED),
                                scales.reshape(-1, 1), interpret=interpret)
    return out.reshape(lead + (rows, LANE))


def _tile_counts(counts, rows, lead):
    """Normalize a (rows,)/(rows, 1) counts operand and tile it across any
    leading worker dims (the per-row layout is identical per worker)."""
    if counts is None:
        return None
    c = jnp.asarray(counts, jnp.float32).reshape(rows, 1)
    if lead:
        c = jnp.tile(c, (int(np.prod(lead)), 1))
    return c


def topk_pack(x_mat, counts=None, *, fraction: float,
              interpret: bool | None = None):
    """(..., rows, 1024) → (idx (..., rows, W) i32, vals (..., rows, W) f32)
    with W = ceil(fraction·1024) — the blockwise top-k wire payload.

    ``counts``: per-row valid lengths (:meth:`KernelPlan.row_counts`); the
    active slot count per row is ``ceil(fraction · count)``.
    """
    lead, rows = x_mat.shape[:-2], x_mat.shape[-2]
    idx, vals = tk.topk_select_pallas(
        _rows2d(x_mat), _tile_counts(counts, rows, lead),
        fraction=fraction, interpret=interpret)
    w = idx.shape[-1]
    return (idx.reshape(lead + (rows, w)), vals.reshape(lead + (rows, w)))


def topk_unpack(idx, vals, *, interpret: bool | None = None):
    """Inverse scatter of :func:`topk_pack` → (..., rows, 1024) f32."""
    lead, rows, w = idx.shape[:-2], idx.shape[-2], idx.shape[-1]
    out = tk.topk_scatter_pallas(idx.reshape(-1, w), vals.reshape(-1, w),
                                 interpret=interpret)
    return out.reshape(lead + (rows, LANE))


def qsgd_pack(x_mat, *, levels: int, interpret: bool | None = None):
    """(..., rows, 1024) → (levels (..., rows, 1024·bits/8) u8,
    norms (..., rows, 1) f32) — the blockwise QSGD wire payload."""
    lead, rows = x_mat.shape[:-2], x_mat.shape[-2]
    packed, norms = qq.qsgd_quant_pallas(_rows2d(x_mat), levels=levels,
                                         interpret=interpret)
    return (packed.reshape(lead + (rows, packed.shape[-1])),
            norms.reshape(lead + (rows, 1)))


def qsgd_unpack(packed, norms, *, levels: int,
                interpret: bool | None = None):
    """Inverse of :func:`qsgd_pack`: (..., rows, 1024) f32."""
    lead, rows = packed.shape[:-2], packed.shape[-2]
    out = qq.qsgd_dequant_pallas(packed.reshape(-1, packed.shape[-1]),
                                 norms.reshape(-1, 1), levels=levels,
                                 interpret=interpret)
    return out.reshape(lead + (rows, LANE))


def row_gather(x_mat, idx, counts=None, *, interpret: bool | None = None):
    """(..., rows, 1024) + idx (..., S) i32 → gathered (..., S, 1024) f32 —
    the sparse wire's payload builder (``repro.kernels.row_gather``).

    ``counts``: per-row valid lengths (:meth:`KernelPlan.row_counts`,
    shared across workers); gathered rows keep only their valid prefix.
    Scalar-prefetch grids cannot be vmapped, so leading worker dims run as
    a static Python loop — K kernel launches, one per simulated worker
    (the sharded production path has no lead dim).
    """
    from repro.kernels import row_gather as rg
    if counts is not None:
        counts = jnp.asarray(counts, jnp.float32).reshape(x_mat.shape[-2])
    lead = x_mat.shape[:-2]
    if not lead:
        return rg.row_gather_pallas(x_mat, idx, counts, interpret=interpret)
    k = int(np.prod(lead))
    xs = x_mat.reshape((k,) + x_mat.shape[-2:])
    ids = idx.reshape(k, idx.shape[-1])
    out = jnp.stack([rg.row_gather_pallas(xs[i], ids[i], counts,
                                          interpret=interpret)
                     for i in range(k)])
    return out.reshape(lead + out.shape[-2:])


def row_scatter(idx, vals, *, rows: int, interpret: bool | None = None):
    """Inverse of :func:`row_gather`: idx (..., S) + vals (..., S, 1024) →
    (..., rows, 1024) f32 with ``out[idx[j]] += vals[j]`` per worker and
    untouched rows exactly 0."""
    from repro.kernels import row_gather as rg
    lead = vals.shape[:-2]
    if not lead:
        return rg.row_scatter_pallas(idx, vals, rows=rows,
                                     interpret=interpret)
    k = int(np.prod(lead))
    ids = idx.reshape(k, idx.shape[-1])
    vs = vals.reshape((k,) + vals.shape[-2:])
    out = jnp.stack([rg.row_scatter_pallas(ids[i], vs[i], rows=rows,
                                           interpret=interpret)
                     for i in range(k)])
    return out.reshape(lead + out.shape[-2:])


# -------------------------------------------------------------------- tree ops
def momentum_update_tree(params, m, grads, *, mu: float, lr,
                         weight_decay: float = 0.0, nesterov: bool = False,
                         interpret: bool | None = None):
    """Fused SGDM over a whole pytree (one kernel launch).

    Per-call flatten/unflatten — the per-step debugging path.  The fused
    round (``PDSGDM.kernel_round``) flattens once per *round* instead.
    """
    plan = KernelPlan.for_tree(params)
    x_new, m_new = momentum_update_mat(
        plan.flatten(params), plan.flatten(m), plan.flatten(grads),
        mu=mu, lr=lr, weight_decay=weight_decay, nesterov=nesterov,
        interpret=interpret)
    return plan.unflatten(x_new), plan.unflatten(m_new, dtype=jnp.float32)


def gossip_mix_tree(trees, weights, *, interpret: bool | None = None):
    """Fused W-row mixing of n aligned pytrees (self + neighbours)."""
    plan = KernelPlan.for_tree(trees[0])
    out = gossip_mix_mat(tuple(plan.flatten(t) for t in trees), weights,
                         interpret=interpret)
    return plan.unflatten(out)
