"""MT-DSGDm / QG-DSGDm: tracking invariant, heterogeneity robustness,
2-tensor wire accounting, and kernel-round equivalence.

The fused-round ≡ per-step and SimTrainer equivalences run in
``tests/test_round_engine.py`` (both optimizers are in its parametrize
list); mid-schedule checkpoint resume in ``tests/test_checkpoint_resume``.
Here: the algorithm-specific contracts.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MTDSGDm, MTDSGDMConfig, QGDSGDm, QGDSGDMConfig,
                        RandKCompressor, SignCompressor, make_optimizer)
from repro.core.gossip import DenseComm, ShardedComm
from repro.core.topology import (exponential, one_peer_exponential_schedule,
                                 ring)

K, D, P = 8, 80, 4


def _params():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (K, D))}


def _hetero_grads_fn(scale=1.0):
    """Per-worker quadratic F_k(x) = ||x − b_k||²/2 with very different
    b_k — the textbook heterogeneous problem: the global optimum is
    mean(b), but every worker's local gradient points at its own b_k."""
    b = scale * jax.random.normal(jax.random.PRNGKey(3), (K, D))

    def grads_fn(params, batch):
        g = {"w": params["w"] - b}
        losses = 0.5 * jnp.sum((params["w"] - b) ** 2, axis=-1)
        return losses.mean(), g

    return grads_fn, b


def _run_rounds(opt, grads_fn, n_rounds, params=None):
    params = _params() if params is None else params
    state = opt.init(params)
    batches = jnp.zeros((P, 1))
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    for _ in range(n_rounds):
        params, state, _ = roundj(state, params, batches)
    return params, state


def test_tracking_invariant_mean_c_equals_mean_gradient():
    """The defining property: after every local step AND every gossip,
    mean_k c⁽ᵏ⁾ == mean_k ĝ⁽ᵏ⁾ (the worker-mean of the latest folded
    gradients) — c₀ = ĝ₋₁ = 0 establishes it, the local update and the
    doubly-stochastic mix both preserve it."""
    opt = MTDSGDm(MTDSGDMConfig(eta=0.05, mu=0.9, p=P, weight_decay=1e-4),
                  DenseComm(ring(K)))
    grads_fn, _ = _hetero_grads_fn()
    params = _params()
    state = opt.init(params)
    for t in range(2 * P + 1):          # crosses two gossip rounds
        _, g = grads_fn(params, None)
        g32 = jax.tree_util.tree_map(
            lambda gg, x: gg + jnp.float32(1e-4) * x, g, params)
        params, state = opt.step(state, params, g)
        np.testing.assert_allclose(
            np.asarray(state["c"]["w"].mean(0)),
            np.asarray(g32["w"].mean(0)), rtol=1e-5, atol=1e-6), t


def _per_worker_dist(params, b_star):
    """RMS per-worker distance to the global optimum — the heterogeneity
    metric.  (The worker-*mean* converges for plain momentum too on this
    symmetric problem: mean dynamics are blind to the drift; what PD-SGDM
    cannot do is pull the individual workers off their local optima.)"""
    w = np.asarray(params["w"])
    return float(np.sqrt(((w - b_star[None]) ** 2).sum(-1).mean()))


def test_mt_beats_plain_momentum_on_heterogeneous_quadratic():
    """On the heterogeneous quadratic, gradient tracking steers every
    worker toward the *global* optimum mean(b); plain local momentum
    (PD-SGDM) pins each worker at its own b_k and its per-worker distance
    never decays.  QG sits in between."""
    grads_fn, b = _hetero_grads_fn(scale=3.0)
    b_star = np.asarray(b.mean(0))
    dist = {}
    for name in ["pd_sgdm", "mt_dsgdm", "qg_dsgdm"]:
        opt = make_optimizer(name, DenseComm(exponential(K)), eta=0.05,
                             mu=0.9, p=P, weight_decay=0.0)
        params, _ = _run_rounds(opt, grads_fn, n_rounds=100)
        dist[name] = _per_worker_dist(params, b_star)
    assert dist["mt_dsgdm"] < 0.05 * dist["pd_sgdm"], dist
    assert dist["qg_dsgdm"] < 0.5 * dist["pd_sgdm"], dist


def test_compressed_tracking_sign_still_tracks():
    """Sign-compressed correction wire: the mix sees Q(c), so the exact
    invariant is gone, but workers still move measurably closer to the
    global optimum than plain momentum ever does."""
    grads_fn, b = _hetero_grads_fn(scale=3.0)
    b_star = np.asarray(b.mean(0))
    dist = {}
    for name, comp in [("pd_sgdm", None), ("mt_dsgdm", SignCompressor())]:
        opt = make_optimizer(name, DenseComm(exponential(K)), eta=0.05,
                             mu=0.9, p=P, weight_decay=0.0, compressor=comp)
        params, _ = _run_rounds(opt, grads_fn, n_rounds=100)
        dist[name] = _per_worker_dist(params, b_star)
    assert dist["mt_dsgdm"] < 0.7 * dist["pd_sgdm"], dist


def test_mt_bytes_charges_two_tensor_payload():
    """bytes_per_comm_round = degree × (full-precision x + correction
    wire): f32 c doubles the x bytes; a codec charges its exact payload."""
    per_worker = {"w": jnp.zeros((D,), jnp.float32)}
    deg = ring(K).degree
    x_bytes = deg * D * 4

    opt = MTDSGDm(MTDSGDMConfig(p=P), DenseComm(ring(K)))
    assert opt.bytes_per_comm_round(per_worker) == 2 * x_bytes

    opt_s = MTDSGDm(MTDSGDMConfig(p=P), DenseComm(ring(K)),
                    SignCompressor())
    sign_payload = opt_s.codec.wire_bytes(D)
    assert opt_s.bytes_per_comm_round(per_worker) == \
        x_bytes + deg * sign_payload

    # QG ships x only — identical to PD-SGDM's wire
    opt_q = QGDSGDm(QGDSGDMConfig(p=P), DenseComm(ring(K)))
    assert opt_q.bytes_per_comm_round(per_worker) == x_bytes


def test_qg_rejects_nesterov_and_mt_gates_sharded_codec():
    with pytest.raises(ValueError, match="nesterov"):
        QGDSGDm(QGDSGDMConfig(p=P, nesterov=True), DenseComm(ring(K)))
    with pytest.raises(ValueError, match="static"):
        MTDSGDm(MTDSGDMConfig(p=P),
                ShardedComm(one_peer_exponential_schedule(K),
                            axis_names=("w",)), SignCompressor())
    # full-precision MT composes with schedules on both backends
    MTDSGDm(MTDSGDMConfig(p=P),
            ShardedComm(one_peer_exponential_schedule(K),
                        axis_names=("w",)))


def test_mt_scheduled_dense_round_equals_per_step():
    """Dense scheduled MT: the dual (x, c) mix follows the per-round W of
    a time-varying schedule, fused round ≡ per-step."""
    sched = one_peer_exponential_schedule(K)
    grads_fn, _ = _hetero_grads_fn()

    def grad_only(pp, b):
        return grads_fn(pp, b)[1]

    for comp in [None, SignCompressor()]:
        opt = MTDSGDm(MTDSGDMConfig(eta=0.05, mu=0.9, p=P,
                                    weight_decay=1e-4),
                      DenseComm(sched), comp)
        params, state = _params(), opt.init(_params())
        stepj = jax.jit(
            lambda s, pp, b: opt.step(s, pp, grad_only(pp, b)))
        for t in range(2 * P):
            params, state = stepj(state, params, None)
        params2, state2 = _run_rounds(opt, grads_fn, n_rounds=2)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(params2["w"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(state["c"]["w"]),
                                   np.asarray(state2["c"]["w"]),
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------- kernel-round equivalence
def _run_kernel_rounds(opt, K=4, P=4):
    """2 fused rounds over a ragged multi-leaf tree (mirrors
    tests/test_kernels.py::_run_rounds)."""
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (K, 33, 65)),
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (K, 7)),
              "w3": jax.random.normal(jax.random.fold_in(key, 2),
                                      (K, 2, 5, 11))}

    def loss_fn(pp, b):
        return 0.5 * sum(jnp.sum((l - b[0, 0]) ** 2)
                         for l in jax.tree_util.tree_leaves(pp))

    grad = jax.vmap(jax.value_and_grad(loss_fn))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    batches = jnp.stack([
        jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(9), t),
                          (K, 2, 3)) for t in range(P)])
    state = opt.init(params)
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    for _ in range(2):
        params, state, losses = roundj(state, params, batches)
    return params, state, losses


@pytest.mark.parametrize("name,comp", [
    ("mt_dsgdm", None),
    ("mt_dsgdm", SignCompressor()),
    ("qg_dsgdm", None),
])
def test_kernel_round_equals_jnp_round_dense(name, comp):
    """use_kernel=True fused round == jnp fused round: the tracking
    matrices (c, ĝ_prev / xprev) ride the flatten-once layout through the
    momentum scan, the tracking AXPY, and the dual gossip mix."""
    K_, P_ = 4, 4
    outs = []
    for uk in (False, True):
        opt = make_optimizer(name, DenseComm(ring(K_)), eta=0.05, mu=0.9,
                             p=P_, weight_decay=1e-4, compressor=comp,
                             use_kernel=uk, kernel_interpret=True)
        outs.append(_run_kernel_rounds(opt, K_, P_))
    (pa, sa, la), (pb, sb, lb) = outs
    assert int(sb["step"]) == 2 * P_
    extras = [k for k in ("c", "g_prev", "xprev") if k in sa]
    for x, y in zip(
            jax.tree_util.tree_leaves(
                (pa, sa["m"], la, [sa[k] for k in extras])),
            jax.tree_util.tree_leaves(
                (pb, sb["m"], lb, [sb[k] for k in extras]))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)


def test_kernel_round_randk_tracking_falls_back_to_tree_comm():
    """rand-k has no rows kernel: kernel_comm_supported is False and the
    kernel round finishes with the tree comm at the boundary — same
    trajectory as the jnp round."""
    K_, P_ = 4, 2
    outs = []
    for uk in (False, True):
        opt = MTDSGDm(MTDSGDMConfig(eta=0.05, mu=0.9, p=P_,
                                    use_kernel=uk, kernel_interpret=True),
                      DenseComm(ring(K_)),
                      RandKCompressor(fraction=0.2))
        if uk:
            assert not opt.kernel_comm_supported
        outs.append(_run_kernel_rounds(opt, K_, P_))
    for x, y in zip(jax.tree_util.tree_leaves((outs[0][0], outs[0][1]["c"])),
                    jax.tree_util.tree_leaves((outs[1][0], outs[1][1]["c"]))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)


_SCRIPT_SHARDED_TRACKING = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    # tp=1 mesh (kernel codec blocks == per-device tree blocks, tight tol)
    for opt_name, tc in [("mt_dsgdm", False), ("mt_dsgdm", True),
                         ("qg_dsgdm", False)]:
        finals = []
        for uk in (False, True):
            run = RunCfg(model=mcfg,
                         parallel=ParallelCfg(profile="A", remat="none"),
                         optim=OptimCfg(name=opt_name, eta=0.05, mu=0.9, p=3,
                                        weight_decay=1e-4, use_kernel=uk,
                                        compressor="sign",
                                        track_compressed=tc))
            mesh = make_debug_mesh(8, 1)
            pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
            K = pack.layout.n_workers
            assert "c" in pack.state_struct or opt_name != "mt_dsgdm"
            batches = [train_batch_arrays(mcfg, K, 1, 16,
                       jax.random.fold_in(jax.random.PRNGKey(1), t))
                       for t in range(3)]
            params, state = pack.init_fn(jax.random.PRNGKey(0))
            rb = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)
            for _ in range(2):
                params, state, losses = pack.train_round(params, state, rb)
            finals.append(jax.tree_util.tree_map(np.asarray, (params, state)))
        for a, b in zip(jax.tree_util.tree_leaves(finals[0]),
                        jax.tree_util.tree_leaves(finals[1])):
            np.testing.assert_allclose(a, b, rtol=2e-6, atol=2e-6)
        print("TRACKING_KERNEL_EQ_OK", opt_name, "tc" if tc else "fp")
""")


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_kernel_round_equals_jnp_round_sharded_tracking():
    """use_kernel=True TrainPack.train_round == the jnp tree round on the
    ShardedComm backend for MT (full-precision and sign-compressed
    tracking) and QG."""
    out = _run_sub(_SCRIPT_SHARDED_TRACKING)
    assert "TRACKING_KERNEL_EQ_OK mt_dsgdm fp" in out
    assert "TRACKING_KERNEL_EQ_OK mt_dsgdm tc" in out
    assert "TRACKING_KERNEL_EQ_OK qg_dsgdm fp" in out
