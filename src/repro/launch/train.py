"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --optimizer pd_sgdm --steps 50 --devices 8

On this CPU container ``--devices N`` forces N host devices and a debug mesh
(the production path is identical code on a real mesh).  ``--smoke`` selects
the reduced config; the full configs are exercised by ``dryrun``.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--optimizer", default=None,
                    help="pd_sgdm|cpd_sgdm|mt_dsgdm|qg_dsgdm|c_sgdm|"
                         "d_sgd|pd_sgd|choco_sgd")
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--eta", type=float, default=None)
    ap.add_argument("--topology", default=None,
                    help="ring|torus|complete|exponential|disconnected")
    ap.add_argument("--topology-schedule", default=None,
                    help="static|one_peer_exp|alt_axes|random_matching "
                         "(time-varying gossip graph)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run the fused round on the flatten-once Pallas "
                         "kernel layout (recommended on TPU; interpret "
                         "mode — the correctness harness — on CPU)")
    ap.add_argument("--overlap", action="store_true",
                    help="communication-hiding overlapped rounds: exchange "
                         "round r's gossip payload during round r+1's "
                         "local scan and mix it one round late (stale "
                         "delayed mixing; unsupported optimizer combos "
                         "raise at construction)")
    ap.add_argument("--node-size", type=int, default=None,
                    help="hierarchical two-level gossip: exact intra-node "
                         "averaging over groups of this many workers, "
                         "--topology between node leaders only")
    ap.add_argument("--wire-dtype", default=None,
                    choices=("float32", "bfloat16"),
                    help="dtype of the gossip payload on the wire "
                         "(bfloat16 halves it; accumulation stays f32)")
    ap.add_argument("--inter-codec", default=None,
                    help="compress the hierarchical inter-node wire "
                         "(identity|sign|topk|qsgd; needs --node-size)")
    ap.add_argument("--compressor", default=None,
                    help="cpd_sgdm/choco wire codec: "
                         "identity|sign|topk|randk|qsgd|sparse|"
                         "sparse+sign|sparse+qsgd")
    ap.add_argument("--compressor-fraction", type=float, default=None,
                    help="topk/randk kept fraction")
    ap.add_argument("--compressor-levels", type=int, default=None,
                    help="qsgd quantization levels (7 = 4-bit wire)")
    ap.add_argument("--compressor-block", type=int, default=None,
                    help="sign/topk/qsgd/sparse block width (1024 = kernel "
                         "lane; other widths use the per-leaf jnp wire)")
    ap.add_argument("--compressor-rows", type=int, default=None,
                    help="sparse wire: shipped-row budget per leaf "
                         "(bytes/round scale with it, not with table size)")
    ap.add_argument("--track-compressed", action="store_true",
                    help="mt_dsgdm: ship the gradient-tracking correction "
                         "through the --compressor wire codec instead of "
                         "full precision")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU debug)")
    ap.add_argument("--data-axis", type=int, default=4)
    ap.add_argument("--model-axis", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in --ckpt-dir")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import dataclasses

    import jax

    from repro.configs.registry import get_config, get_smoke_config
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_mesh
    from repro.launch.runtime import build_train
    from repro.train.trainer import ShardedTrainer

    run = (get_smoke_config if args.smoke else get_config)(args.arch)
    optim = run.optim
    if args.optimizer:
        optim = dataclasses.replace(optim, name=args.optimizer)
    if args.p:
        optim = dataclasses.replace(optim, p=args.p)
    if args.eta is not None:
        optim = dataclasses.replace(optim, eta=args.eta)
    if args.use_kernel:
        optim = dataclasses.replace(optim, use_kernel=True)
    if args.overlap:
        optim = dataclasses.replace(optim, overlap=True)
    if args.compressor:
        optim = dataclasses.replace(optim, compressor=args.compressor)
    if args.compressor_fraction is not None:
        optim = dataclasses.replace(
            optim, compressor_fraction=args.compressor_fraction)
    if args.compressor_levels is not None:
        optim = dataclasses.replace(
            optim, compressor_levels=args.compressor_levels)
    if args.compressor_block is not None:
        optim = dataclasses.replace(
            optim, compressor_block=args.compressor_block)
    if args.compressor_rows is not None:
        optim = dataclasses.replace(
            optim, compressor_rows=args.compressor_rows)
    if args.track_compressed:
        optim = dataclasses.replace(optim, track_compressed=True)
    if args.wire_dtype:
        optim = dataclasses.replace(optim, wire_dtype=args.wire_dtype)
    parallel = run.parallel
    if args.topology:
        parallel = dataclasses.replace(parallel, topology=args.topology)
    if args.topology_schedule:
        parallel = dataclasses.replace(
            parallel, topology_schedule=args.topology_schedule)
    if args.node_size is not None:
        parallel = dataclasses.replace(parallel, node_size=args.node_size)
    if args.inter_codec:
        parallel = dataclasses.replace(parallel,
                                       inter_codec=args.inter_codec)
    run = dataclasses.replace(run, optim=optim, parallel=parallel)

    n_dev = len(jax.devices())
    if n_dev >= args.data_axis * args.model_axis:
        mesh = make_mesh((args.data_axis, args.model_axis),
                         ("data", "model"))
    else:
        mesh = make_mesh((n_dev, 1), ("data", "model"))

    shape = InputShape("cli", args.seq_len, args.global_batch, "train")
    pack = build_train(run, mesh, shape)
    n_w = pack.layout.n_workers
    print(f"arch={args.arch} optimizer={optim.name} p={optim.p} "
          f"workers={n_w} kernel={optim.use_kernel} "
          f"overlap={optim.overlap} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    def batch_fn(t):
        return train_batch_arrays(
            run.model, n_w, args.global_batch // n_w, args.seq_len,
            jax.random.fold_in(jax.random.PRNGKey(1), t))

    trainer = ShardedTrainer(pack, ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every)
    with mesh:
        out = trainer.train(jax.random.PRNGKey(0), batch_fn, args.steps,
                            log_every=max(args.steps // 10, 1),
                            resume=args.resume)
    h = out["history"]
    if not h.loss:      # e.g. --resume with a checkpoint at/past --steps
        print("no steps run")
        return
    print(f"final loss {h.loss[-1]:.4f} (start {h.loss[0]:.4f})")
    if out["steps_run"] == args.steps and h.loss[-1] >= h.loss[0]:
        # a short resumed tail is too noisy to judge — only warn on full runs
        print("WARNING: loss did not decrease", file=sys.stderr)


if __name__ == "__main__":
    main()
