"""Kernel microbenchmarks: Pallas (interpret mode) vs pure-jnp oracle.

Interpret-mode wall time is a CPU emulation — correctness harness, not TPU
performance.  Derived column reports bytes touched so the HBM-bound roofline
claim (the reason these kernels exist) is auditable: each kernel's traffic
is the stream count × matrix bytes.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels import LANE, ops, ref
from repro.kernels.momentum import BLOCK_ROWS


def _time(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main():
    rows = BLOCK_ROWS * 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (rows, LANE))
    m = jnp.zeros_like(x)
    g = jax.random.normal(jax.random.fold_in(key, 1), (rows, LANE))
    nbytes = x.size * 4

    from repro.kernels.momentum import momentum_update
    us = _time(momentum_update, x, m, g, 0.1, mu=0.9, wd=1e-4)
    csv_row("kernel/momentum_pallas_interpret", us,
            f"streams=5;bytes={5*nbytes}")
    us = _time(jax.jit(lambda *a: ref.momentum_update_ref(
        *a, mu=0.9, wd=1e-4)), x, m, g, 0.1)
    csv_row("kernel/momentum_jnp_ref", us, f"bytes={8*nbytes}")

    from repro.kernels.sign_compress import (sign_pack_pallas,
                                             sign_unpack_pallas)
    us = _time(sign_pack_pallas, x)
    csv_row("kernel/sign_pack_pallas_interpret", us,
            f"in={nbytes};out={nbytes//32 + rows*4}")
    pk, sl = ops.sign_pack(x)
    us = _time(sign_unpack_pallas, pk, sl[:, 0])
    csv_row("kernel/sign_unpack_pallas_interpret", us,
            f"compression_ratio={nbytes/(pk.size + sl.size*4):.1f}x")
    us = _time(jax.jit(ref.sign_pack_ref), x)
    csv_row("kernel/sign_pack_jnp_ref", us, f"in={nbytes}")

    from repro.kernels.gossip_mix import gossip_mix
    t3 = (x, g, m + 1.0)
    us = _time(gossip_mix, t3, weights=(1 / 3, 1 / 3, 1 / 3))
    csv_row("kernel/gossip_mix_pallas_interpret", us,
            f"streams=4;bytes={4*nbytes}")
    us = _time(jax.jit(lambda t: ref.gossip_mix_ref(t, (1/3, 1/3, 1/3))), t3)
    csv_row("kernel/gossip_mix_jnp_ref", us, f"bytes={4*nbytes}")


if __name__ == "__main__":
    main()
