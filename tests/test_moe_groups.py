"""Grouped MoE dispatch (§Perf lever) equals the global-sort baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoECfg, moe_apply, moe_init


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_equals_global_at_high_capacity(groups):
    """With capacity ≥ all tokens, per-group dispatch must be numerically
    identical to the single global sort (no drops on either path)."""
    cfg1 = MoECfg(d_model=16, d_ff=32, n_experts=4, top_k=2,
                  capacity_factor=16.0, n_groups=1)
    cfgg = MoECfg(d_model=16, d_ff=32, n_experts=4, top_k=2,
                  capacity_factor=16.0, n_groups=groups)
    p = moe_init(jax.random.PRNGKey(0), cfg1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y1, a1 = moe_apply(p, x, cfg1)
    yg, ag = moe_apply(p, x, cfgg)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(y1), atol=1e-5)
    np.testing.assert_allclose(float(ag), float(a1), rtol=1e-6)


def test_grouped_fallback_when_indivisible():
    """N % groups != 0 silently falls back to the global sort."""
    cfg = MoECfg(d_model=8, d_ff=16, n_experts=2, top_k=1, n_groups=7)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 8))
    y, _ = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_grouped_drops_are_per_group():
    cfg = MoECfg(d_model=8, d_ff=16, n_experts=2, top_k=1,
                 capacity_factor=0.5, n_groups=4)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    y, _ = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
