"""Attention: GQA (+bias), sliding-window, MLA, blockwise long-seq, KV-cache decode.

Three execution paths per variant:
  * ``attend_full``      — O(s²) einsum + causal mask (short sequences).
  * ``attend_blockwise`` — scan over query chunks; memory O(s·chunk) instead
    of O(s²).  Sliding-window attention additionally slices the KV band, so
    flops drop to O(s·window).
  * ``decode``           — one new token against a KV cache (full-length
    cache, or ring-buffer cache for sliding-window).

GQA layout: q (b, s, n_heads, hd); k/v (b, s, n_kv, hd); heads grouped as
(n_kv, group) for the score einsums so XLA sees the kv-head dim it can shard.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["AttnCfg", "attention_init", "attention_apply", "attention_decode",
           "init_kv_cache", "mla_init", "mla_apply", "mla_decode",
           "init_mla_cache", "NEG_INF"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False          # qwen2
    window: Optional[int] = None    # sliding-window size (None = full causal)
    q_chunk: int = 1024      # blockwise query-chunk length  # lint: allow
    blockwise_threshold: int = 8192  # use blockwise when seq >= this
    rope_theta: float = 10000.0
    # MLA dims (minicpm3 / deepseek-v2 style); used only by the mla_* path
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


# ============================================================================ GQA
def attention_init(key, cfg: AttnCfg, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": dense_init(kq, d, h * hd, dtype, use_bias=cfg.qkv_bias),
        "wk": dense_init(kk, d, kvh * hd, dtype, use_bias=cfg.qkv_bias),
        "wv": dense_init(kv, d, kvh * hd, dtype, use_bias=cfg.qkv_bias),
        "wo": dense_init(ko, h * hd, d, dtype),
    }


def _qkv(params, x, cfg: AttnCfg, cos, sin, positions):
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(params["wq"], x).reshape(b, s, h, hd)
    k = dense(params["wk"], x).reshape(b, s, kvh, hd)
    v = dense(params["wv"], x).reshape(b, s, kvh, hd)
    q = apply_rope(q, cos, sin, positions)
    k = apply_rope(k, cos, sin, positions)
    return q, k, v


def _scores_to_out(q, k, v, mask, scale):
    """q: (b,sq,kv,g,hd); k/v: (b,sk,kv,hd); mask: (b|1,1|kv?,sq,sk) bool."""
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    # cast the fill explicitly: a bare python float is a weak f64 scalar
    # under jax_enable_x64 and would promote the whole softmax to f64
    # (repro.analysis.jaxpr_check's no-f64 contract)
    logits = jnp.where(mask[:, None, None, :, :], logits,
                       jnp.float32(NEG_INF))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _group(q, cfg: AttnCfg):
    b, s, h, hd = q.shape
    return q.reshape(b, s, cfg.n_kv_heads, h // cfg.n_kv_heads, hd)


def attend_full(q, k, v, cfg: AttnCfg, q_positions, k_positions):
    """Materialized causal (+optional sliding-window) attention."""
    scale = cfg.head_dim ** -0.5
    qg = _group(q, cfg)
    caus = q_positions[:, :, None] >= k_positions[:, None, :]
    if cfg.window is not None:
        caus &= (q_positions[:, :, None] - k_positions[:, None, :]) < cfg.window
    out = _scores_to_out(qg, k, v, caus, scale)
    b, s = q.shape[0], q.shape[1]
    # v head dim may differ from qk head dim (MLA)
    return out.reshape(b, s, cfg.n_heads, v.shape[-1])


def attend_blockwise(q, k, v, cfg: AttnCfg, q_positions, k_positions):
    """Scan over query chunks; SWA slices a static-size KV band per chunk."""
    b, s, h, hd = q.shape
    cq = min(cfg.q_chunk, s)
    assert s % cq == 0, f"seq {s} not divisible by q_chunk {cq}"
    nchunks = s // cq
    scale = hd ** -0.5
    qg = _group(q, cfg)

    if cfg.window is not None:
        band = cq + ((cfg.window + cq - 1) // cq) * cq  # static KV band length
        pad = band - cq
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        posp = jnp.pad(k_positions, ((0, 0), (pad, 0)), constant_values=-1)

        def chunk_fn(_, i):
            qs = jax.lax.dynamic_slice_in_dim(qg, i * cq, cq, axis=1)
            qpos = jax.lax.dynamic_slice_in_dim(q_positions, i * cq, cq, axis=1)
            ks = jax.lax.dynamic_slice_in_dim(kp, i * cq, band, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, i * cq, band, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(posp, i * cq, band, axis=1)
            m = (qpos[:, :, None] >= kpos[:, None, :]) & (kpos[:, None, :] >= 0)
            m &= (qpos[:, :, None] - kpos[:, None, :]) < cfg.window
            return None, _scores_to_out(qs, ks, vs, m, scale)
    else:
        def chunk_fn(_, i):
            qs = jax.lax.dynamic_slice_in_dim(qg, i * cq, cq, axis=1)
            qpos = jax.lax.dynamic_slice_in_dim(q_positions, i * cq, cq, axis=1)
            m = qpos[:, :, None] >= k_positions[:, None, :]
            return None, _scores_to_out(qs, k, v, m, scale)

    _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(nchunks))
    # outs: (nchunks, b, cq, kv, g, vd) -> (b, s, h, vd)
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.n_heads, v.shape[-1])
    return outs


def _noshd(x, *names):
    return x


def attention_apply(params, x, cfg: AttnCfg, cos, sin, positions=None,
                    force_blockwise: Optional[bool] = None, shd=_noshd):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _qkv(params, x, cfg, cos, sin, positions)
    # perf lever (attn_ctx_shard): queries seq-sharded over the tp axis,
    # k/v replicated -> the s² score tensors partition over query chunks
    # with no sharded-contraction psum.
    q = shd(q, "batch", "seq_q", "heads", "head")
    k = shd(k, "batch", "seq_kv", "kv", "head")
    v = shd(v, "batch", "seq_kv", "kv", "head")
    blockwise = (s >= cfg.blockwise_threshold if force_blockwise is None
                 else force_blockwise)
    attend = attend_blockwise if blockwise else attend_full
    out = attend(q, k, v, cfg, positions, positions)
    out = shd(out, "batch", "seq_q", "heads", "head")
    return dense(params["wo"], out.reshape(b, s, -1))


def _prompt_cache(cfg: AttnCfg, k, v, positions, max_len: int):
    """Pack a full-prompt K/V into the decode cache layout.

    Full cache: positions 0..s-1 at slots 0..s-1, rest invalid.
    Ring (SWA) cache: position p lives at slot p % slots — for the
    consecutive prompt tail this is a roll by (s mod slots).
    """
    b, s = positions.shape
    slots = max_len if cfg.window is None else min(cfg.window, max_len)
    if cfg.window is not None and s > slots:
        k_t, v_t = k[:, s - slots:], v[:, s - slots:]
        p_t = positions[:, s - slots:]
        sh = s % slots
        return {"k": jnp.roll(k_t, sh, axis=1),
                "v": jnp.roll(v_t, sh, axis=1),
                "pos": jnp.roll(p_t, sh, axis=1)}
    pad = slots - s
    return {
        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "pos": jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1),
    }


def attention_prefill(params, x, cfg: AttnCfg, cos, sin, max_len: int,
                      positions=None, shd=_noshd):
    """Full-sequence forward that also emits the decode cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _qkv(params, x, cfg, cos, sin, positions)
    q = shd(q, "batch", "seq_q", "heads", "head")
    k = shd(k, "batch", "seq_kv", "kv", "head")
    v = shd(v, "batch", "seq_kv", "kv", "head")
    blockwise = s >= cfg.blockwise_threshold
    attend = attend_blockwise if blockwise else attend_full
    out = attend(q, k, v, cfg, positions, positions)
    out = shd(out, "batch", "seq_q", "heads", "head")
    y = dense(params["wo"], out.reshape(b, s, -1))
    return y, _prompt_cache(cfg, k, v, positions, max_len)


# ---------------------------------------------------------------------------- decode
def init_kv_cache(cfg: AttnCfg, batch: int, max_len: int, dtype):
    """Full cache, or ring buffer of ``window`` slots for sliding-window."""
    slots = max_len if cfg.window is None else min(cfg.window, max_len)
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def attention_decode(params, x, cache, pos, cfg: AttnCfg, cos, sin):
    """One-step decode.  x: (b, 1, d); pos: scalar int32 current position."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(params, x, cfg, cos, sin, positions)

    slots = cache["k"].shape[1]
    slot = pos % slots if cfg.window is not None else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, slot, axis=1)

    scale = cfg.head_dim ** -0.5
    qg = _group(q, cfg)
    mask = (cpos >= 0) & (cpos <= pos)
    if cfg.window is not None:
        mask &= cpos > pos - cfg.window
    out = _scores_to_out(qg, k, v, mask[:, None, :], scale)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = dense(params["wo"], out)
    return y, {"k": k, "v": v, "pos": cpos}


# ============================================================================ MLA
def mla_init(key, cfg: AttnCfg, dtype):
    ks = jax.random.split(key, 7)
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wdq": dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(cfg.q_lora_rank, dtype),
        "wuq": dense_init(ks[1], cfg.q_lora_rank, h * qk, dtype),
        "wdkv": dense_init(ks[2], d, cfg.kv_lora_rank, dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkr": dense_init(ks[3], d, cfg.qk_rope_dim, dtype),
        "wuk": dense_init(ks[4], cfg.kv_lora_rank, h * cfg.qk_nope_dim, dtype),
        "wuv": dense_init(ks[5], cfg.kv_lora_rank, h * cfg.v_head_dim, dtype),
        "wo": dense_init(ks[6], h * cfg.v_head_dim, d, dtype),
    }


def _mla_qkv(params, x, cfg: AttnCfg, cos, sin, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(params["q_norm"], dense(params["wdq"], x))
    q = dense(params["wuq"], cq).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, cos, sin, positions)

    ckv = rmsnorm(params["kv_norm"], dense(params["wdkv"], x))  # (b,s,r)
    k_rope = dense(params["wkr"], x)[:, :, None, :]             # shared head
    k_rope = apply_rope(k_rope, cos, sin, positions)
    return q_nope, q_rope, ckv, k_rope


def _mla_expand(params, ckv, k_rope, cfg: AttnCfg):
    b, s, _ = ckv.shape
    h = cfg.n_heads
    k_nope = dense(params["wuk"], ckv).reshape(b, s, h, cfg.qk_nope_dim)
    v = dense(params["wuv"], ckv).reshape(b, s, h, cfg.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], axis=-1)
    return k, v


def mla_apply(params, x, cfg: AttnCfg, cos, sin, positions=None):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, x, cfg, cos, sin, positions)
    k, v = _mla_expand(params, ckv, k_rope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # MLA is MHA (n_kv == n_heads) over qk = nope+rope dims
    mcfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads,
                               head_dim=cfg.qk_nope_dim + cfg.qk_rope_dim)
    blockwise = s >= cfg.blockwise_threshold
    attend = attend_blockwise if blockwise else attend_full
    out = attend(q, k, v, mcfg, positions, positions)
    return dense(params["wo"], out.reshape(b, s, -1))


def mla_prefill(params, x, cfg: AttnCfg, cos, sin, max_len: int,
                positions=None):
    """MLA full-sequence forward that also emits the compressed cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, x, cfg, cos, sin, positions)
    k, v = _mla_expand(params, ckv, k_rope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    mcfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads,
                               head_dim=cfg.qk_nope_dim + cfg.qk_rope_dim)
    attend = attend_blockwise if s >= cfg.blockwise_threshold else attend_full
    out = attend(q, k, v, mcfg, positions, positions)
    y = dense(params["wo"], out.reshape(b, s, -1))
    pad = max_len - s
    cache = {
        "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
        "krope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "pos": jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1),
    }
    return y, cache


def init_mla_cache(cfg: AttnCfg, batch: int, max_len: int, dtype):
    """Compressed cache: latent c_kv + shared rotary key — the MLA win."""
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, 1, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode(params, x, cache, pos, cfg: AttnCfg, cos, sin):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv(
        params, x, cfg, cos, sin, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], krope_new, pos, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions, pos, axis=1)

    k, v = _mla_expand(params, ckv, krope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    mcfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads,
                               head_dim=cfg.qk_nope_dim + cfg.qk_rope_dim)
    mask = (cpos >= 0) & (cpos <= pos)
    out = _scores_to_out(_group(q, mcfg), k, v, mask[:, None, :],
                         mcfg.head_dim ** -0.5)
    y = dense(params["wo"], out.reshape(b, 1, -1))
    return y, {"ckv": ckv, "krope": krope, "pos": cpos}
