"""Multi-device correctness (8 forced host devices, run in a subprocess so
the main pytest process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, functools
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.core import PDSGDM, PDSGDMConfig, CPDSGDM, CPDSGDMConfig, SignCompressor
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.models import make_model

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    OPT = os.environ["TEST_OPT"]
    run = RunCfg(model=mcfg, parallel=ParallelCfg(profile="A", remat="none"),
                 optim=OptimCfg(name=OPT, eta=0.05, mu=0.9, p=2,
                                weight_decay=1e-4))
    mesh = make_debug_mesh(4, 2)   # 4 workers x TP2
    shape = InputShape("t", 16, 8, "train")
    pack = build_train(run, mesh, shape)
    K = pack.layout.n_workers
    assert K == 4, K
    params, state = pack.init_fn(jax.random.PRNGKey(0))
    batches = [train_batch_arrays(mcfg, K, 2, 16,
               jax.random.fold_in(jax.random.PRNGKey(1), t)) for t in range(6)]
    for b in batches:
        params, state, loss = pack.train_step(params, state, b)
    sharded_final = jax.tree_util.tree_map(np.asarray, params)

    # --- dense single-device simulation of the same run
    model = make_model(mcfg)
    params2 = jax.vmap(lambda k: model.init(jax.random.PRNGKey(0)))(
        jax.random.split(jax.random.PRNGKey(0), K))
    comm = DenseComm(ring(K))
    if OPT == "pd_sgdm":
        opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=2, weight_decay=1e-4), comm)
    else:
        opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4,
                                    weight_decay=1e-4), comm, SignCompressor())
    st = opt.init(params2)
    gradf = jax.vmap(jax.value_and_grad(lambda p, b: model.loss(p, b)[0]))
    stepf = jax.jit(lambda st, p, b: opt.step(st, p, gradf(p, b)[1]))
    for b in batches:
        params2, st = stepf(st, params2, b)
    sim_final = jax.tree_util.tree_map(np.asarray, params2)

    errs = [np.abs(a - b).max() for a, b in
            zip(jax.tree_util.tree_leaves(sharded_final),
                jax.tree_util.tree_leaves(sim_final))]
    print("max leaf err:", max(errs))
    # PD-SGDM: gossip is linear => bitwise-equivalent up to reduction order.
    # CPD-SGDM: sign-compression *blocks* are per-device-shard in production
    # (compression happens where the data lives) vs whole-leaf in the
    # simulation, so Q(x) differs slightly where leaves are model-sharded;
    # the delta-contraction property holds either way (Definition 1 applies
    # to the concatenation), so trajectories agree to compression noise.
    tol = 5e-4 if OPT == "pd_sgdm" else 8e-3
    assert max(errs) < tol, max(errs)
    # worker-mean must be preserved by the comm round in both backends
    for a, b in zip(jax.tree_util.tree_leaves(sharded_final),
                    jax.tree_util.tree_leaves(sim_final)):
        np.testing.assert_allclose(a.mean(0), b.mean(0), atol=2e-3)
    print("EQUIV_OK", OPT)
""")


def _run(script, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_equals_dense_sim_pdsgdm():
    """ppermute gossip over the mesh == dense W-matmul simulation."""
    out = _run(_SCRIPT_EQUIV, {"TEST_OPT": "pd_sgdm"})
    assert "EQUIV_OK pd_sgdm" in out


@pytest.mark.slow
def test_sharded_equals_dense_sim_cpdsgdm():
    """packed-sign ppermute exchange == dense simulated CPD-SGDM."""
    out = _run(_SCRIPT_EQUIV, {"TEST_OPT": "cpd_sgdm"})
    assert "EQUIV_OK cpd_sgdm" in out


_SCRIPT_COLLECTIVES = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.launch.hlo_analysis import parse_collectives

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    for opt_name, want_permute in [("pd_sgdm", True), ("cpd_sgdm", True),
                                   ("c_sgdm", False)]:
        run = RunCfg(model=mcfg, parallel=ParallelCfg(profile="A"),
                     optim=OptimCfg(name=opt_name, p=2))
        mesh = make_debug_mesh(4, 2)
        pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
        lowered = pack.train_round.lower(pack.params_struct,
                                         pack.state_struct,
                                         pack.round_batch_struct)
        txt = lowered.compile().as_text()
        st = parse_collectives(txt)
        has_permute = st.counts.get("collective-permute", 0) > 0
        assert has_permute == want_permute, (opt_name, st.counts)
        if opt_name == "cpd_sgdm":
            # packed wire: at least one u8 collective-permute (the sign bits)
            assert any("u8[" in l for l in st.lines
                       if "collective-permute" in l), st.lines
        print(opt_name, st.counts)
    print("COLLECTIVES_OK")
""")


@pytest.mark.slow
def test_gossip_lowers_to_collective_permute():
    """PD/CPD gossip must appear as collective-permute in the compiled HLO;
    C-SGDM must not (it is all-reduce based).  CPD's payload must be uint8
    (bit-packed) — the compression is real bytes on the wire."""
    out = _run(_SCRIPT_COLLECTIVES)
    assert "COLLECTIVES_OK" in out
