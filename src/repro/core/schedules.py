"""Learning-rate schedules (multiplicative factors; peak LR lives in config).

The paper uses step decay (×0.1 at epoch milestones); we additionally provide
warmup-cosine for the LM pretraining examples.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

__all__ = ["constant", "step_decay", "warmup_cosine"]


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def step_decay(milestones: Sequence[int], factor: float = 0.1):
    """×factor at each milestone step (paper: epochs {150,225} / {30,60,80})."""
    ms = jnp.asarray(sorted(milestones), jnp.int32)

    def fn(step):
        n = jnp.sum(step >= ms)
        return jnp.power(jnp.float32(factor), n.astype(jnp.float32))

    return fn


def warmup_cosine(warmup_steps: int, total_steps: int, min_factor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        w = jnp.float32(max(warmup_steps, 1))
        warm = step / w
        t = jnp.clip((step - w) / jnp.maximum(total_steps - w, 1.0), 0.0, 1.0)
        cos = min_factor + (1 - min_factor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < w, warm, cos)

    return fn
