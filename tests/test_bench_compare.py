"""Exit-code contract of tools/bench_compare.py: 0 green, 1 regression or
missing gated row, 2 bad spec / empty gate; --spec appends custom gates."""
import importlib.util
import json
import os

REPO = os.path.join(os.path.dirname(__file__), "..")


def _main():
    path = os.path.join(REPO, "tools", "bench_compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare_cli", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.main


def _doc(rows):
    return {"rows": [{"name": n, "derived": d} for n, d in rows]}


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(_doc(rows)))
    return str(p)


BASE_ROWS = [
    ("kernel_path/speedup_p4", {"fused_vs_perstep_parity": 1.0}),
    ("wire_codecs/sign", {"x_bf16": 16.0}),
]


def test_green(tmp_path):
    base = _write(tmp_path, "base.json", BASE_ROWS)
    fresh = _write(tmp_path, "fresh.json", BASE_ROWS)
    assert _main()(["--fresh", fresh, "--baseline", base]) == 0


def test_ratio_below_floor(tmp_path):
    base = _write(tmp_path, "base.json", BASE_ROWS)
    fresh = _write(tmp_path, "fresh.json", [
        ("kernel_path/speedup_p4", {"fused_vs_perstep_parity": 0.3}),
        ("wire_codecs/sign", {"x_bf16": 16.0}),
    ])  # 0.3 < 0.5 × baseline
    assert _main()(["--fresh", fresh, "--baseline", base]) == 1


def test_byte_ratio_drift(tmp_path):
    base = _write(tmp_path, "base.json", BASE_ROWS)
    fresh = _write(tmp_path, "fresh.json", [
        ("kernel_path/speedup_p4", {"fused_vs_perstep_parity": 1.0}),
        ("wire_codecs/sign", {"x_bf16": 15.0}),
    ])  # |Δ|/baseline = 6.25% > 2%
    assert _main()(["--fresh", fresh, "--baseline", base]) == 1


def test_missing_gated_row_fails(tmp_path):
    """A silently dropped benchmark must not read as green."""
    base = _write(tmp_path, "base.json", BASE_ROWS)
    fresh = _write(tmp_path, "fresh.json", BASE_ROWS[:1])  # sign row gone
    assert _main()(["--fresh", fresh, "--baseline", base]) == 1


def test_fresh_only_rows_ignored(tmp_path):
    """New benchmarks land before their baseline — extra fresh rows pass."""
    base = _write(tmp_path, "base.json", BASE_ROWS)
    fresh = _write(tmp_path, "fresh.json", BASE_ROWS + [
        ("wire_codecs/newcodec", {"x_bf16": 4.0})])
    assert _main()(["--fresh", fresh, "--baseline", base]) == 0


def test_bad_spec(tmp_path):
    base = _write(tmp_path, "base.json", BASE_ROWS)
    fresh = _write(tmp_path, "fresh.json", BASE_ROWS)
    assert _main()(["--fresh", fresh, "--baseline", base,
                    "--spec", "not-a-spec"]) == 2


def test_empty_gate_refused(tmp_path):
    """Zero matched rows is a refusal (2), not a pass."""
    rows = [("other/row", {"some_key": 1.0})]
    base = _write(tmp_path, "base.json", rows)
    fresh = _write(tmp_path, "fresh.json", rows)
    assert _main()(["--fresh", fresh, "--baseline", base]) == 2


def test_spec_override_gates_custom_row(tmp_path):
    rows_ok = BASE_ROWS + [("custom/row", {"ratio": 2.0})]
    base = _write(tmp_path, "base.json", rows_ok)
    fresh_bad = _write(tmp_path, "fresh.json", BASE_ROWS + [
        ("custom/row", {"ratio": 0.5})])
    spec = "custom/*:ratio:min_frac=0.9"
    assert _main()(["--fresh", fresh_bad, "--baseline", base,
                    "--spec", spec]) == 1
    fresh_ok = _write(tmp_path, "fresh2.json", rows_ok)
    assert _main()(["--fresh", fresh_ok, "--baseline", base,
                    "--spec", spec]) == 0
