"""Ablation: data heterogeneity (non-IID Dirichlet splits) × communication
period p.

The paper's Assumption 4 bounds per-worker gradients uniformly; in practice
heterogeneity is where decentralized methods diverge from centralized ones.
Workers draw labels from Dirichlet(α) class distributions — small α =
strongly non-IID — and we sweep p to show the consensus/staleness trade-off.

  PYTHONPATH=src python examples/noniid_ablation.py
"""
import jax

from repro.core import make_optimizer
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.data.synthetic import ClassStreamCfg, class_batch
from repro.models.resnet import resnet20_init, resnet20_loss
from repro.train.trainer import SimTrainer

import jax.numpy as jnp

K, STEPS = 8, 50


def stacked(width=4):
    p = resnet20_init(jax.random.PRNGKey(0), width=width)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), p)


print(f"{'alpha':>8}{'p':>4}{'final loss':>12}{'comm MB':>9}")
for alpha in [None, 1.0, 0.1]:
    for p in [1, 4, 16]:
        cfg = ClassStreamCfg(batch=16, n_workers=K, dirichlet_alpha=alpha)
        opt = make_optimizer("pd_sgdm", DenseComm(ring(K)), eta=0.1,
                             mu=0.9, p=p, weight_decay=1e-4)
        # one fused log block for the whole sweep point: the round engine
        # syncs the host once at the end instead of every step
        trainer = SimTrainer(resnet20_loss, opt)
        _, _, h = trainer.train(stacked(), lambda t: class_batch(cfg, t),
                                STEPS, log_every=STEPS - 1)
        label = "IID" if alpha is None else f"{alpha:g}"
        print(f"{label:>8}{p:>4}{h.loss[-1]:>12.4f}{h.comm_mb[-1]:>9.2f}")
print("\nreading: within every alpha row the loss degrades as p grows — "
      "the staleness Theorem 1 prices via p²G²/ρ².  Note the *local* loss "
      "is easier under strong non-IID (a worker seeing few classes has a "
      "simpler problem); judge heterogeneity on the averaged model over "
      "the global distribution (SimTrainer's eval_fn hook).")
