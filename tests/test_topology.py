"""Topology invariants (paper Assumption 1 / Lemma 1) and schedule cycles."""
import numpy as np
import pytest

from repro.core.topology import (alternating_axes_schedule, complete,
                                 cycle_spectral_gap, disconnected,
                                 exponential, is_doubly_stochastic,
                                 make_schedule, make_topology, mixing_gap,
                                 one_peer_exponential_schedule,
                                 random_matching_schedule, ring,
                                 spectral_gap, static_schedule, torus)

TOPOLOGIES = [
    ring(2), ring(3), ring(8), ring(16),
    torus((2, 8)), torus((2, 16)), torus((4, 4)),
    complete(8), complete(5), exponential(16), exponential(8),
    disconnected(4),
]

SCHEDULES = [
    static_schedule(ring(8)),
    one_peer_exponential_schedule(8),
    one_peer_exponential_schedule(16),
    one_peer_exponential_schedule(12),     # K not a power of two
    alternating_axes_schedule((2, 8)),
    alternating_axes_schedule((4, 4)),
    random_matching_schedule(8, 4, seed=3),
    random_matching_schedule(7, 3, seed=0),  # odd K: one idle worker/round
]


@pytest.mark.parametrize("top", TOPOLOGIES, ids=lambda t: f"{t.name}{t.n_workers}")
def test_doubly_stochastic(top):
    top.validate()
    assert is_doubly_stochastic(top.W)


@pytest.mark.parametrize("top", TOPOLOGIES, ids=lambda t: f"{t.name}{t.n_workers}")
def test_spectral_gap_range(top):
    rho = top.rho
    if top.name == "disconnected":
        assert rho == pytest.approx(0.0, abs=1e-12)
    else:
        assert 0.0 < rho <= 1.0 + 1e-12


@pytest.mark.parametrize("top", [ring(8), torus((2, 8)), complete(8),
                                 exponential(16)],
                         ids=lambda t: f"{t.name}{t.n_workers}")
def test_lemma1_operator_norm(top):
    """‖W − 11ᵀ/K‖₂ = 1 − ρ  (Lemma 1)."""
    K = top.n_workers
    M = top.W - np.ones((K, K)) / K
    opnorm = np.linalg.norm(M, 2)
    assert opnorm == pytest.approx(1.0 - top.rho, abs=1e-8)


@pytest.mark.parametrize("top", TOPOLOGIES, ids=lambda t: f"{t.name}{t.n_workers}")
def test_structure_matches_dense_w(top):
    """The shift/perm structure (what the ppermute backend executes) must
    reproduce the constructor-built dense W for *every* topology — this is
    the cross-check that catches drift like the ``exponential()``
    ±K/2-alias/symmetrization case at K a power of two."""
    assert np.allclose(top.structure_matrix(), top.W, atol=1e-9), top.name


@pytest.mark.parametrize("sched", SCHEDULES, ids=lambda s: f"{s.name}{s.n_workers}")
def test_schedule_structure_every_step(sched):
    """Extend the structure-vs-W cross-check to every step of every
    time-varying schedule, plus per-round double stochasticity (symmetry
    only where the round claims it — one-peer rounds are directed)."""
    sched.validate()
    for r in range(sched.period):
        top = sched.at(r)
        assert np.allclose(top.structure_matrix(), top.W, atol=1e-9), (
            sched.name, r)
        assert is_doubly_stochastic(top.W,
                                    require_symmetric=top.symmetric), (
            sched.name, r)
    # wrap-around: at(T) is round 0 again
    assert sched.at(sched.period) is sched.at(0)


def test_one_peer_exp_cycle_exact_average():
    """K a power of two: the ⌈log₂K⌉-round one-peer cycle product is the
    exact global average — cycle_rho == 1 at degree 1 per round."""
    for K in (4, 8, 16):
        s = one_peer_exponential_schedule(K)
        assert s.degrees() == (1,) * s.period
        assert np.allclose(s.cycle_product(), np.ones((K, K)) / K, atol=1e-12)
        assert s.cycle_rho == pytest.approx(1.0, abs=1e-9)
    # K not a power of two: still mixes, just not exactly
    s12 = one_peer_exponential_schedule(12)
    assert 0.0 < s12.cycle_rho < 1.0


def test_alt_axes_cycle_equals_torus():
    """Alternating per-axis ring rounds compose to the full Kronecker torus
    over one cycle (the factors commute), at half the per-round degree."""
    shape = (4, 4)
    s = alternating_axes_schedule(shape)
    assert np.allclose(s.cycle_product(), torus(shape).W, atol=1e-12)
    assert cycle_spectral_gap([t.W for t in s.topologies]) == pytest.approx(
        mixing_gap(torus(shape).W), abs=1e-9)
    assert all(d == 2 for d in s.degrees())   # one ring axis per round


def test_random_matching_rounds_are_symmetric_pair_averages():
    s = random_matching_schedule(8, 5, seed=11)
    for top in s.topologies:
        assert top.symmetric
        assert is_doubly_stochastic(top.W)
        # matching: each row has the self weight and at most one partner
        offdiag = top.W - np.diag(np.diag(top.W))
        assert np.all((offdiag == 0) | (offdiag == 0.5))
        assert np.allclose(top.W, top.W.T)
    # seeded determinism: same seed → identical matrices
    s2 = random_matching_schedule(8, 5, seed=11)
    for a, b in zip(s.topologies, s2.topologies):
        assert np.array_equal(a.W, b.W)


def test_make_schedule_factory():
    assert make_schedule("static", (8,)).period == 1
    assert make_schedule("one_peer_exp", (8,)).period == 3
    assert make_schedule("alt_axes", (2, 8)).period == 2
    assert make_schedule("random_matching", (8,), rounds=4, seed=1).period == 4
    with pytest.raises(ValueError):
        make_schedule("one_peer_exp", (2, 4))   # needs a single worker axis
    with pytest.raises(ValueError):
        make_schedule("nope", (8,))


def test_shifts_reconstruct_w():
    """The shift decomposition must reproduce the dense circulant W."""
    for top in [ring(8), torus((2, 8)), exponential(8)]:
        K = top.n_workers
        grid = top.axis_sizes
        W = np.zeros((K, K))
        import itertools
        for idx in itertools.product(*[range(s) for s in grid]):
            k = np.ravel_multi_index(idx, grid)
            acc = {k: 1.0}
            for ax in range(len(grid)):
                new = {}
                for j, wj in acc.items():
                    jidx = list(np.unravel_index(j, grid))
                    for (a, sh, w) in top.shifts:
                        if a != ax:
                            continue
                        t = jidx.copy()
                        t[ax] = (t[ax] + sh) % grid[ax]
                        jj = np.ravel_multi_index(t, grid)
                        new[jj] = new.get(jj, 0.0) + wj * w
                if any(a == ax for (a, _s, _w) in top.shifts):
                    acc = new
            for j, w in acc.items():
                W[k, j] += w
        assert np.allclose(W, top.W, atol=1e-9), top.name


def test_make_topology():
    assert make_topology("ring", (8,)).n_workers == 8
    assert make_topology("torus", (2, 16)).n_workers == 32
    assert make_topology("complete", (4,)).rho == pytest.approx(1.0)
    with pytest.raises(ValueError):
        make_topology("nope", (4,))


def test_torus_beats_long_ring():
    """Hierarchical pod×ring mixing has a larger spectral gap than one ring
    of the same size — the reason the multi-pod layout uses it."""
    assert torus((2, 16)).rho > ring(32).rho
