"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED same-family variant (2 layers, d_model ≤ 512,
≤ 4 experts) and runs one forward + one PD-SGDM train step on CPU, asserting
output shapes and the absence of NaNs.  The FULL configs are exercised by
the multi-pod dry-run (ShapeDtypeStruct only).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ASSIGNED, get_config, get_smoke_config
from repro.configs.shapes import train_batch_arrays
from repro.core import PDSGDM, PDSGDMConfig
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.models import make_model


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    run = get_smoke_config(arch)
    mcfg = run.model
    assert mcfg.n_layers <= max(2, len(mcfg.pattern))
    assert mcfg.d_model <= 512
    assert mcfg.n_experts <= 4

    model = make_model(mcfg)
    K, b, s = 2, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(0), K)
    params = jax.vmap(lambda k: model.init(jax.random.PRNGKey(0)))(keys)
    batch = train_batch_arrays(mcfg, K, b, s, jax.random.PRNGKey(1))

    # forward: logits shape + finite
    logits, aux = model.apply(
        params and jax.tree_util.tree_map(lambda x: x[0], params),
        {k: v[0] for k, v in batch.items() if k != "labels"})
    assert logits.shape[0] == b and logits.shape[-1] == mcfg.vocab
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one decentralized train step across K=2 workers
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=1), DenseComm(ring(K)))
    state = opt.init(params)
    lossf = jax.vmap(jax.value_and_grad(
        lambda p, bb: model.loss(p, bb)[0]))
    losses, grads = lossf(params, batch)
    new_params, state = opt.step(state, params, grads)
    assert bool(jnp.isfinite(losses).all()), f"{arch}: NaN loss"
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32)
                      - b_.astype(jnp.float32)).max()) > 0
        for a, b_ in zip(jax.tree_util.tree_leaves(new_params),
                         jax.tree_util.tree_leaves(params)))
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned dimensions."""
    spec = {
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000,
                            n_experts=128),
        "mixtral-8x7b": dict(n_layers=32, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=14336, vocab=32000,
                             n_experts=8),
        "stablelm-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab=100352),
        "olmo-1b": dict(n_layers=16, d_model=2048, n_heads=16,
                        n_kv_heads=16, d_ff=8192, vocab=50304,
                        norm="nonparametric"),
        "qwen2-72b": dict(n_layers=80, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=29568, vocab=152064,
                          qkv_bias=True),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab=2048,
                                input_mode="embeds"),
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            n_kv_heads=40, d_ff=6400, vocab=73448,
                            use_mla=True),
        "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=28672, vocab=128256,
                              input_mode="vlm"),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=24576, vocab=65536,
                                     n_experts=16),
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, d_ff=0,
                            vocab=50280, ssm_state=128),
    }[arch]
    m = get_config(arch).model
    for k, v in spec.items():
        assert getattr(m, k) == v, (arch, k, getattr(m, k), v)
    assert m.source, arch


def test_jamba_interleave_ratio():
    m = get_config("jamba-1.5-large-398b").model
    mixers = [s.mixer for s in m.pattern]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    ffns = [s.ffn for s in m.pattern]
    assert ffns.count("moe") == 4  # every other layer
