"""qwen2-72b — Qwen2 [arXiv:2407.10671].

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064.
QKV bias (Qwen's signature), RMSNorm, rope_theta 1e6.
"""
from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="qwen2-72b", arch_type="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2407.10671",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="B"),
                  optim=OptimCfg())
