"""Fault injection for elastic-membership chaos tests.

Three pieces, shared by ``tests/test_chaos.py`` and
``benchmarks/elastic_sweep.py``:

* **Scripts** — ``chaos_script`` draws a seeded kill / revive / straggle
  event sequence that never drops the fleet below ``min_live`` live
  workers; ``membership_for`` compiles it into the core
  ``MembershipSchedule`` that masks the mixing matrices.

* **Driver** — ``run_dense_chaos`` runs any fused-round optimizer built
  on a membership-carrying ``DenseComm`` through ``n_rounds`` rounds of
  churn, applying ``warm_start_worker`` at each revival *before* the
  revival round (the rejoined worker's first exchange carries a live
  model, not its stale pre-kill shard), and records per-round survivor
  metrics: consensus distance over live workers, loss of the
  live-worker-averaged model, live counts and accounted wire bytes.

* **Oracle** — ``oracle_fleet_bytes`` re-derives the fleet's shipped
  bytes per round from the *structure* mixing matrix's support and the
  round's active mask (plus an independently derived commit set for
  CPD), never from ``edges_per_worker`` / ``_commit_mask``: the
  accounted ≡ shipped invariant is checked through a different code
  path.  The support enumeration assumes every off-diagonal exchange is
  a distinct graph edge (true for ring / exponential / complete at the
  K ≥ 3 sizes the chaos tests use; aliased shifts would collapse matrix
  entries).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.elastic import pick_donor, warm_start_worker
from repro.core.topology import MembershipSchedule, membership_from_events

__all__ = ["ChaosEvent", "ChaosRun", "chaos_script", "check_round_matrix",
           "membership_for", "oracle_fleet_bytes", "revivals_by_round",
           "run_dense_chaos"]

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One membership fault: ``kind`` ∈ {kill, revive, straggle}, applied
    at communication round ``round`` to worker ``worker``.  A kill holds
    until the matching revive; a straggle masks one round only."""
    round: int
    kind: str
    worker: int


def chaos_script(n_workers: int, n_rounds: int, *, seed: int,
                 kill_prob: float = 0.15, straggle_prob: float = 0.15,
                 down_rounds: int = 2, min_live: int = 2
                 ) -> List[ChaosEvent]:
    """Seeded churn: each round, each live worker dies with ``kill_prob``
    (reviving ``down_rounds`` rounds later) or straggles one round with
    ``straggle_prob``.  Kills that would leave fewer than ``min_live``
    live workers are skipped, so the masked matrix always has a live
    quorum to renormalize over.  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    live = np.ones(n_workers, dtype=bool)
    pending: Dict[int, List[int]] = {}          # revive round -> workers
    events: List[ChaosEvent] = []
    for r in range(n_rounds):
        for w in pending.pop(r, []):
            events.append(ChaosEvent(r, "revive", w))
            live[w] = True
        for w in range(n_workers):
            if not live[w]:
                continue
            u = rng.random()
            if u < kill_prob and live.sum() > min_live:
                events.append(ChaosEvent(r, "kill", w))
                live[w] = False
                back = r + down_rounds
                if back < n_rounds:
                    pending.setdefault(back, []).append(w)
            elif u < kill_prob + straggle_prob:
                events.append(ChaosEvent(r, "straggle", w))
    return events


def membership_for(n_workers: int, n_rounds: int,
                   events: Sequence[ChaosEvent]) -> MembershipSchedule:
    """Compile a chaos script into the core membership schedule."""
    return membership_from_events(n_workers, n_rounds, events)


def revivals_by_round(events: Sequence[ChaosEvent]) -> Dict[int, List[int]]:
    """round -> workers rejoining at that round (warm-start points)."""
    out: Dict[int, List[int]] = {}
    for ev in events:
        if ev.kind == "revive":
            out.setdefault(ev.round, []).append(ev.worker)
    return out


# ------------------------------------------------------------------ invariants
def check_round_matrix(comm, r: int, atol: float = 1e-12) -> np.ndarray:
    """Assert round ``r``'s effective mixing matrix honours the liveness
    mask: every row sums to 1, masked-out workers hold the identity row
    e_k, and no active row reads from a masked-out column.  Returns the
    matrix for further checks."""
    W = np.asarray(comm.effective_matrix(r), dtype=np.float64)
    act = np.asarray(comm.active_at(r), dtype=bool)
    K = W.shape[0]
    np.testing.assert_allclose(W.sum(axis=1), np.ones(K), atol=atol,
                               err_msg=f"round {r}: rows not stochastic")
    for k in np.flatnonzero(~act):
        np.testing.assert_allclose(
            W[k], np.eye(K)[k], atol=atol,
            err_msg=f"round {r}: masked worker {k} row is not e_k")
    dead_cols = W[np.ix_(act, ~act)]
    if dead_cols.size:
        np.testing.assert_allclose(
            dead_cols, 0.0, atol=atol,
            err_msg=f"round {r}: active rows read masked-out columns")
    return W


# ----------------------------------------------------------------- byte oracle
def _support_edges(comm, r: int):
    """Directed (receiver, source) exchanges of round ``r``'s *structure*
    graph — off-diagonal support of the unmasked mixing matrix."""
    Wt = np.asarray(comm.topology_at(r).W)
    K = Wt.shape[0]
    return [(k, j) for k in range(K) for j in range(K)
            if k != j and Wt[k, j] != 0.0]


def _leaf_bytes(params) -> int:
    return sum(int(np.prod(l.shape, dtype=np.int64)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(params))


def _codec_bytes(codec, params) -> int:
    return sum(codec.wire_bytes(int(np.prod(l.shape, dtype=np.int64)))
               for l in jax.tree_util.tree_leaves(params))


def oracle_fleet_bytes(opt, params, r: int) -> float:
    """Fleet-total wire bytes the round-``r`` exchange actually ships,
    enumerated from the structure graph + active mask (and, for CPD, a
    commit set re-derived from the matrix support).  Compare against
    ``n_workers × opt.bytes_per_comm_round(params, r)`` — the accounted
    side, which goes through ``edges_per_worker`` / ``_commit_np``
    instead.  ``params`` is one worker's (unstacked) tree."""
    from repro.core.cpdsgdm import CPDSGDM
    from repro.core.tracking import MTDSGDm

    comm = opt.comm
    act = np.asarray(comm.active_at(r), dtype=bool)
    edges = _support_edges(comm, r)
    live_edges = sum(1 for (k, j) in edges if act[k] and act[j])

    if isinstance(opt, CPDSGDM):
        # commit set, independently: source j ships iff j is active and
        # every receiver of j (its copy-holders) is active too
        K = act.shape[0]
        receivers: Dict[int, List[int]] = {j: [] for j in range(K)}
        for (k, j) in edges:
            receivers[j].append(k)
        commit = np.array([act[j] and all(act[k] for k in receivers[j])
                           for j in range(K)])
        shipped_edges = sum(len(receivers[j])
                            for j in range(K) if commit[j])
        if opt.config.packed_wire and opt.codec is not None:
            per_edge = _codec_bytes(opt.codec, params)
        else:
            per_edge = 4 * sum(int(np.prod(l.shape, dtype=np.int64))
                               for l in jax.tree_util.tree_leaves(params))
        return float(shipped_edges * per_edge)

    x_edge = _leaf_bytes(params)
    if isinstance(opt, MTDSGDm):
        if opt.codec is not None:
            c_edge = _codec_bytes(opt.codec, params)
        else:
            c_edge = 4 * sum(int(np.prod(l.shape, dtype=np.int64))
                             for l in jax.tree_util.tree_leaves(params))
        return float(live_edges * (x_edge + c_edge))
    return float(live_edges * x_edge)          # PD / QG: x only


# --------------------------------------------------------------------- driver
@dataclasses.dataclass
class ChaosRun:
    """Per-round survivor metrics from a chaos drive.

    ``consensus[r]`` — RMS distance of live workers' params to their
    live-worker mean after round ``r``; ``avg_loss[r]`` — loss of the
    live-averaged model; ``live[r]`` — live count;
    ``accounted_bytes[r]`` — fleet bytes the optimizer *charged* for the
    round (oracle comparisons happen in the tests)."""
    params: Any
    state: Any
    consensus: np.ndarray
    avg_loss: np.ndarray
    live: np.ndarray
    accounted_bytes: np.ndarray


def _consensus_rms(params, live_mask) -> float:
    idx = np.flatnonzero(live_mask)
    total, count = 0.0, 0
    for leaf in jax.tree_util.tree_leaves(params):
        sub = np.asarray(leaf)[idx]
        mean = sub.mean(axis=0, keepdims=True)
        total += float(((sub - mean) ** 2).sum())
        count += sub.size
    return float(np.sqrt(total / max(count, 1)))


def run_dense_chaos(opt, events: Sequence[ChaosEvent], params,
                    grads_fn: Callable, n_rounds: int, *,
                    loss_fn: Optional[Callable] = None,
                    warm_start: bool = True) -> ChaosRun:
    """Drive ``n_rounds`` fused rounds of ``opt`` (a DenseComm optimizer
    whose backend carries the script's membership) under churn.

    At each revival round the rejoining worker's params *and full
    optimizer state* are cloned from the nearest live donor on the ring
    order (``warm_start_worker``) before the round runs.  ``grads_fn``
    is the fused-round loss/grad callback (``(params, batch) -> (loss,
    grads)``); ``loss_fn`` (optional) maps stacked params to per-worker
    losses for the averaged-model metric — defaults to the loss part of
    ``grads_fn``."""
    ms = opt.comm.membership
    if ms is None:
        raise ValueError("run_dense_chaos: opt.comm carries no membership")
    revive_at = revivals_by_round(events)
    p = opt.config.p
    batches = jnp.zeros((p, 1))
    roundj = jax.jit(lambda s, pp: opt.round(s, pp, grads_fn, batches))
    per_worker = tmap(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                      params)
    if loss_fn is None:
        loss_fn = lambda pp: grads_fn(pp, None)[0]

    state = opt.init(params)
    consensus, avg_loss, live_n, acc_bytes = [], [], [], []
    for r in range(n_rounds):
        if warm_start:
            for w in revive_at.get(r, []):
                live_now = ms.live_at(r).copy()
                live_now[w] = False            # donor must be someone else
                donor = pick_donor(live_now, w)
                params, state = warm_start_worker(params, state,
                                                  joiner=w, donor=donor)
        params, state, _ = roundj(state, params)
        live = np.asarray(ms.live_at(r), dtype=bool)
        consensus.append(_consensus_rms(params, live))
        idx = np.flatnonzero(live)
        mean_p = tmap(
            lambda x: jnp.broadcast_to(
                jnp.asarray(np.asarray(x)[idx]).mean(0, keepdims=True),
                x.shape),
            params)
        avg_loss.append(float(np.asarray(loss_fn(mean_p)).mean()))
        live_n.append(int(live.sum()))
        acc_bytes.append(
            float(ms.n_workers * opt.bytes_per_comm_round(per_worker, r=r)))
    return ChaosRun(params=params, state=state,
                    consensus=np.asarray(consensus),
                    avg_loss=np.asarray(avg_loss),
                    live=np.asarray(live_n, dtype=np.int64),
                    accounted_bytes=np.asarray(acc_bytes))
