"""Compiled-HLO round-contract checks (AOT lowering, nothing executed).

Three invariants on the sharded ``TrainPack.train_round`` executable:

* **donation honored** — every ``donate_argnums`` entry must appear in the
  module's ``input_output_alias`` map (an unhonored donation silently
  doubles the parameter+state memory footprint);
* **collective allowlist** — the only substantive collectives are the
  gossip's ``collective-permute`` set; a stray all-gather / all-reduce is
  exactly the silent regression that erases the periodic-communication
  advantage (tiny scalar all-reduces — the loss mean — are exempt);
* **accounted ≡ shipped** — per-round ``collective-permute`` wire bytes
  parsed from HLO must equal ``opt.bytes_per_comm_round`` for the codec,
  a compile-time re-proof of the wire-codec byte accounting.

All checks take HLO text (``lowered.compile().as_text()``) so they run in
interpret mode on CPU with forced host devices — no accelerator needed.
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import jax

from repro.analysis.hlo_parse import (CollectiveStats, donated_aliases,
                                      parse_collectives)

__all__ = ["compile_round_text", "check_donation",
           "check_collectives_allowed", "check_wire_bytes",
           "check_sharded_round"]

# an all-reduce at or below this payload is bookkeeping (the scalar loss
# mean over workers), not gossip traffic
SCALAR_ALLREDUCE_BYTES = 256


def compile_round_text(pack) -> str:
    """AOT-compile the canonical hot path and return the optimized HLO."""
    lowered = pack.train_round.lower(pack.params_struct, pack.state_struct,
                                     pack.round_batch_struct)
    return lowered.compile().as_text()


def check_donation(hlo_text: str, n_donated: int) -> List[str]:
    """``donate_argnums`` must materialize as input/output aliases.

    ``n_donated`` is the number of donated *buffers* (flattened leaves of
    the donated argnums).  XLA may legitimately skip aliasing a buffer
    whose shape/dtype cannot match any output, so the check requires the
    alias map to cover at least 90% of the donated set — an empty or
    near-empty map means the donation was dropped altogether.
    """
    aliases = donated_aliases(hlo_text)
    if n_donated == 0:
        return []
    if len(aliases) == 0:
        return ["donation dropped: input_output_alias is empty but "
                f"{n_donated} buffer(s) were donated"]
    if len(aliases) < 0.9 * n_donated:
        return [f"donation partially honored: {len(aliases)} aliased "
                f"buffer(s) out of {n_donated} donated"]
    return []


def check_collectives_allowed(
        stats: CollectiveStats,
        allowed: Iterable[str] = ("collective-permute",),
        scalar_allreduce_ok: bool = True) -> List[str]:
    """No collectives beyond the expected gossip set.

    ``allowed`` ops pass unconditionally; an ``all-reduce`` whose payload
    is ≤ ``SCALAR_ALLREDUCE_BYTES`` passes when ``scalar_allreduce_ok``
    (the per-round loss mean).  Everything else is a contract violation.
    """
    allowed = set(allowed)
    out = []
    for call in stats.calls:
        if call.op in allowed:
            continue
        if (scalar_allreduce_ok and call.op == "all-reduce"
                and call.result_bytes <= SCALAR_ALLREDUCE_BYTES):
            continue
        out.append(f"unexpected collective in the round: {call.op} "
                   f"({call.result_bytes} B payload) — {call.line[:120]}")
    return out


def check_wire_bytes(stats: CollectiveStats, expected: int,
                     label: str = "") -> List[str]:
    """collective-permute bytes per device ≡ ``bytes_per_comm_round``.

    Only valid on a mesh where one device is one worker (TP=1): with model
    parallelism each worker's wire bytes are split across its TP shards
    and the per-device total no longer equals the per-worker accounting.
    """
    got = int(stats.wire_bytes.get("collective-permute", 0))
    if got != int(expected):
        who = f" [{label}]" if label else ""
        return [f"wire bytes{who}: HLO ships {got} B/device/round but "
                f"bytes_per_comm_round accounts {int(expected)} B"]
    return []


def _count_donated_leaves(pack) -> int:
    return sum(len(jax.tree_util.tree_leaves(t))
               for t in (pack.params_struct, pack.state_struct))


def check_sharded_round(pack, *, check_bytes: bool = True,
                        expected_wire_bytes: Optional[int] = None,
                        label: str = "") -> List[str]:
    """All HLO checks on one built ``TrainPack`` (donation + allowlist +
    accounted≡shipped).  ``check_bytes=False`` skips the byte equality —
    required on meshes with model parallelism (see :func:`check_wire_bytes`).
    """
    txt = compile_round_text(pack)
    stats = parse_collectives(txt)
    out = []
    out += check_donation(txt, _count_donated_leaves(pack))
    out += check_collectives_allowed(stats)
    if check_bytes:
        if expected_wire_bytes is None:
            # params_struct is worker-stacked; the wire ships one worker's
            # leaves per device, so the accounting runs on the unstacked tree
            per_worker = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                pack.params_struct)
            expected_wire_bytes = pack.opt.bytes_per_comm_round(per_worker)
        out += check_wire_bytes(stats, expected_wire_bytes, label=label)
    return out
