"""Shared neural-net layers (pure-functional, pytree params).

Conventions:
  * ``init_*`` returns a params dict; ``apply`` style functions are pure.
  * All matmuls accumulate in float32 (``preferred_element_type``) and cast
    back to the compute dtype.
  * Logical sharding hints are attached by the runtime, not here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense", "rmsnorm_init", "rmsnorm", "layernorm_init",
    "layernorm", "nonparametric_layernorm", "embedding_init", "embed",
    "rope_freqs", "apply_rope", "mlp_init", "mlp", "truncated_normal_init",
]


def truncated_normal_init(key, shape, dtype, scale: float):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------- dense
def dense_init(key, in_dim: int, out_dim: int, dtype, use_bias: bool = False):
    p = {"w": truncated_normal_init(key, (in_dim, out_dim), dtype,
                                    scale=in_dim ** -0.5)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p, x):
    y = jnp.einsum("...d,df->...f", x, p["w"],
                   preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------- norms
def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype, use_bias: bool = True):
    p = {"scale": jnp.ones((dim,), dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mean) ** 2, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def nonparametric_layernorm(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale/bias; arXiv:2402.00838)."""
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mean) ** 2, axis=-1, keepdims=True)
    return ((x32 - mean) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------- embed
def embedding_init(key, vocab: int, dim: int, dtype):
    return {"table": truncated_normal_init(key, (vocab, dim), dtype, scale=1.0)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


# ---------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, max_len: int, theta: float = 10000.0):
    """(max_len, head_dim/2) complex-free cos/sin tables, float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    f = jnp.outer(t, inv)
    return jnp.cos(f), jnp.sin(f)


def apply_rope(x, cos, sin, positions):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    c = cos[positions][..., None, :]  # (..., seq, 1, hd/2)
    s = sin[positions][..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------- mlp
def mlp_init(key, dim: int, hidden: int, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, dim, hidden, dtype),
        "wo": dense_init(k2, hidden, dim, dtype),
    }
    if gated:
        p["wg"] = dense_init(k3, dim, hidden, dtype)
    return p


def mlp(p, x):
    h = dense(p["wi"], x)
    if "wg" in p:
        h = jax.nn.silu(dense(p["wg"], x).astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], h)
