"""Retrace guard: one compilation across a schedule sweep + mid-cycle
resume, and the counter catches a retracing round driver."""
import jax
import jax.numpy as jnp

from repro.analysis.retrace import CompileCounter, check_schedule_no_retrace


def test_schedule_sweep_compiles_once():
    assert check_schedule_no_retrace() == []


def test_counter_counts_distinct_compiles():
    def fn_add_one(x):
        return x + 1

    def fn_times_two(x):
        return x * 2

    x = jnp.zeros((4,), jnp.float32)   # pre-built: its own compile
    with CompileCounter() as cc:
        jax.jit(fn_add_one)(x)
        jax.jit(fn_times_two)(x)
        jax.jit(fn_add_one)(x)         # cache hit: no new compile
    assert cc.count("fn_add_one") == 1
    assert cc.count("fn_times_two") == 1


def test_catches_retracing_round_driver():
    """The anti-pattern the guard exists for: baking the python-int round
    index into the trace compiles once per round."""
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import make_schedule
    from repro.analysis.jaxpr_check import toy_grads_fn, toy_params

    K, p = 8, 2
    sched = make_schedule("one_peer_exp", (K,))
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=p), DenseComm(sched))
    params = toy_params(K)
    state = opt.init(params)
    batches = jnp.zeros((p, K, 4), jnp.float32)

    def make_round():
        def bad_round(params, state, batches):
            # static round index → a fresh jit cache entry every round
            r = int(state["step"]) // p

            @jax.jit
            def stepped(params, state, batches):
                st = dict(state)
                st["step"] = jnp.asarray(r * p, jnp.int32)
                return opt.round(st, params, toy_grads_fn, batches)

            return stepped(params, state, batches)

        return bad_round, params, state, batches, sched.period

    out = check_schedule_no_retrace(make_round)
    assert out and "expected exactly 1" in out[0]
