"""Overlapped-round contract: one-round-stale delayed mixing.

Under ``overlap=True`` the gossip payload of round r is a snapshot of the
end-of-round-r params, exchanged at the *start* of round r+1's local scan
and mixed in at its end — the transfer has no data dependence on the
round's local steps, so it can hide behind compute.  Every test here pins
the executed semantics against an explicit two-phase numpy oracle built
from ``comm.effective_stale_matrix`` (payload round's topology, delivery
round's liveness):

    round 0:  local scan; snapshot buf;            (gate 0 — no-op mix)
    round r:  dx = gate · (W̃_stale · buf − buf)   issued at round start
              p local steps (MT drips dc/p after each)
              x ← x + dx; snapshot buf             at round end

Covered per optimizer family: fused round ≡ oracle, kernel path ≡ tree
path, fused ≈ per-step dispatch (tolerance — XLA fuses the cond'd apply
differently, same convention as test_kernels), membership composition
(a payload from a worker that died in flight is dropped with
renormalization), and the unsupported-combo construction errors.  The
slow tier runs the sharded backend end-to-end on 8 forced host devices.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compressor
from repro.core.baselines import make_optimizer
from repro.core.gossip import DenseComm, ShardedComm
from repro.core.topology import ring

K, P, ETA, MU = 4, 4, 0.05, 0.9


def _params():
    key = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(key, (K, 5)), "b": jnp.ones((K, 2))}


def _grads_fn(params, batch):
    g = jax.tree_util.tree_map(lambda x: 0.1 * x + batch, params)
    return sum(jnp.sum(v) for v in jax.tree_util.tree_leaves(g)), g


def _np_params(params):
    return {k: np.asarray(v, np.float32) for k, v in params.items()}


def _mixW(W, tree):
    return {k: (W @ v.reshape(K, -1)).reshape(v.shape)
            for k, v in tree.items()}


def _batches(p=P):
    return jnp.arange(p, dtype=jnp.float32) * 0.01


def _run_rounds(opt, params, rounds, p=P):
    state = opt.init(params)
    for _ in range(rounds):
        params, state, _ = opt.round(state, params, _grads_fn, _batches(p))
    return params, state


# ------------------------------------------------------------------ oracles
def _pd_oracle(W_at, params, rounds, p=P, gamma=1.0):
    """Two-phase delayed-mixing reference for the PD local dynamics
    (plain momentum; CPD with the identity codec is the same walk with a
    γ-scaled correction and buf ≡ x̂ ≡ x)."""
    x = _np_params(params)
    m = {k: np.zeros_like(v) for k, v in x.items()}
    b = np.asarray(_batches(p))
    buf, have = None, False
    for rnd in range(rounds):
        if have:
            mx = _mixW(W_at(rnd - 1), buf)
            dx = {k: gamma * (mx[k] - buf[k]) for k in x}
        for i in range(p):
            for k in x:
                g = 0.1 * x[k] + float(b[i])
                m[k] = MU * m[k] + g
                x[k] = x[k] - ETA * m[k]
        if have:
            for k in x:
                x[k] = x[k] + dx[k]
        buf, have = {k: v.copy() for k, v in x.items()}, True
    return x, m


def test_pd_overlap_matches_delayed_mixing_oracle():
    comm = DenseComm(ring(K))
    opt = make_optimizer("pd_sgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    pr, sr = _run_rounds(opt, _params(), 3)
    W = np.asarray(comm.effective_stale_matrix(0), np.float32)
    x, _ = _pd_oracle(lambda r: W, _params(), 3)
    for k in x:
        np.testing.assert_allclose(np.asarray(pr[k]), x[k], atol=2e-5)
    # round-end snapshot is the next in-flight payload, phase armed
    assert int(sr["mix"]["phase"]) == 1
    for k in x:
        np.testing.assert_allclose(np.asarray(sr["mix"]["buf"][k]), x[k],
                                   atol=2e-5)


def test_pd_overlap_kernel_matches_tree():
    comm = DenseComm(ring(K))
    opt = make_optimizer("pd_sgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    optk = make_optimizer("pd_sgdm", comm, eta=ETA, mu=MU, p=P, overlap=True,
                          use_kernel=True, kernel_interpret=True)
    pr, sr = _run_rounds(opt, _params(), 3)
    pk, sk = _run_rounds(optk, _params(), 3)
    for k in pr:
        np.testing.assert_allclose(np.asarray(pk[k]), np.asarray(pr[k]),
                                   atol=2e-5)
    assert int(sk["mix"]["phase"]) == 1
    np.testing.assert_allclose(np.asarray(sk["mix"]["buf"]["w"]),
                               np.asarray(sr["mix"]["buf"]["w"]), atol=2e-5)


@pytest.mark.parametrize("name", ["pd_sgdm", "mt_dsgdm"])
def test_overlap_fused_matches_per_step(name):
    """The per-step dispatch path (``opt.step`` with the exchange embedded
    at comm steps) walks the same trajectory as the fused round — up to
    XLA's cond-fusion ulp, the repo's round-equivalence convention."""
    comm = DenseComm(ring(K))
    opt = make_optimizer(name, comm, eta=ETA, mu=MU, p=P, overlap=True)
    params = _params()
    pr, sr = params, opt.init(params)
    ps, ss = params, opt.init(params)
    for _ in range(2):
        pr, sr, _ = opt.round(sr, pr, _grads_fn, _batches())
        for i in range(P):
            _, g = _grads_fn(ps, _batches()[i])
            ps, ss = opt.step(ss, ps, g)
    for a, b in zip(jax.tree_util.tree_leaves(pr),
                    jax.tree_util.tree_leaves(ps)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    assert int(ss["mix"]["phase"]) == 1


def test_pd_overlap_membership_stale_mask():
    """Membership composition: the stale matrix is the payload round's
    topology masked by the *delivery* round's liveness — a payload from a
    worker that died in flight is dropped, with the row renormalized."""
    from repro.testing import chaos_script, membership_for
    ms = membership_for(K, 6, chaos_script(K, 6, seed=7))
    comm = DenseComm(ring(K), membership=ms)
    opt = make_optimizer("pd_sgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    pr, _ = _run_rounds(opt, _params(), 4)
    x, _ = _pd_oracle(
        lambda r: np.asarray(comm.effective_stale_matrix(r), np.float32),
        _params(), 4)
    for k in x:
        np.testing.assert_allclose(np.asarray(pr[k]), x[k], atol=2e-5)


def test_mt_overlap_matches_drip_oracle():
    """MT under overlap refreshes the tracking correction mid-round: the
    stale dc lands in p equal drips after each local step (the aging fix
    that restores stability at p ≥ 4), and dx lands at round end."""
    comm = DenseComm(ring(K))
    W = np.asarray(comm.effective_stale_matrix(0), np.float32)
    opt = make_optimizer("mt_dsgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    pr, sr = _run_rounds(opt, _params(), 4)

    x = _np_params(_params())
    m = {k: np.zeros_like(v) for k, v in x.items()}
    c = {k: np.zeros_like(v) for k, v in x.items()}
    gp = {k: np.zeros_like(v) for k, v in x.items()}
    b = np.asarray(_batches())
    buf, buf_c, have = None, None, False
    for rnd in range(4):
        if have:
            mx, mc = _mixW(W, buf), _mixW(W, buf_c)
            dx = {k: mx[k] - buf[k] for k in x}
            dc = {k: mc[k] - buf_c[k] for k in x}
        for i in range(P):
            for k in x:
                g = 0.1 * x[k] + float(b[i])
                c[k] = c[k] + g - gp[k]
                m[k] = MU * m[k] + c[k]
                x[k] = x[k] - ETA * m[k]
                gp[k] = g
            if have:
                for k in x:
                    c[k] = c[k] + dc[k] / P
        if have:
            for k in x:
                x[k] = x[k] + dx[k]
        buf = {k: v.copy() for k, v in x.items()}
        buf_c = {k: v.copy() for k, v in c.items()}
        have = True
    for k in x:
        np.testing.assert_allclose(np.asarray(pr[k]), x[k], atol=3e-5)
    np.testing.assert_allclose(np.asarray(sr["c"]["w"]), c["w"], atol=3e-5)
    # under doubly-stochastic W̃ the drip is mean-preserving: the tracking
    # invariant mean_k(c) = mean_k(ĝ) survives the mid-round refresh
    np.testing.assert_allclose(np.asarray(sr["c"]["w"]).mean(axis=0),
                               gp["w"].mean(axis=0), atol=3e-5)


def test_mt_overlap_kernel_matches_tree():
    comm = DenseComm(ring(K))
    opt = make_optimizer("mt_dsgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    optk = make_optimizer("mt_dsgdm", comm, eta=ETA, mu=MU, p=P,
                          overlap=True, use_kernel=True,
                          kernel_interpret=True)
    pr, sr = _run_rounds(opt, _params(), 4)
    pk, sk = _run_rounds(optk, _params(), 4)
    for k in pr:
        np.testing.assert_allclose(np.asarray(pk[k]), np.asarray(pr[k]),
                                   atol=3e-5)
    np.testing.assert_allclose(np.asarray(sk["mix"]["buf_c"]["w"]),
                               np.asarray(sr["mix"]["buf_c"]["w"]),
                               atol=3e-5)


def test_qg_overlap_matches_oracle():
    """QG: the stale correction lands on the drifted params, then the
    quasi-global momentum folds the realized round displacement
    (xprev − x_new)/(ηp) exactly as in the synchronous form."""
    comm = DenseComm(ring(K))
    W = np.asarray(comm.effective_stale_matrix(0), np.float32)
    opt = make_optimizer("qg_dsgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    pr, sr = _run_rounds(opt, _params(), 4)

    x = _np_params(_params())
    m = {k: np.zeros_like(v) for k, v in x.items()}
    xprev = {k: v.copy() for k, v in x.items()}
    b = np.asarray(_batches())
    buf, have = None, False
    for rnd in range(4):
        if have:
            mx = _mixW(W, buf)
            dx = {k: mx[k] - buf[k] for k in x}
        for i in range(P):
            for k in x:
                g = 0.1 * x[k] + float(b[i])
                x[k] = x[k] - ETA * (g + MU * m[k])
        if have:
            for k in x:
                x[k] = x[k] + dx[k]
        for k in x:
            m[k] = MU * m[k] + (1 - MU) * (xprev[k] - x[k]) / (ETA * P)
            xprev[k] = x[k].copy()
        buf, have = {k: v.copy() for k, v in x.items()}, True
    for k in x:
        np.testing.assert_allclose(np.asarray(pr[k]), x[k], atol=3e-5)
    np.testing.assert_allclose(np.asarray(sr["m"]["w"]), m["w"], atol=3e-5)

    optk = make_optimizer("qg_dsgdm", comm, eta=ETA, mu=MU, p=P,
                          overlap=True, use_kernel=True,
                          kernel_interpret=True)
    pk, _ = _run_rounds(optk, _params(), 4)
    for k in x:
        np.testing.assert_allclose(np.asarray(pk[k]), np.asarray(pr[k]),
                                   atol=3e-5)


def test_cpd_overlap_matches_identity_q_oracle():
    """CPD with the identity codec: x̂ tracks x exactly, so the overlap
    round is the PD walk with a γ-scaled stale correction and the payload
    snapshot cut from x̂ (Alg. 2's consensus estimate)."""
    comm = DenseComm(ring(K))
    W = np.asarray(comm.effective_stale_matrix(0), np.float32)
    opt = make_optimizer("cpd_sgdm", comm, eta=ETA, mu=MU, p=P, gamma=0.4,
                         compressor=make_compressor("identity"),
                         overlap=True)
    pr, sr = _run_rounds(opt, _params(), 4)
    x, _ = _pd_oracle(lambda r: W, _params(), 4, gamma=0.4)
    for k in x:
        np.testing.assert_allclose(np.asarray(pr[k]), x[k], atol=3e-5)
    np.testing.assert_allclose(np.asarray(sr["xhat"]["w"]), x["w"],
                               atol=3e-5)


def test_overlap_round0_is_gated_noop():
    """Round 0 has nothing in flight: gate 0 makes the mix an exact no-op
    while the exchange still runs (uniform trace, uniform wire bytes) —
    the first round must equal a pure local scan."""
    comm = DenseComm(ring(K))
    opt = make_optimizer("pd_sgdm", comm, eta=ETA, mu=MU, p=P, overlap=True)
    opt_sync = make_optimizer("pd_sgdm", comm, eta=ETA, mu=MU, p=P)
    params = _params()
    pr, sr, _ = opt.round(opt.init(params), params, _grads_fn, _batches())
    ps, ss, _ = opt_sync.round(opt_sync.init(params), params, _grads_fn,
                               _batches(), gossip=False)
    for a, b in zip(jax.tree_util.tree_leaves(pr),
                    jax.tree_util.tree_leaves(ps)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(sr["mix"]["phase"]) == 1      # armed for round 1


def test_overlap_unsupported_combos_raise():
    comm = DenseComm(ring(K))
    sharded = ShardedComm(ring(K), axis_names=("w",))
    bad = [
        # CPD's x̂_nbrs replica contract breaks under a stale consensus
        lambda: make_optimizer("cpd_sgdm", sharded, overlap=True),
        # CPD kernel path has no matrix-domain delayed wire
        lambda: make_optimizer("cpd_sgdm", comm, overlap=True,
                               use_kernel=True),
        # compressed tracking would need a second codec wire per round
        lambda: make_optimizer("mt_dsgdm", comm, overlap=True,
                               compressor=make_compressor("sign")),
        # every-step baselines have no local scan to overlap
        lambda: make_optimizer("c_sgdm", comm, overlap=True),
        lambda: make_optimizer("d_sgd", comm, overlap=True),
        lambda: make_optimizer("choco_sgd", comm, overlap=True),
    ]
    for ctor in bad:
        with pytest.raises(ValueError):
            ctor()


# ------------------------------------------------------------- sharded (slow)
_SCRIPT_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.train.trainer import ShardedTrainer

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)

    def run_one(name, use_kernel):
        run = RunCfg(model=mcfg,
                     parallel=ParallelCfg(profile="A", remat="none"),
                     optim=OptimCfg(name=name, eta=0.05, mu=0.9, p=2,
                                    weight_decay=1e-4, overlap=True,
                                    use_kernel=use_kernel,
                                    kernel_interpret=True))
        mesh = make_debug_mesh(8, 1)
        pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
        assert "mix" in pack.state_struct, name
        K = pack.layout.n_workers

        def batch_fn(t):
            return train_batch_arrays(
                mcfg, K, 1, 16,
                jax.random.fold_in(jax.random.PRNGKey(1), t))

        with mesh:
            out = ShardedTrainer(pack).train(jax.random.PRNGKey(0),
                                             batch_fn, 6, log_every=2,
                                             verbose=False)
        assert int(np.asarray(out["state"]["step"])) == 6
        assert int(np.asarray(out["state"]["mix"]["phase"])) == 1
        return out

    # tree vs kernel on the sharded backend walk the same trajectory
    for name in ("pd_sgdm", "mt_dsgdm"):
        a = run_one(name, False)
        b = run_one(name, True)
        for x, y in zip(jax.tree_util.tree_leaves(a["params"]),
                        jax.tree_util.tree_leaves(b["params"])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-5, atol=2e-5)
        print(name, "SHARDED_TREE_EQ_KERNEL")
    run_one("qg_dsgdm", False)
    print("SHARDED_OVERLAP_OK")
""")


@pytest.mark.slow
def test_sharded_overlap_tree_matches_kernel():
    """Overlap end-to-end on the sharded backend (8 forced host devices):
    PD and MT run the same trajectory on the tree and kernel paths, QG
    trains through the round engine; in-flight phase is armed after the
    first boundary."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT_SHARDED], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARDED_OVERLAP_OK" in r.stdout
