"""δ-contraction compression operators (paper Definition 1).

An operator ``Q`` is a δ-contraction if ``‖x − Q(x)‖² ≤ (1 − δ)‖x‖²`` for some
δ ∈ (0, 1].  CPD-SGDM (Alg. 2) sends ``q = Q(x_{t+1} − x̂_t)`` over the wire.

Every operator is paired with a :class:`~repro.core.wire.WireCodec` — the
concrete pack/unpack of its on-the-wire payload — and ``apply`` is defined
as the codec round-trip ``unpack ∘ pack``, so the simulated semantics, the
kernel semantics, and the bytes-on-wire accounting agree *by construction*
for all five operators (not just sign).  Operators are *blockwise* (blocks
of :data:`SIGN_BLOCK` elements by default) so the flatten-once kernel
layout's rows coincide with the per-leaf blocks.

All operators are deterministic given the PRNG key; stochastic ones (rand-k)
thread the key explicitly so every worker can reproduce its neighbour's
decompression without extra communication.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Compressor",
    "IdentityCompressor",
    "SignCompressor",
    "TopKCompressor",
    "RandKCompressor",
    "QSGDCompressor",
    "SparseRowsCompressor",
    "make_compressor",
    "sign_pack",
    "sign_unpack",
    "sign_wire_bytes",
    "contraction_ratio",
    "SIGN_BLOCK",
]

# elements per scale block ≡ the kernel lane width (so the flatten-once
# rows coincide with the per-leaf blocks); repro.kernels is import-light
from repro.kernels import LANE as SIGN_BLOCK  # noqa: E402


def _pad_to(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def sign_pack(x: jnp.ndarray, block: int = SIGN_BLOCK):
    """Blockwise scaled-sign compress + bit-pack.

    Returns ``(packed, scales)`` where ``packed`` is uint8 of shape
    (nblocks, block//8) holding sign bits (1 = non-negative) and ``scales``
    is float32 (nblocks,) = mean |x| over each block.  Padding contributes
    zeros (sign bit arbitrary; scale ignores pad via true-length masking).
    The true length ``n`` is static (``x.size``) so it is not returned —
    pass it to :func:`sign_unpack` (keeps this function vmap-able).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    flat, _ = _pad_to(flat, block)
    nb = flat.shape[0] // block
    blocks = flat.reshape(nb, block)
    # mask out padding in the scale so Q(x) matches the unpadded semantics
    idx = jnp.arange(nb * block).reshape(nb, block)
    valid = (idx < n).astype(jnp.float32)
    counts = jnp.maximum(valid.sum(axis=1), 1.0)
    scales = (jnp.abs(blocks) * valid).sum(axis=1) / counts
    bits = (blocks >= 0).astype(jnp.uint8).reshape(nb, block // 8, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8)).astype(jnp.uint8)
    packed = (bits * weights).sum(axis=-1).astype(jnp.uint8)
    return packed, scales.astype(jnp.float32)


def sign_unpack(packed: jnp.ndarray, scales: jnp.ndarray, n: int, shape, dtype,
                block: int = SIGN_BLOCK) -> jnp.ndarray:
    """Inverse of :func:`sign_pack`: Q(x) = scaleᵦ · sign(xᵦ)."""
    nb = packed.shape[0]
    bytes_ = packed.reshape(nb, block // 8, 1)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bytes_ >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.float32) * 2.0 - 1.0  # {0,1} -> {-1,+1}
    vals = signs.reshape(nb, block) * scales[:, None]
    flat = vals.reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def sign_wire_bytes(n: int, block: int = SIGN_BLOCK) -> int:
    """Exact packed-wire payload for an ``n``-element leaf: per block,
    ``block/8`` sign bytes + one f32 scale — *including* the padded tail
    block, which really crosses the wire (``(uint8, f32)`` pair per
    ``ppermute``).  This is the cost model behind
    ``CPDSGDM.bytes_per_comm_round`` on the packed path."""
    nblocks = -(-int(n) // block)
    return nblocks * (block // 8 + 4)


def contraction_ratio(x: jnp.ndarray, qx: jnp.ndarray) -> jnp.ndarray:
    """‖x − Q(x)‖² / ‖x‖² — must be ≤ 1 − δ (Definition 1)."""
    num = jnp.sum((x.astype(jnp.float32) - qx.astype(jnp.float32)) ** 2)
    den = jnp.maximum(jnp.sum(x.astype(jnp.float32) ** 2), 1e-30)
    return num / den


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base δ-contraction operator.

    ``apply(x, key)`` returns Q(x) with the same shape/dtype as x — it is
    defined as ``unpack ∘ pack`` of the paired wire codec
    (``repro.core.wire.make_codec``), so the simulated math and the bytes
    on the wire can never disagree.  ``wire_bits_per_element`` is the
    per-element *rate model* (Fig. 2 curves); ``wire_bytes`` is the exact
    payload size, taken from the codec's array shapes.
    """

    name: str = "identity"

    def _codec(self):
        from repro.core.wire import make_codec   # lazy: wire imports us
        return make_codec(self)

    def apply(self, x: jnp.ndarray, key: jax.Array | None = None) -> jnp.ndarray:
        codec = self._codec()
        return codec.unpack(codec.pack(x, key), x.size, x.shape, x.dtype,
                            key=key)

    def wire_bits_per_element(self, dtype=jnp.float32) -> float:
        raise NotImplementedError

    def delta_lower_bound(self, d: int) -> float:
        """A guaranteed δ for dimension d (may be loose)."""
        raise NotImplementedError

    def wire_bytes(self, x: jnp.ndarray) -> int:
        """Exact shipped bytes for one leaf: the summed ``nbytes`` of the
        codec's wire payload (falls back to the per-element rate model for
        compressors without a codec)."""
        from repro.core.wire import make_codec
        try:
            codec = make_codec(self)
        except TypeError:
            return int(np.ceil(
                x.size * self.wire_bits_per_element(x.dtype) / 8.0))
        return codec.wire_bytes(x.size)


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(Compressor):
    name: str = "identity"

    def apply(self, x, key=None):
        return x

    def wire_bits_per_element(self, dtype=jnp.float32):
        return float(jnp.dtype(dtype).itemsize * 8)

    def wire_bytes(self, x: jnp.ndarray) -> int:
        # shipping *this tensor* uncompressed is dtype-faithful; note the
        # codec (CPD's wire) ships the f32 drift instead (4 bytes/elem)
        return int(x.size * jnp.dtype(x.dtype).itemsize)

    def delta_lower_bound(self, d):
        return 1.0


@dataclasses.dataclass(frozen=True)
class SignCompressor(Compressor):
    """Blockwise scaled sign (paper's experimental choice, ref [5] signSGD).

    Q(x)ᵦ = mean(|xᵦ|) · sign(xᵦ) per block of ``block`` elements.
    δ = ‖x‖₁²/(d‖x‖₂²) ≥ 1/d per block; in practice ≈ 0.5–0.8 for dense grads.
    Wire cost: 1 bit/element + one f32 scale per block.
    """

    name: str = "sign"
    block: int = SIGN_BLOCK

    def apply(self, x, key=None):
        packed, scales = sign_pack(x, self.block)
        return sign_unpack(packed, scales, x.size, x.shape, x.dtype, self.block)

    def wire_bits_per_element(self, dtype=jnp.float32):
        return 1.0 + 32.0 / self.block

    def delta_lower_bound(self, d):
        return 1.0 / min(d, self.block)


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Keep the top ``fraction`` of entries by magnitude, *blockwise*.

    Leaves are processed in blocks of ``block`` elements (matching the sign
    operator and the kernel row layout, so the kernel wire blocks are
    identical to this per-leaf semantics); each block keeps its own
    ``ceil(fraction · d_b)`` largest entries.  For leaves with d ≤ block
    this coincides with global top-k.  δ ≥ fraction (per-block k/d ≥ f).
    Wire: (int32 idx, f32 val) per kept slot — see
    ``repro.core.wire.TopKCodec``.
    """

    name: str = "topk"
    fraction: float = 0.01
    block: int = SIGN_BLOCK

    def _k(self, d: int) -> int:
        return max(1, int(np.ceil(self.fraction * d)))

    def wire_bits_per_element(self, dtype=jnp.float32):
        # W slots of (idx, val) per block of `block` elements
        from repro.core.wire import topk_width
        return topk_width(self.fraction, self.block) * 64.0 / self.block

    def delta_lower_bound(self, d):
        if d <= self.block:
            return self._k(d) / d
        return self.fraction       # min over blocks of ceil(f·d_b)/d_b ≥ f


@dataclasses.dataclass(frozen=True)
class RandKCompressor(Compressor):
    """Keep a uniformly random fraction (unscaled).  E‖x−Q‖² = (1−k/d)‖x‖².

    The kept coordinates are derived from the PRNG key alone — the key is
    shared by sender and receiver (it folds the leaf index and the round,
    never the worker id), so only the k values ever cross the wire
    (``repro.core.wire.RandKCodec``)."""

    name: str = "randk"
    fraction: float = 0.01

    def wire_bits_per_element(self, dtype=jnp.float32):
        # indices reproducible from the shared key: only k f32 values ship
        return self.fraction * 32.0

    def delta_lower_bound(self, d):
        return max(1.0 / d, self.fraction)  # in expectation


@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    """QSGD-style s-level quantization, norm-scaled, *blockwise* (ref [3]).

    Deterministic rounding variant (nearest level) so it is a contraction
    (stochastic QSGD is unbiased but not a contraction without scaling).
    Each block of ``block`` elements carries its own max-|x| norm; the
    2·levels+1 symmetric levels bit-pack into ``qsgd_bits(levels)`` ∈
    {2, 4, 8} bits per element (``repro.core.wire.QSGDCodec``).  The
    default ``levels=7`` is the 4-bit wire.
    """

    name: str = "qsgd"
    levels: int = 7   # 15 symmetric levels -> 4-bit nibble packing
    block: int = SIGN_BLOCK

    def wire_bits_per_element(self, dtype=jnp.float32):
        from repro.core.wire import qsgd_bits
        return qsgd_bits(self.levels) + 32.0 / self.block

    def delta_lower_bound(self, d):
        # the per-block max element quantizes exactly -> δ ≥ 1/d; nearest
        # rounding also gives |x−q| ≤ norm/(2s) per element, so per block
        # ratio ≤ d_b/(4s²) — take whichever guarantee is stronger.
        d_eff = min(d, self.block)
        return max(1.0 / d, 1.0 - d_eff / (4.0 * self.levels ** 2))


@dataclasses.dataclass(frozen=True)
class SparseRowsCompressor(Compressor):
    """Ship only the ``max_rows`` largest rows (by L2 norm) of each leaf's
    blockwise layout — the push-by-key wire for embedding-dominated
    workloads where each round touches a few thousand rows of a huge table.

    Each leaf is viewed as ``nb = ceil(d / block)`` rows of ``block``
    elements (the flatten-once kernel rows); the wire carries
    ``R = min(max_rows, nb)`` (int32 row index, row payload) pairs.  The
    row payload is the ``inner`` codec applied to the gathered (R, block)
    row matrix: ``"f32"`` ships raw rows (lossless on the touched set),
    ``"sign"`` / ``"qsgd"`` compose the existing blockwise operators
    row-wise.  Untouched rows decode to exact 0, so when ≤ R rows are
    non-zero (the embedding regime) the f32 wire satisfies Q(x) = x.

    δ: the selected rows are the top-R by norm, so the kept energy is at
    least R/nb of ‖x‖² — composed with the inner operator's own δ.
    """

    name: str = "sparse_rows"
    max_rows: int = 64
    inner: str = "f32"     # "f32" | "sign" | "qsgd"
    levels: int = 7        # inner="qsgd" quantization levels
    block: int = SIGN_BLOCK

    def _inner_row_bytes(self) -> int:
        """Exact wire bytes per shipped row (excluding the row index)."""
        if self.inner == "f32":
            return 4 * self.block
        if self.inner == "sign":
            return self.block // 8 + 4          # bits + f32 scale
        if self.inner == "qsgd":
            from repro.core.wire import qsgd_bits
            return self.block * qsgd_bits(self.levels) // 8 + 4
        raise ValueError(f"unknown sparse inner codec {self.inner!r}")

    def wire_bits_per_element(self, dtype=jnp.float32):
        # per *touched* element rate (the honest denominator for this
        # codec: bytes scale with rows touched, not with leaf size)
        return 8.0 * (4 + self._inner_row_bytes()) / self.block

    def delta_lower_bound(self, d):
        nb = -(-int(d) // self.block)
        keep = min(self.max_rows, nb) / nb      # top-R rows keep ≥ R/nb energy
        if self.inner == "f32":
            return keep
        inner_delta = (SignCompressor(block=self.block) if self.inner == "sign"
                       else QSGDCompressor(levels=self.levels,
                                           block=self.block)
                       ).delta_lower_bound(min(d, self.block))
        return keep * inner_delta


def make_compressor(name: str, **kw) -> Compressor:
    name = name.lower()
    if name in ("identity", "none", "full"):
        return IdentityCompressor()
    if name == "sign":
        return SignCompressor(**kw)
    if name == "topk":
        return TopKCompressor(**kw)
    if name == "randk":
        return RandKCompressor(**kw)
    if name == "qsgd":
        return QSGDCompressor(**kw)
    if name in ("sparse", "sparse_rows"):
        return SparseRowsCompressor(**kw)
    if name.startswith("sparse+"):          # composed: sparse+sign, sparse+qsgd
        return SparseRowsCompressor(inner=name.split("+", 1)[1], **kw)
    raise ValueError(f"unknown compressor {name!r}")
