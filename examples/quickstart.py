"""Quickstart: decentralized momentum SGD (PD-SGDM) in ~40 lines.

8 workers on a ring train a tiny LM with local momentum steps and gossip
every p=4 iterations; the same run with sign-compressed gossip (CPD-SGDM)
shows the ~30× communication saving at matching loss; and a time-varying
one-peer exponential topology halves the bytes of the ring again (degree 1
per round) while its 3-round cycle mixes like a hypercube.

Execution goes through the fused round engine: each jitted call runs a
``lax.scan`` of whole rounds (p local steps + one gossip), syncing the
host once per log block instead of once per step.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core import (CPDSGDMConfig, CPDSGDM, PDSGDM, PDSGDMConfig,
                        SignCompressor)
from repro.core.gossip import DenseComm
from repro.core.topology import one_peer_exponential_schedule, ring
from repro.data.synthetic import LMStreamCfg, lm_batch
from repro.models import make_model
from repro.train.trainer import SimTrainer

K = 8       # workers on a ring (the paper's setup)
STEPS = 60

model = make_model(ModelCfg(
    name="tiny-lm", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256))

# every worker starts from the same x0 (Algorithm 1 input)
params0 = jax.vmap(lambda _: model.init(jax.random.PRNGKey(0)))(
    jnp.arange(K))
data = LMStreamCfg(vocab=256, seq_len=32, batch=4, n_workers=K)

for label, opt in [
    ("PD-SGDM  (Alg.1, full-precision gossip)",
     PDSGDM(PDSGDMConfig(eta=0.3, mu=0.9, p=4), DenseComm(ring(K)))),
    ("CPD-SGDM (Alg.2, 1-bit sign gossip)",
     CPDSGDM(CPDSGDMConfig(eta=0.3, mu=0.9, p=4, gamma=0.4),
             DenseComm(ring(K)), SignCompressor())),
    ("PD-SGDM  (one-peer exponential schedule, degree 1)",
     PDSGDM(PDSGDMConfig(eta=0.3, mu=0.9, p=4),
            DenseComm(one_peer_exponential_schedule(K)))),
]:
    trainer = SimTrainer(lambda p, b: model.loss(p, b), opt,
                         rounds_per_log=5)   # 5 rounds = 20 steps per sync
    _, _, hist = trainer.train(params0, lambda t: lm_batch(data, t),
                               steps=STEPS, log_every=20)
    print(f"{label}\n  loss {hist.loss[0]:.3f} -> {hist.loss[-1]:.3f}   "
          f"communicated {hist.comm_mb[-1]:.2f} MB over "
          f"{STEPS // opt.config.p} rounds\n")
