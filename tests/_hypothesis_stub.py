"""Minimal stand-in for ``hypothesis`` when it is not installed.

``tests/test_compression.py`` falls back to this so the suite *collects and
runs* in environments without hypothesis (the container image, offline dev
boxes).  Each ``@given`` test executes a fixed number of seeded
pseudo-random examples — weaker than the real engine (no shrinking, no
adaptive search) but the properties are still exercised.  Installing the
``[test]`` extra from pyproject.toml restores real hypothesis.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng):
        return self._draw(rng)


class st:
    """Subset of ``hypothesis.strategies`` the tests use."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def composite(fn):
        @functools.wraps(fn)
        def make(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda strat: strat.draw(rng),
                               *args, **kwargs))
        return make


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


# Each distinct drawn shape triggers an XLA recompile of the compressor
# under test, so the stub trades example count for wall-clock time.
_EXAMPLES_CAP = 8


def given(**named_strategies):
    def deco(fn):
        n = min(getattr(fn, "_stub_max_examples", 20), _EXAMPLES_CAP)

        @functools.wraps(fn)
        def run(*args, **kwargs):
            for i in range(n):
                rng = np.random.default_rng(0xC0FFEE + 7919 * i)
                drawn = {k: s.draw(rng)
                         for k, s in named_strategies.items()}
                fn(*args, **kwargs, **drawn)

        # hide the strategy-supplied params from pytest's fixture resolver
        sig = inspect.signature(fn)
        run.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in named_strategies])
        del run.__wrapped__
        return run
    return deco
