"""Config registry: ``get_config(name)``, smoke-reduction, shape policies."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs import (arctic_480b, internvl2_76b, jamba_1_5_large_398b,
                           mamba2_1_3b, minicpm3_4b, mixtral_8x7b,
                           musicgen_medium, olmo_1b, paper_resnet20,
                           qwen2_72b, stablelm_12b)
from repro.configs.base import LayerSpec, ModelCfg, RunCfg
from repro.configs.shapes import SHAPES, InputShape

__all__ = ["ARCHS", "get_config", "get_smoke_config", "list_archs",
           "long_ctx_variant", "shape_supported"]

ARCHS = {
    "arctic-480b": arctic_480b.config,
    "mixtral-8x7b": mixtral_8x7b.config,
    "stablelm-12b": stablelm_12b.config,
    "olmo-1b": olmo_1b.config,
    "qwen2-72b": qwen2_72b.config,
    "musicgen-medium": musicgen_medium.config,
    "minicpm3-4b": minicpm3_4b.config,
    "internvl2-76b": internvl2_76b.config,
    "jamba-1.5-large-398b": jamba_1_5_large_398b.config,
    "mamba2-1.3b": mamba2_1_3b.config,
    "paper-resnet20": paper_resnet20.config,
}

ASSIGNED: List[str] = [k for k in ARCHS if k != "paper-resnet20"]


def list_archs() -> List[str]:
    return list(ARCHS)


def get_config(name: str) -> RunCfg:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {list(ARCHS)}")
    return ARCHS[name]()


# --------------------------------------------------------------------- long ctx
LONG_CTX_WINDOW = 8192  # sliding-window applied to full-attention archs @500k


def long_ctx_variant(model: ModelCfg) -> ModelCfg:
    """Model variant used for the long_500k shape.

    SSM/hybrid run natively (O(1)/sparse state).  Archs with a native window
    (mixtral) keep it.  Pure full-attention archs get the sliding-window
    variant (window 8192) — the sub-quadratic requirement of the assignment.
    """
    if model.arch_type in ("ssm", "hybrid"):
        return model
    if model.window is not None:
        return model
    return dataclasses.replace(model, window=LONG_CTX_WINDOW)


def shape_supported(model: ModelCfg, shape: InputShape) -> bool:
    if model.arch_type == "cnn":
        return False  # paper model: trained by the benchmarks, not dryrun
    return True


# --------------------------------------------------------------------- smoke
def get_smoke_config(name: str) -> RunCfg:
    """Reduced same-family variant: 2 layers, d_model ≤ 512, ≤ 4 experts."""
    run = get_config(name)
    m = run.model
    if m.arch_type == "cnn":
        return run
    pattern = m.pattern
    if len(pattern) > 2:  # jamba: keep hybrid character in 2 layers
        pattern = (LayerSpec("mamba", "dense"), LayerSpec("attn", "moe"))
    n_layers = 2 if len(pattern) <= 2 else len(pattern)
    small = dataclasses.replace(
        m,
        n_layers=n_layers,
        pattern=pattern,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, 4 * m.n_kv_heads // m.n_heads)),
        head_dim=32,
        d_ff=min(m.d_ff, 256) if m.d_ff else 0,
        vocab=min(m.vocab, 512),
        n_experts=min(m.n_experts, 4) if m.n_experts else 0,
        window=min(m.window, 64) if m.window else None,
        q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        ssm_state=32, ssm_headdim=16, ssm_chunk=16,
        n_patches=16,
        param_dtype="float32", compute_dtype="float32",
    )
    return dataclasses.replace(run, model=small)
