"""Pallas TPU kernels for the paper's memory-bound hot spots.

momentum       — fused SGDM update (PD-SGDM inner loop)
sign_compress  — blockwise scaled-sign + bit-pack (CPD-SGDM wire format)
gossip_mix     — fused W-row neighbour AXPY after ppermute

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling; ``ops.py``
holds the ``KernelPlan`` flatten-once layout and the jit'd pytree wrappers
(interpret-mode on CPU); ``ref.py`` the pure-jnp oracles used by the
allclose sweeps in tests/test_kernels.py.
"""


def default_interpret() -> bool:
    """Whether Pallas calls should run in interpret mode *right now*.

    Evaluated lazily (not pinned at import time) so backend selection that
    happens after this package is imported — ``jax.config`` updates in
    tests, subprocess runners forcing host devices — is respected.  Every
    kernel entry point also takes an explicit ``interpret=`` override.
    """
    import jax
    return jax.default_backend() != "tpu"
