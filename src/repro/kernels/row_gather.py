"""Sparse-row gather/scatter kernels: the data movers of the sparse wire.

The sparse-rows codec (``repro.core.wire.SparseRowsCodec``) ships only the
*touched* rows of the flatten-once ``(rows, LANE)`` layout: an index vector
plus a compact ``(S, LANE)`` payload matrix, ``S`` = the static per-leaf
row budget summed over leaves.  These two kernels are its hot spots:

  * ``row_gather_pallas``  — x (rows, LANE) f32 + idx (S,) i32 →
                             payload (S, LANE) f32, ``payload[j] =
                             x[idx[j]]`` with lanes ≥ the row's true
                             length (``counts``) zeroed (counts-aware: a
                             gathered tail row ships exactly its valid
                             prefix even if the source held junk).
  * ``row_scatter_pallas`` — inverse: out (rows, LANE) f32 with
                             ``out[idx[j]] += payload[j]`` and every
                             untouched row exactly 0.

Both are scalar-prefetch kernels (``pltpu.PrefetchScalarGridSpec``): the
index vector is prefetched to SMEM and drives the ``BlockSpec`` index_map,
so each grid step DMAs exactly one touched row — the canonical TPU sparse
gather idiom.  The scatter accumulates into a zero-initialized output via
``input_output_aliases`` (the zeros operand *is* the output buffer), so
rows no grid step visits stay exactly 0.

Contract: within one payload the indices are **distinct** (the codec
selects per-leaf top-norm rows — distinct within a leaf, disjoint row
segments across leaves) and sorted ascending, so the scatter is a pure
permutation write and bit-exact against the jnp oracle
(``repro.kernels.ref.row_gather_ref`` / ``row_scatter_ref``); duplicate
indices would make the read-accumulate-write order visible and are not
supported.  Kernels move bytes, they never transform values — which is
what makes the kernel wire bit-identical to the per-leaf jnp codec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import LANE, default_interpret

__all__ = ["row_gather_pallas", "row_scatter_pallas", "LANE"]


def _gather_kernel(idx_ref, x_ref, cnt_ref, out_ref):
    del idx_ref  # consumed by the BlockSpec index_map (scalar prefetch)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
    valid = lanes < cnt_ref[0, 0].astype(jnp.int32)
    out_ref[...] = jnp.where(valid, x_ref[...], jnp.float32(0.0))


def _scatter_kernel(idx_ref, base_ref, val_ref, out_ref):
    del idx_ref  # consumed by the BlockSpec index_maps (scalar prefetch)
    out_ref[...] = base_ref[...] + val_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def row_gather_pallas(x, idx, counts=None, *,
                      interpret: bool | None = None):
    """x (rows, LANE) f32 + idx (S,) i32 → gathered (S, LANE) f32.

    ``counts``: per-row true lengths (``KernelPlan.row_counts``); the
    gathered row keeps only its valid prefix.  None = full rows.
    """
    if interpret is None:
        interpret = default_interpret()
    rows, lane = x.shape
    assert lane == LANE, (rows, lane)
    (s,) = idx.shape
    idx = idx.astype(jnp.int32)
    if counts is None:
        cnt_g = jnp.full((s, 1), float(LANE), jnp.float32)
    else:
        cnt_g = jnp.take(jnp.asarray(counts, jnp.float32).reshape(rows),
                         idx, axis=0).reshape(s, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, LANE), lambda j, idx_ref: (idx_ref[j], 0)),
            pl.BlockSpec((1, 1), lambda j, idx_ref: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda j, idx_ref: (j, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, LANE), jnp.float32),
        interpret=interpret,
    )(idx, x.astype(jnp.float32), cnt_g)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def row_scatter_pallas(idx, vals, *, rows: int,
                       interpret: bool | None = None):
    """idx (S,) i32 + vals (S, LANE) f32 → out (rows, LANE) f32 with
    ``out[idx[j]] += vals[j]`` and untouched rows exactly 0."""
    if interpret is None:
        interpret = default_interpret()
    s, lane = vals.shape
    assert lane == LANE and idx.shape == (s,), (idx.shape, vals.shape)
    idx = idx.astype(jnp.int32)
    base = jnp.zeros((rows, LANE), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, LANE), lambda j, idx_ref: (idx_ref[j], 0)),
            pl.BlockSpec((1, LANE), lambda j, idx_ref: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda j, idx_ref: (idx_ref[j], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        # the zeros operand is the output buffer: unvisited rows stay 0
        input_output_aliases={1: 0},
        interpret=interpret,
    )(idx, base, vals.astype(jnp.float32))
