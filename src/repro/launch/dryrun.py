import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place that forces 512
# placeholder devices — tests and benchmarks see the real single device.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import (ASSIGNED, get_config, long_ctx_variant,
                                    shape_supported)  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.analytic import analytic_cost  # noqa: E402
from repro.launch.hlo_analysis import (model_flops, parse_collectives,
                                       roofline_terms)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.runtime import build_serve, build_train  # noqa: E402

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) this lowers + compiles the
production step function against ShapeDtypeStruct inputs (no allocation),
prints ``memory_analysis()`` / ``cost_analysis()``, parses the post-SPMD HLO
for collective traffic, and writes one JSON artifact per combination into
``artifacts/dryrun/`` for the roofline benchmark to aggregate.

  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                # 16×16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod    # 2×16×16
"""


def _cost_scalar(cost, key):
    try:
        return float(cost.get(key, 0.0))
    except Exception:
        return 0.0


def compute_loop_trips(mcfg, shape, kind: str, p: int):
    """Known scan trip counts by while-nesting depth.

    depth 1 (train) = p round steps; next = layer-scan repeats; innermost =
    the largest per-layer scan (blockwise-attention q-chunks when the shape
    triggers blockwise, else the SSD chunk count) — a conservative upper
    bound used to surface in-chunk collectives, which a healthy sharding
    should not have at all.
    """
    s = shape.seq_len
    has_attn = any(sp.mixer in ("attn", "mla") for sp in mcfg.pattern)
    has_ssm = any(sp.mixer == "mamba" for sp in mcfg.pattern)
    inner = 1
    if kind != "decode":
        if has_attn and s >= 8192:           # AttnCfg.blockwise_threshold
            from repro.models.attention import AttnCfg
            inner = max(inner, s // AttnCfg.q_chunk)
        if has_ssm:
            inner = max(inner, s // mcfg.ssm_chunk)
    trips = [mcfg.n_repeats]
    if kind == "train":
        trips = [p] + trips
    if inner > 1:
        trips = trips + [inner]
    return tuple(trips)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            outdir: str, overrides=None, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    run = get_config(arch)
    if overrides:
        run = overrides(run)
    mcfg = run.model
    if shape_name == "long_500k":
        mcfg = long_ctx_variant(mcfg)
    if not shape_supported(mcfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            pack = build_train(run, mesh, shape, model_cfg=mcfg)
            lowered = pack.train_round.lower(
                pack.params_struct, pack.state_struct,
                pack.round_batch_struct)
            tokens = (run.optim.p * shape.global_batch * shape.seq_len)
            kind = "train"
            n_workers = pack.layout.n_workers
        else:
            sp = build_serve(run, mesh, shape, model_cfg=mcfg)
            if shape.kind == "prefill":
                lowered = sp.prefill_step.lower(sp.params_struct,
                                                sp.pre_struct)
                tokens = shape.global_batch * shape.seq_len
            else:
                tok_struct = jax.ShapeDtypeStruct(
                    (shape.global_batch,), jnp.int32)
                pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = sp.decode_step.lower(
                    sp.params_struct, sp.cache_struct, tok_struct,
                    pos_struct)
                tokens = shape.global_batch  # one token per sequence
            kind = shape.kind
            n_workers = 1
        compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    print(f"--- {arch} × {shape_name} × "
          f"{'2x16x16' if multi_pod else '16x16'} {tag}")
    print(f"memory_analysis: {mem}")
    print("cost_analysis:", {k: v for k, v in sorted(cost.items())
                             if "{" not in k})

    # collective traffic from post-SPMD HLO, with known scan trip counts
    # (outer train-round scan = p steps; layer scan = n_repeats; innermost
    # per-layer scan = blockwise-attention q-chunks or SSD chunks).
    loop_trips = compute_loop_trips(mcfg, shape, kind, run.optim.p)
    colls = parse_collectives(compiled.as_text(), loop_trips=loop_trips)

    # analytic flop/byte model (XLA cost_analysis counts scan bodies once —
    # raw numbers recorded below for reference)
    ac = analytic_cost(mcfg, shape, kind, run.optim.p, n_chips,
                       n_workers, run.parallel.remat)
    terms = roofline_terms(ac["flops_per_device"], ac["bytes_per_device"],
                           colls.total_wire_bytes)

    mf = model_flops(mcfg.active_params_count(), ac["tokens"], kind)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "kind": kind, "n_chips": n_chips, "n_workers": n_workers,
        "profile": run.parallel.profile,
        "optimizer": run.optim.name, "p": run.optim.p,
        "compile_s": round(compile_s, 1),
        "tokens_per_call": ac["tokens"],
        "flops_per_device": ac["flops_per_device"],
        "bytes_per_device": ac["bytes_per_device"],
        "xla_cost_flops_per_device": _cost_scalar(cost, "flops"),
        "xla_cost_bytes_per_device": _cost_scalar(cost, "bytes accessed"),
        "collective_counts": colls.counts,
        "collective_result_bytes": colls.result_bytes,
        "collective_wire_bytes": colls.wire_bytes,
        "wire_bytes_per_device": colls.total_wire_bytes,
        "terms": terms,
        "model_flops": mf,
        "hlo_total_flops": ac["flops_total"],
        "useful_flops_ratio": (mf / ac["flops_total"])
        if ac["flops_total"] else 0.0,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "skipped": False,
    }
    dom = terms["dominant"]
    bpd = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
           + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    print(f"terms: compute={terms['compute_s']*1e3:.2f}ms "
          f"memory={terms['memory_s']*1e3:.2f}ms "
          f"collective={terms['collective_s']*1e3:.2f}ms "
          f"dominant={dom} useful_ratio={record['useful_flops_ratio']:.2f} "
          f"hbm/dev={bpd/2**30:.2f}GiB compile={compile_s:.0f}s")

    os.makedirs(outdir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{record['mesh']}"
    if tag:
        fname += f"__{tag}"
    with open(os.path.join(outdir, fname + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    return record


def _hier_overrides(multi_pod: bool):
    """Two-level gossip on the production meshes: 16 workers → 4 nodes of
    4 on the single-pod worker axis; on the 2×16×16 multi-pod mesh the
    ("pod","data") layout requires node_size == data-axis size (the pod
    boundary is the node boundary)."""
    node_size = 16 if multi_pod else 4

    def ov(run):
        return dataclasses.replace(
            run, parallel=dataclasses.replace(run.parallel,
                                              node_size=node_size))
    return ov


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--hier", action="store_true",
                    help="compile the two-level gossip round (node_size 4 "
                         "single-pod / 16 multi-pod); artifacts tagged "
                         "__hier")
    ap.add_argument("--outdir", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shp in shapes:
                mesh_tag = "2x16x16" if mp else "16x16"
                fname = f"{arch}__{shp}__{mesh_tag}"
                if args.hier:
                    fname += "__hier"
                path = os.path.join(args.outdir, fname + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"skip (exists): {arch} × {shp} × {mesh_tag}")
                    continue
                try:
                    run_one(arch, shp, mp, args.outdir,
                            overrides=(_hier_overrides(mp) if args.hier
                                       else None),
                            tag=("hier" if args.hier else ""))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shp, mesh_tag, repr(e)[:200]))
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nDRY-RUN: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
