"""Repo-specific AST lint (the source-level half of the round contract).

Rules (each a hot-path invariant that grep can't check reliably):

RPR001  host-sync-in-core     ``block_until_ready`` / ``np.asarray`` inside
                              ``core/`` — a host sync in a round body
                              serializes the async dispatch pipeline.
                              ``core/topology.py`` is exempt (its float64
                              spectral math is host-side *by design* and
                              never traced).
RPR002  compressor-dispatch   ``isinstance(…, *Compressor)`` outside
                              ``core/wire.py`` — codec dispatch has exactly
                              one home (``make_codec``); scattered
                              isinstance chains were how pre-PR-4 wire
                              formats drifted apart.
RPR003  lane-literal          hardcoded ``1024`` outside ``repro/kernels/``
                              — the kernel lane width is ``LANE``; a bare
                              1024 silently decouples from the layout if
                              the lane ever changes.  Non-lane 1024s
                              (sequence chunks, patch counts) carry an
                              explicit ``# lint: allow`` pragma.
RPR004  config-at-import      module-level ``jax.config.update`` outside
                              ``repro/__init__.py`` — import-time config
                              mutation makes behavior depend on import
                              order.

``# lint: allow`` on the offending line suppresses any rule (use
sparingly; every pragma is an documented exception, not an escape hatch).

This module deliberately imports no jax so ``tools/lint_repro.py`` stays
instant.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import List

__all__ = ["LintError", "lint_source", "lint_paths", "iter_py_files"]

PRAGMA = "lint: allow"
LANE_WIDTH = 1024      # the rule's own reference value  # lint: allow


@dataclasses.dataclass(frozen=True)
class LintError:
    path: str
    line: int
    rule: str
    msg: str

    def __str__(self):
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _in(path: str, fragment: str) -> bool:
    return fragment in _norm(path)


def _call_name(node: ast.Call) -> str:
    """Trailing attribute/name of a call target: ``jax.block_until_ready``
    -> ``block_until_ready``."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _dotted(node) -> str:
    """Best-effort dotted name of an expression (``jax.config.update``)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class _Linter(ast.NodeVisitor):
    def __init__(self, rel_path: str, src_lines: List[str]):
        self.rel = _norm(rel_path)
        self.lines = src_lines
        self.errors: List[LintError] = []
        self._func_depth = 0

    # ---- helpers
    def _pragma(self, node) -> bool:
        ln = getattr(node, "lineno", 0)
        if 1 <= ln <= len(self.lines) and PRAGMA in self.lines[ln - 1]:
            return True
        return False

    def _err(self, node, rule: str, msg: str):
        if not self._pragma(node):
            self.errors.append(LintError(self.rel, node.lineno, rule, msg))

    # ---- scope tracking (module level vs inside a function)
    def visit_FunctionDef(self, node):
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    # ---- RPR001 / RPR002 / RPR004
    def visit_Call(self, node: ast.Call):
        name = _call_name(node)
        in_core = (_in(self.rel, "repro/core/")
                   and not self.rel.endswith("core/topology.py"))
        if in_core and name == "block_until_ready":
            self._err(node, "RPR001",
                      "block_until_ready in core/ — host sync in the round "
                      "hot path")
        if in_core and _dotted(node.func) in ("np.asarray", "numpy.asarray"):
            self._err(node, "RPR001",
                      "np.asarray in core/ — device→host transfer in traced "
                      "code (topology.py is the only host-side module)")
        if (name == "isinstance" and len(node.args) == 2
                and not self.rel.endswith("core/wire.py")):
            classes = node.args[1]
            cands = (classes.elts if isinstance(classes, ast.Tuple)
                     else [classes])
            for c in cands:
                cname = _dotted(c)
                if cname.split(".")[-1].endswith("Compressor"):
                    self._err(node, "RPR002",
                              f"isinstance(…, {cname}) — compressor dispatch "
                              "belongs to core/wire.py (make_codec)")
                    break
        if (_dotted(node.func) in ("jax.config.update", "config.update",
                                   "_jax.config.update")
                and self._func_depth == 0
                and not self.rel.endswith("repro/__init__.py")):
            self._err(node, "RPR004",
                      "module-level jax.config.update — import-time config "
                      "mutation outside repro/__init__")
        self.generic_visit(node)

    # ---- RPR003
    def visit_Constant(self, node: ast.Constant):
        if (node.value == LANE_WIDTH and isinstance(node.value, int)
                and not isinstance(node.value, bool)
                and not _in(self.rel, "repro/kernels/")):
            self._err(node, "RPR003",
                      "hardcoded 1024 — use the LANE constant "
                      "(repro.kernels.LANE) or mark a genuine non-lane "
                      "constant with `# lint: allow`")
        self.generic_visit(node)


def lint_source(src: str, rel_path: str) -> List[LintError]:
    """Lint one file's source text; ``rel_path`` is repo-relative."""
    try:
        tree = ast.parse(src, filename=rel_path)
    except SyntaxError as e:
        return [LintError(_norm(rel_path), e.lineno or 0, "RPR000",
                          f"syntax error: {e.msg}")]
    linter = _Linter(rel_path, src.splitlines())
    linter.visit(tree)
    return sorted(linter.errors, key=lambda e: (e.path, e.line))


def iter_py_files(roots):
    for root in roots:
        if os.path.isfile(root):
            if root.endswith(".py"):
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_paths(roots, base: str = ".") -> List[LintError]:
    """Lint every ``.py`` under the given roots (files or directories)."""
    out: List[LintError] = []
    for path in iter_py_files(roots):
        rel = os.path.relpath(path, base)
        with open(path, encoding="utf-8") as f:
            out.extend(lint_source(f.read(), rel))
    return out
