"""Architecture configs (assigned pool + the paper's own model) and shapes."""
