"""Distributed runtime: builds jit-able train / prefill / decode steps.

Structure of one training iteration (see DESIGN.md):

  1. per-worker forward+backward — ``vmap`` over the stacked worker dim, in
     the pjit/GSPMD domain (XLA inserts the tensor-parallel collectives and,
     in profile B, the FSDP all-gathers + within-worker gradient psums);
  2. the PD/CPD-SGDM optimizer step — wrapped in ``jax.shard_map`` so the
     gossip round lowers to explicit ``ppermute`` (collective-permute) over
     the worker axes, with the compressed payload bit-packed on the wire.

``TrainPack.train_round`` is the **canonical hot path**: one jitted call =
``lax.scan`` of p local steps + exactly one gossip round (``opt.round``
with the optimizer calls shard_mapped), buffers donated.  It is what
``repro.train.trainer.ShardedTrainer`` executes, and the honest unit for
the dry-run roofline: compute of p steps, communication of exactly one
gossip round.  ``train_step`` remains for per-step debugging and for runs
whose tail is shorter than a round.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelCfg, RunCfg
from repro.configs.shapes import InputShape, train_batch_specs
from repro.core import make_compressor, make_optimizer
from repro.core.gossip import DenseComm, HierarchicalComm, ShardedComm
from repro.core.topology import (disconnected, hierarchical, make_schedule,
                                 make_topology, torus)
from repro.launch.sharding import (Layout, batch_spec_tree, cache_spec_tree,
                                   make_layout, param_spec_tree, to_shardings)
from repro.models import make_model

__all__ = ["build_comm", "build_train", "build_serve", "TrainPack",
           "ServePack", "make_shd"]

if hasattr(jax, "shard_map"):           # stable top-level API
    _shard_map_compat = jax.shard_map
else:                                   # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_compat

# the replication-check kwarg was renamed check_rep -> check_vma; key on the
# signature, not the jax version, so the mid-range releases work too
_CHECK_KW = ("check_vma" if "check_vma" in inspect.signature(
    _shard_map_compat).parameters else "check_rep")


def _smap(mesh):
    return functools.partial(_shard_map_compat, mesh=mesh,
                             **{_CHECK_KW: False})


def make_shd(layout: Layout, parallel):
    """Logical-axis sharding-constraint hook for the model (perf levers).

    Only active when a perf flag requests it — the baseline model runs with
    GSPMD propagation alone.  Names present in the rule table force a
    constraint (a None mapping = explicit replication over that dim).
    """
    rules = {}       # name -> (axis, priority); higher priority wins an axis
    if getattr(parallel, "attn_ctx_shard", False) and layout.tp_axis:
        # attention core: prefer head-sharded q (blockwise-safe: the chunk
        # scan slices seq, so a seq shard would reshard every chunk); fall
        # back to seq-sharded q when heads don't divide the tp axis
        # (e.g. arctic's 56 heads on 16).  k/v explicitly replicated.
        rules["heads"] = (layout.tp_axis, 2)
        rules["seq_q"] = (layout.tp_axis, 1)
        rules["seq_kv"] = (None, 0)
    if getattr(parallel, "moe_token_shard", False):
        if layout.fsdp_axis:
            rules["tokens"] = (layout.fsdp_axis, 2)
            rules["expert"] = (layout.fsdp_axis, 2)
            rules["group"] = (layout.fsdp_axis, 2)
        if layout.tp_axis:
            rules["mlp"] = (layout.tp_axis, 1)
    if not rules:
        return lambda x, *names: x
    mesh = layout.mesh

    def shd(x, *names):
        if not any(n in rules for n in names):
            return x
        spec = [None] * len(names)
        used = set()
        order = sorted(range(len(names)),
                       key=lambda i: -(rules.get(names[i], (None, -1))[1]))
        for i in order:
            n = names[i]
            if n not in rules:
                continue
            ax = rules[n][0]
            if (ax is None or ax in used or i >= x.ndim
                    or x.shape[i] % mesh.shape[ax] != 0):
                continue
            spec[i] = ax
            used.add(ax)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return shd


# --------------------------------------------------------------------------- comm
def build_comm(run: RunCfg, layout: Layout, membership=None):
    """Topology (or topology schedule) + comm backend for the worker layout.

    ``parallel.topology_schedule != "static"`` selects a time-varying gossip
    graph: the ShardedComm precomputes every round's ppermute program and
    the fused round engine switches between them on the traced round index.
    ``membership`` (a ``MembershipSchedule``) masks dead/straggling workers
    out of each round's mixing matrix (elastic fleets).

    ``parallel.node_size > 0`` selects two-level gossip
    (:class:`HierarchicalComm`): exact intra-node averaging over groups of
    ``node_size`` workers + ``parallel.topology`` between node leaders
    (optionally codec-compressed via ``parallel.inter_codec``).  On a
    two-axis worker layout the inner axis is the node.
    """
    waxes = layout.worker_axes
    sizes = layout.worker_sizes
    wd = getattr(run.optim, "wire_dtype", "float32")
    if not waxes:
        return DenseComm(disconnected(1), membership=membership,
                         wire_dtype=wd)
    sched_name = getattr(run.parallel, "topology_schedule", "static")
    node_size = int(getattr(run.parallel, "node_size", 0) or 0)
    if node_size:
        K = int(layout.n_workers)
        if len(waxes) == 2:
            if node_size != sizes[1]:
                raise ValueError(
                    f"node_size {node_size} must equal the inner worker "
                    f"axis size {sizes[1]} on a two-axis layout "
                    f"{waxes}: the node boundary is the mesh axis")
        elif K % node_size != 0:
            raise ValueError(
                f"node_size {node_size} does not divide the worker count "
                f"{K}")
        n_nodes = K // node_size
        if sched_name in ("hier_one_peer", "hierarchical_one_peer"):
            first = make_schedule("hier_one_peer", (n_nodes, node_size))
        elif sched_name == "static":
            first = hierarchical(n_nodes, node_size,
                                 inter=run.parallel.topology)
        else:
            raise ValueError(
                f"topology_schedule {sched_name!r} does not compose with "
                "node_size (hierarchical rounds support 'static' and "
                "'hier_one_peer')")
        return HierarchicalComm(first, axis_names=waxes,
                                membership=membership, wire_dtype=wd,
                                inter_codec=_make_inter_codec(run))
    if sched_name != "static":
        sched = make_schedule(
            sched_name, sizes, base_topology=run.parallel.topology,
            rounds=run.parallel.schedule_rounds,
            seed=run.parallel.schedule_seed)
        return ShardedComm(sched, axis_names=waxes, membership=membership,
                           wire_dtype=wd)
    if len(waxes) == 1:
        topo = make_topology(run.parallel.topology, sizes)
    else:
        topo = torus(sizes)  # hierarchical pod×ring mixing
    return ShardedComm(topo, axis_names=waxes, membership=membership,
                       wire_dtype=wd)


def _make_inter_codec(run: RunCfg):
    """The keyless WireCodec for the hierarchical inter-node wire, from
    ``parallel.inter_codec`` (shape knobs shared with the compressor)."""
    from repro.core.wire import make_codec
    name = str(getattr(run.parallel, "inter_codec", "none")).lower()
    if name in ("none", ""):
        return None
    o = run.optim
    comp = make_compressor(
        name, **_compressor_kwargs(dataclasses.replace(o, compressor=name)))
    return make_codec(comp)


def _compressor_kwargs(o) -> dict:
    """OptimCfg knobs → the named compressor's constructor args."""
    name = o.compressor.lower()
    if name == "sign":
        return {"block": o.compressor_block}
    if name == "topk":
        return {"fraction": o.compressor_fraction,
                "block": o.compressor_block}
    if name == "randk":
        return {"fraction": o.compressor_fraction}
    if name == "qsgd":
        return {"levels": o.compressor_levels,
                "block": o.compressor_block}
    if name in ("sparse", "sparse_rows") or name.startswith("sparse+"):
        return {"max_rows": o.compressor_rows,
                "levels": o.compressor_levels,
                "block": o.compressor_block}
    return {}


def _make_optimizer(run: RunCfg, comm):
    o = run.optim
    # cpd/choco always ship a codec payload; mt ships the correction wire
    # compressed only when explicitly opted in (track_compressed)
    wants_comp = (o.name.startswith(("cpd", "choco"))
                  or (o.name.startswith("mt") and o.track_compressed))
    comp = make_compressor(o.compressor, **_compressor_kwargs(o)) if \
        wants_comp else None
    return make_optimizer(
        o.name, comm, eta=o.eta, mu=o.mu, p=o.p, gamma=o.gamma,
        weight_decay=o.weight_decay, compressor=comp,
        use_kernel=o.use_kernel, kernel_interpret=o.kernel_interpret,
        overlap=o.overlap)


# --------------------------------------------------------------------------- train
@dataclasses.dataclass
class TrainPack:
    model: object
    opt: object
    layout: Layout
    params_struct: object
    state_struct: object
    batch_struct: object
    params_sharding: object
    state_sharding: object
    batch_sharding: object
    init_fn: Callable             # (key) -> (params, opt_state)  [jit, sharded]
    train_step: Callable          # (params, state, batch) -> (params, state, loss)
    train_round: Callable         # (params, state, batches[p]) -> (..., losses)
    round_batch_struct: object
    round_batch_sharding: object


def build_train(run: RunCfg, mesh, shape: InputShape,
                model_cfg: Optional[ModelCfg] = None,
                membership=None) -> TrainPack:
    mcfg = model_cfg or run.model
    layout = make_layout(run.parallel, mesh)
    model = make_model(mcfg, shd=make_shd(layout, run.parallel))
    n_w = layout.n_workers
    comm = build_comm(run, layout, membership=membership)
    opt = _make_optimizer(run, comm)
    remat = run.parallel.remat
    p_round = run.optim.p

    # ---- structs
    def init_stacked(key):
        keys = jax.random.split(key, n_w)
        # all workers start from x0 (paper: x₀ identical) — fold_in worker id
        # only for data; params use the same key.
        return jax.vmap(lambda k: model.init(key))(keys)

    params_struct = jax.eval_shape(init_stacked, jax.random.PRNGKey(0))
    state_struct = jax.eval_shape(opt.init, params_struct)
    batch_struct = train_batch_specs(mcfg, shape, n_w)

    # ---- spec trees
    pspec = param_spec_tree(params_struct, layout, stacked_worker=True)
    sspec = _state_spec(state_struct, pspec)
    bspec = batch_spec_tree(batch_struct, layout)
    params_sh = to_shardings(pspec, mesh)
    state_sh = to_shardings(sspec, mesh)
    batch_sh = to_shardings(bspec, mesh)

    # ---- loss / grads (GSPMD domain)
    def loss_fn(p, b):
        loss, met = model.loss(p, b, remat=remat)
        return loss, met

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn, has_aux=True))

    # ---- optimizer (manual / shard_map domain)
    def opt_full(p, s, g):
        return opt.step(s, p, g)

    def opt_local(p, s, g):
        return opt.local_step(s, p, g)

    def opt_comm(p, s):
        return opt.comm_round(s, p)

    smap = _smap(mesh)
    opt_full_sh = smap(opt_full, in_specs=(pspec, sspec, pspec),
                       out_specs=(pspec, sspec))
    opt_local_sh = smap(opt_local, in_specs=(pspec, sspec, pspec),
                        out_specs=(pspec, sspec))
    opt_comm_sh = smap(opt_comm, in_specs=(pspec, sspec),
                       out_specs=(pspec, sspec))

    def train_step(params, state, batch):
        (losses, mets), grads = grad_fn(params, batch)
        params, state = opt_full_sh(params, state, grads)
        return params, state, losses.mean()

    def gfn(p_, b):
        (losses, _mets), grads = grad_fn(p_, b)
        return losses.mean(), grads

    if run.optim.use_kernel and opt.kernel_comm_supported:
        # kernel execution path: the whole round runs on the flatten-once
        # (n_workers, rows, 1024) matrix — flatten/unflatten happen in the
        # GSPMD domain (the worker dim stays sharded over the worker axes;
        # inside shard_map each device sees its (1, rows, 1024) shard), and
        # only the matrix-domain optimizer calls enter the manual domain.
        from repro.kernels import ops as kops
        plan = kops.KernelPlan.for_tree(params_struct, worker_dim=True)
        mspec = P(layout.worker_axes or None, None, None)
        opt_local_mat_sh = smap(opt.local_step_mat,
                                in_specs=(mspec, mspec, mspec, P()),
                                out_specs=(mspec, mspec))
        opt_comm_mat_sh = smap(functools.partial(opt.comm_round_mat,
                                                 plan=plan),
                               in_specs=(mspec, mspec, P(), P()),
                               out_specs=(mspec, mspec))

        if run.optim.overlap:
            # overlapped rounds: the in-flight payload's exchange (the only
            # collective) is shard_mapped at round *start*; the stale
            # correction lands matrix-to-matrix after the scan.
            ob_mat_sh = smap(functools.partial(opt.overlap_begin_mat,
                                               plan=plan),
                             in_specs=(mspec, P(), P()), out_specs=mspec)
            oa_mat_sh = smap(opt.overlap_apply_mat,
                             in_specs=(mspec, mspec, mspec, P()),
                             out_specs=(mspec, mspec))
            orf_mat_sh = (smap(opt.overlap_refresh_mat,
                               in_specs=(mspec, mspec), out_specs=mspec)
                          if opt.overlap_refreshes else None)

            def train_round(params, state, batches):
                """Overlapped round on the kernel layout: exchange issued
                at round start, p momentum steps, stale mix landed."""
                return opt.kernel_round(
                    state, params, gfn, batches,
                    local_step_mat=opt_local_mat_sh,
                    comm_round_mat=opt_comm_mat_sh,
                    overlap_begin_mat=ob_mat_sh,
                    overlap_apply_mat=oa_mat_sh,
                    overlap_refresh_mat=orf_mat_sh)
        else:
            def train_round(params, state, batches):
                """p momentum steps + one gossip, all on the kernel
                layout."""
                return opt.kernel_round(
                    state, params, gfn, batches,
                    local_step_mat=opt_local_mat_sh,
                    comm_round_mat=opt_comm_mat_sh)
    elif run.optim.overlap:
        dspec = {k: pspec for k in opt.overlap_delta_keys}
        ob_sh = smap(opt.overlap_begin, in_specs=(sspec,), out_specs=dspec)
        oa_sh = smap(opt.overlap_apply,
                     in_specs=(sspec, pspec, dspec),
                     out_specs=(pspec, sspec))
        orf_sh = (smap(opt.overlap_step_refresh, in_specs=(sspec, dspec),
                       out_specs=sspec)
                  if opt.overlap_refreshes else None)

        def train_round(params, state, batches):
            """Overlapped round: the in-flight payload's gossip (the only
            ppermutes) issues at round start with no data dependence on
            the p-step scan; the one-round-stale correction lands at the
            round's end (``opt.round`` owns the structure, the optimizer
            calls are shard_mapped exactly like the synchronous path)."""
            return opt.round(
                state, params, gfn, batches,
                local_step=lambda s, p_, g: opt_local_sh(p_, s, g),
                overlap_begin=ob_sh, overlap_apply=oa_sh,
                overlap_refresh=orf_sh)
    else:
        def train_round(params, state, batches):
            """p local momentum steps then exactly one gossip round.

            The scan structure lives in ``opt.round``; only the optimizer
            calls are shard_mapped into the manual domain (the forward/
            backward stays in the GSPMD domain).
            """
            return opt.round(
                state, params, gfn, batches,
                local_step=lambda s, p_, g: opt_local_sh(p_, s, g),
                comm_round=lambda s, p_: opt_comm_sh(p_, s))

    round_batch_struct = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((p_round,) + s.shape, s.dtype),
        batch_struct)
    round_batch_sh = jax.tree_util.tree_map(
        lambda sh: NamedSharding(mesh, P(None, *sh.spec)), batch_sh)

    def init_fn(key):
        params = init_stacked(key)
        return params, opt.init(params)

    jit_init = jax.jit(init_fn, out_shardings=(params_sh, state_sh))
    jit_step = jax.jit(train_step,
                       in_shardings=(params_sh, state_sh, batch_sh),
                       out_shardings=(params_sh, state_sh, None),
                       donate_argnums=(0, 1))
    jit_round = jax.jit(train_round,
                        in_shardings=(params_sh, state_sh, round_batch_sh),
                        out_shardings=(params_sh, state_sh, None),
                        donate_argnums=(0, 1))

    return TrainPack(
        model=model, opt=opt, layout=layout,
        params_struct=params_struct, state_struct=state_struct,
        batch_struct=batch_struct,
        params_sharding=params_sh, state_sharding=state_sh,
        batch_sharding=batch_sh,
        init_fn=jit_init, train_step=jit_step, train_round=jit_round,
        round_batch_struct=round_batch_struct,
        round_batch_sharding=round_batch_sh)


def _state_spec(state_struct, pspec):
    """Optimizer-state specs: per-element trees (momentum, CPD's x̂,
    MT's tracking c / ĝ_prev, QG's xprev) mirror params; step replicated."""
    def build(struct, like):
        out = {}
        for k, v in struct.items():
            if k == "step":
                out[k] = P()
            elif k in ("m", "xhat", "c", "g_prev", "xprev"):
                out[k] = like
            elif k == "xhat_nbrs":
                out[k] = {kk: like for kk in v}
            elif k == "mix":
                # DelayedMixState (overlap=True): in-flight payload trees
                # (buf, MT's buf_c) mirror params; the staleness phase is a
                # replicated scalar
                out[k] = {kk: (P() if kk == "phase" else like)
                          for kk in v}
            else:
                raise KeyError(k)
        return out

    return build(state_struct, pspec)


# --------------------------------------------------------------------------- serve
@dataclasses.dataclass
class ServePack:
    model: object
    layout: Layout
    params_struct: object
    cache_struct: object
    pre_struct: object
    params_sharding: object
    cache_sharding: object
    prefill_step: Callable
    decode_step: Callable
    batch: int
    max_len: int


def build_serve(run: RunCfg, mesh, shape: InputShape,
                model_cfg: Optional[ModelCfg] = None) -> ServePack:
    mcfg = model_cfg or run.model
    layout = make_layout(run.parallel, mesh, serving=True)
    model = make_model(mcfg, shd=make_shd(layout, run.parallel))
    b, s = shape.global_batch, shape.seq_len

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_struct = jax.eval_shape(
        functools.partial(model.init_cache, b, s))
    pspec = param_spec_tree(params_struct, layout, stacked_worker=False)
    cspec = cache_spec_tree(cache_struct, layout, b)
    params_sh = to_shardings(pspec, mesh)
    cache_sh = to_shardings(cspec, mesh)

    from repro.configs.shapes import _batch_struct
    pre_struct = _batch_struct(mcfg, b, s, with_labels=False)
    pre_spec = {k: P(layout.batch_axes or None,
                     *([None] * (len(v.shape) - 1)))
                for k, v in pre_struct.items()}
    if b % max(1, math.prod(layout.axis_size(a)
                            for a in layout.batch_axes)) != 0:
        pre_spec = {k: P(*([None] * len(v.shape)))
                    for k, v in pre_struct.items()}
    pre_sh = to_shardings(pre_spec, mesh)

    def prefill_step(params, batch):
        return model.prefill_fast(params, batch, max_len=s)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos,
                                 max_positions=s)

    tok_spec = P(layout.batch_axes or None)
    if b % max(1, math.prod(layout.axis_size(a)
                            for a in layout.batch_axes)) != 0:
        tok_spec = P()
    tok_sh = NamedSharding(mesh, tok_spec)
    scalar_sh = NamedSharding(mesh, P())

    jit_prefill = jax.jit(prefill_step,
                          in_shardings=(params_sh, pre_sh),
                          out_shardings=(None, cache_sh))
    jit_decode = jax.jit(decode_step,
                         in_shardings=(params_sh, cache_sh, tok_sh,
                                       scalar_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))

    return ServePack(
        model=model, layout=layout,
        params_struct=params_struct, cache_struct=cache_struct,
        pre_struct=pre_struct,
        params_sharding=params_sh, cache_sharding=cache_sh,
        prefill_step=jit_prefill, decode_step=jit_decode,
        batch=b, max_len=s)
