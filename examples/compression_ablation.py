"""Ablation: compression operator × consensus step γ × topology.

Beyond the paper's sign-only experiments: how Q's contraction δ and the
topology's spectral gap ρ trade off against bytes on the wire — the
quantities Corollary 2 couples through α = ρ²δ/82.  Every operator ships
its real wire-codec payload (``repro.core.wire``), so the comm-MB column
is the exact bytes a sharded run would move, not a model.

  PYTHONPATH=src python examples/compression_ablation.py

CI runs this as a smoke job with ``ABLATION_STEPS=8`` (trimmed steps —
same code path, just short).
"""
import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core import (CPDSGDM, CPDSGDMConfig, IdentityCompressor,
                        QSGDCompressor, RandKCompressor, SignCompressor,
                        TopKCompressor)
from repro.core.gossip import DenseComm
from repro.core.topology import exponential, ring, torus
from repro.data.synthetic import LMStreamCfg, lm_batch
from repro.models import make_model
from repro.train.trainer import SimTrainer

K = 8
STEPS = int(os.environ.get("ABLATION_STEPS", "50"))
model = make_model(ModelCfg(name="t", arch_type="dense", n_layers=2,
                            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                            vocab=256))
params0 = jax.vmap(lambda _: model.init(jax.random.PRNGKey(0)))(
    jnp.arange(K))
data = LMStreamCfg(vocab=256, seq_len=32, batch=4, n_workers=K)

print(f"{'compressor':<14}{'topology':<13}{'gamma':>6}{'rho':>7}"
      f"{'final loss':>12}{'comm MB':>9}")
for comp, gamma in [(IdentityCompressor(), 0.4),
                    (SignCompressor(), 0.4),
                    (QSGDCompressor(levels=7), 0.4),
                    (TopKCompressor(fraction=0.1), 0.15),
                    (RandKCompressor(fraction=0.1), 0.1)]:
    for topo in [ring(K), exponential(K)]:
        opt = CPDSGDM(CPDSGDMConfig(eta=0.3, mu=0.9, p=4, gamma=gamma),
                      DenseComm(topo), comp)
        # fused rounds: each jitted call scans p local steps + one gossip
        trainer = SimTrainer(lambda p, b: model.loss(p, b), opt)
        _, _, h = trainer.train(params0, lambda t: lm_batch(data, t),
                                STEPS, log_every=max(STEPS - 1, 1))
        print(f"{comp.name:<14}{topo.name:<13}{gamma:>6.2f}{topo.rho:>7.3f}"
              f"{h.loss[-1]:>12.4f}{h.comm_mb[-1]:>9.2f}")
