"""jaxpr-level round-contract checks: structural invariants on traces.

The fused round (``PDSGDM.round`` / ``kernel_round`` / the runtime's
``train_round``) promises: p local steps inside one ``lax.scan``, exactly
one gossip exchange at the round boundary, no host callbacks, no float64
operands (``core.topology``'s f64 spectral math must stay on the host), a
single flatten at the kernel-path boundary, and — under a topology
schedule — one ``lax.switch`` whose branch count is the schedule period.
Every check here walks a ``jax.make_jaxpr`` trace; nothing executes.

All checks return a list of human-readable violation strings (empty =
contract holds) so the CLI driver can aggregate across the optimizer ×
backend × codec grid; ``require`` turns them into an exception for tests.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ContractViolation", "require", "iter_eqns", "collective_eqns",
           "check_no_host_callbacks", "check_no_f64", "check_round_scan",
           "check_gossip_boundary", "check_overlap_boundary",
           "check_schedule_switch",
           "check_kernel_flatten_once", "check_membership_mask",
           "traced_mixing_matrix", "trace_round", "check_round_contract"]

# primitives that move data across workers inside shard_map.  (GSPMD-domain
# collectives never appear in a jaxpr — XLA inserts them at partitioning —
# so any collective eqn here is an explicit gossip/exchange op.)
COLLECTIVE_PRIMS = frozenset({
    "ppermute", "pshuffle", "psum", "pmax", "pmin", "pmean", "all_gather",
    "all_to_all", "reduce_scatter", "psum_scatter",
})
# host-callback primitives: a round containing one cannot be async-dispatched
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
})


class ContractViolation(AssertionError):
    """One or more round-contract checks failed."""

    def __init__(self, violations: List[str]):
        self.violations = list(violations)
        super().__init__("\n".join(self.violations))


def require(violations: List[str]) -> None:
    """Raise :class:`ContractViolation` unless ``violations`` is empty."""
    if violations:
        raise ContractViolation(violations)


# --------------------------------------------------------------------- walking
def _sub_jaxprs(eqn):
    """The jaxprs nested in an eqn's params (scan/cond/pjit/shard_map/...)."""
    for v in eqn.params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if hasattr(x, "jaxpr"):      # ClosedJaxpr
                yield x.jaxpr
            elif hasattr(x, "eqns"):     # raw Jaxpr
                yield x


def iter_eqns(jaxpr, _scan_depth: int = 0):
    """Yield ``(eqn, scan_depth)`` for every eqn, recursing into sub-jaxprs.

    ``scan_depth`` counts enclosing ``scan`` bodies — the round contract
    distinguishes "inside the p-step scan" from "at the round boundary".
    """
    for eqn in jaxpr.eqns:
        yield eqn, _scan_depth
        inner = _scan_depth + (1 if eqn.primitive.name == "scan" else 0)
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, inner)


def _closed(fn_or_jaxpr):
    return getattr(fn_or_jaxpr, "jaxpr", fn_or_jaxpr)


def _where(eqn) -> str:
    """Best-effort user source location of an eqn."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return f"{frame.file_name}:{frame.start_line}"
    except Exception:
        pass
    return "<unknown>"


def collective_eqns(jaxpr) -> List[Tuple[object, int]]:
    """All cross-worker collective eqns with their scan depth."""
    return [(eqn, d) for eqn, d in iter_eqns(_closed(jaxpr))
            if eqn.primitive.name in COLLECTIVE_PRIMS]


# ---------------------------------------------------------------------- checks
def check_no_host_callbacks(jaxpr) -> List[str]:
    """Zero host callbacks anywhere in the round (a callback in the scan
    body forces a device→host sync every local step)."""
    out = []
    for eqn, depth in iter_eqns(_closed(jaxpr)):
        if eqn.primitive.name in CALLBACK_PRIMS:
            out.append(f"host callback `{eqn.primitive.name}` in the round "
                       f"(scan depth {depth}) at {_where(eqn)}")
    return out


def check_no_f64(jaxpr) -> List[str]:
    """Zero float64 operands or outputs in the traced round.

    Trace the round under ``jax_enable_x64`` before calling this: the
    default config silently truncates f64 leaks (e.g. a numpy float64
    mixing weight, or an ambient-precision python scalar) to f32, hiding
    the bug until someone flips x64 on — tracing with x64 enabled makes
    the leak visible as a genuine f64 aval.
    """
    out = []
    for eqn, _ in iter_eqns(_closed(jaxpr)):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and dtype == jnp.float64:
                out.append(f"float64 operand {aval.str_short()} in "
                           f"`{eqn.primitive.name}` at {_where(eqn)}")
                break
    return out


def check_round_scan(jaxpr, p: int) -> List[str]:
    """Exactly one top-level ``lax.scan`` of length p (the fused local
    loop) — no per-step python dispatch, no nested accidental scans of p."""
    closed = _closed(jaxpr)
    tops = []

    def top_scans(jxp):
        # descend through non-scan wrappers (pjit/shard_map/cond) so the
        # "top level" is the round body regardless of jit nesting; pallas
        # internals (interpret-mode grid loops) are not round structure
        for eqn in jxp.eqns:
            if eqn.primitive.name == "scan":
                tops.append(eqn)
            elif "pallas" not in eqn.primitive.name:
                for sub in _sub_jaxprs(eqn):
                    top_scans(sub)

    top_scans(closed)
    lengths = [int(e.params.get("length", -1)) for e in tops]
    if lengths.count(p) != 1:
        return [f"expected exactly one round scan of length p={p}, found "
                f"scan lengths {lengths or 'none'}"]
    return []


def check_gossip_boundary(jaxpr, *, expected: Optional[int] = None,
                          allowed=("ppermute", "pmean", "psum")) -> List[str]:
    """Every collective sits at the round boundary (scan depth 0) — the
    paper's one-exchange-per-round structure — and only expected kinds
    appear.  ``expected`` additionally pins the exact ppermute count
    (degree × wire arrays per exchange)."""
    out = []
    colls = collective_eqns(jaxpr)
    for eqn, depth in colls:
        if depth > 0:
            out.append(f"collective `{eqn.primitive.name}` inside the round "
                       f"scan (depth {depth}) at {_where(eqn)} — gossip must "
                       "happen once at the round boundary")
        if eqn.primitive.name not in allowed:
            out.append(f"unexpected collective `{eqn.primitive.name}` at "
                       f"{_where(eqn)} (allowed: {sorted(allowed)})")
    if expected is not None:
        n_perm = sum(1 for eqn, _ in colls
                     if eqn.primitive.name == "ppermute")
        if n_perm != expected:
            out.append(f"expected {expected} ppermute(s) per round, "
                       f"found {n_perm}")
    return out


def check_overlap_boundary(jaxpr, *, p: int,
                           expected: Optional[int] = None,
                           allowed=("ppermute", "pmean", "psum")) -> List[str]:
    """Overlapped-round contract: every collective is *issued before* the
    p-step local scan — in program order the exchange precedes the first
    scan of length p, proving the stale payload has no data dependence on
    the round's local steps (the transfer can hide behind compute).  As
    in the sync contract, collectives must sit at scan depth 0, only
    expected kinds appear, and ``expected`` pins the ppermute count (the
    wire is byte-identical to a sync round — only its timing moves)."""
    out = []
    seen_scan = False
    n_perm = 0
    for eqn, depth in iter_eqns(_closed(jaxpr)):
        name = eqn.primitive.name
        if name == "scan" and int(eqn.params.get("length", -1)) == p:
            seen_scan = True
        if name not in COLLECTIVE_PRIMS:
            continue
        if depth > 0:
            out.append(f"collective `{name}` inside the round scan (depth "
                       f"{depth}) at {_where(eqn)} — overlap gossip must be "
                       "issued once at the round start")
        elif seen_scan:
            out.append(f"collective `{name}` after the local scan at "
                       f"{_where(eqn)} — overlap requires every exchange "
                       "issued before the p-step scan (scan-independent "
                       "payload)")
        if name not in allowed:
            out.append(f"unexpected collective `{name}` at {_where(eqn)} "
                       f"(allowed: {sorted(allowed)})")
        if name == "ppermute":
            n_perm += 1
    if expected is not None and n_perm != expected:
        out.append(f"expected {expected} ppermute(s) per overlap round, "
                   f"found {n_perm}")
    return out


def traced_mixing_matrix(comm, r: int):
    """The (K, K) matrix the dense round-``r`` gossip *actually applies*,
    extracted by pushing identity probe leaves through ``comm.mix`` —
    reading the executed computation, not the backend's weight tables, so
    a table/trace mismatch is visible."""
    import numpy as np
    K = comm.topology_at(r).n_workers
    probe = {"e": jnp.eye(K, dtype=jnp.float32)}
    return np.asarray(jax.jit(lambda t: comm.mix(t, r=r))(probe)["e"])


def check_membership_mask(comm, rounds=None) -> List[str]:
    """Elastic-membership mask semantics on the *traced* dense mixing.

    For every round in the membership cycle (or ``rounds``): the applied
    matrix must be row-stochastic, a masked-out worker must hold exactly
    the identity row e_k (its exchange skipped, self-weight 1), and no
    active worker may read from a masked-out peer (zero dead columns) —
    a round gossiping with a dead worker is a contract violation.
    """
    import numpy as np
    ms = comm.membership
    if ms is None:
        return []
    out = []
    for r in (range(comm.round_cycle) if rounds is None else rounds):
        W = traced_mixing_matrix(comm, r)
        act = np.asarray(comm.active_at(r), dtype=bool)
        K = W.shape[0]
        bad_rows = np.flatnonzero(np.abs(W.sum(axis=1) - 1.0) > 1e-5)
        for k in bad_rows:
            out.append(f"round {r}: row {k} of the applied mixing matrix "
                       f"sums to {W[k].sum():.6f}, not 1 (renormalization "
                       "over live peers broken)")
        for k in np.flatnonzero(~act):
            if np.abs(W[k] - np.eye(K)[k]).max() > 1e-6:
                out.append(f"round {r}: masked-out worker {k} still "
                           "gossips (row != e_k)")
        dead_cols = W[np.ix_(act, ~act)]
        if dead_cols.size and np.abs(dead_cols).max() > 1e-6:
            i, j = np.unravel_index(np.abs(dead_cols).argmax(),
                                    dead_cols.shape)
            src = np.flatnonzero(~act)[j]
            dst = np.flatnonzero(act)[i]
            out.append(f"round {r}: active worker {dst} reads weight "
                       f"{dead_cols[i, j]:.6f} from masked-out worker "
                       f"{src} (dead column must be zero)")
    return out


def check_dense_no_collectives(jaxpr) -> List[str]:
    """The DenseComm simulation backend must trace to zero collectives —
    its gossip is a W-matmul over the stacked worker dim."""
    return [f"collective `{eqn.primitive.name}` in a DenseComm round at "
            f"{_where(eqn)}" for eqn, _ in collective_eqns(jaxpr)]


def check_schedule_switch(jaxpr, period: int) -> List[str]:
    """Under a topology schedule the per-round ppermute program is selected
    by one ``lax.switch`` whose branch count equals the schedule period —
    all T collective patterns live in a single trace (no retracing)."""
    branch_counts = [len(eqn.params["branches"])
                     for eqn, _ in iter_eqns(_closed(jaxpr))
                     if eqn.primitive.name == "cond"
                     and len(eqn.params.get("branches", ())) > 2]
    if period <= 2:
        return []     # a 2-branch switch is indistinguishable from lax.cond
    if period not in branch_counts:
        return [f"no lax.switch with {period} branches (schedule period); "
                f"found multi-way branch counts {branch_counts or 'none'}"]
    return []


def check_kernel_flatten_once(jaxpr, plan, p: int) -> List[str]:
    """The kernel path flattens the pytree into the (rows, LANE) matrix
    once at the round boundary: the round scan's carry must hold the plan
    matrix (params + every per-element state mat ride the carry in matrix
    form, not as leaf trees)."""
    from repro.kernels import LANE
    closed = _closed(jaxpr)
    scan = next((eqn for eqn, d in iter_eqns(closed)
                 if eqn.primitive.name == "scan"
                 and int(eqn.params.get("length", -1)) == p), None)
    if scan is None:
        return [f"kernel round: no scan of length p={p} found"]
    n_carry = int(scan.params.get("num_carry", 0))
    carry_avals = [v.aval for v in scan.invars[:n_carry]
                   if hasattr(v, "aval")]
    mat_shapes = [a.shape for a in carry_avals
                  if getattr(a, "ndim", 0) >= 2 and a.shape[-1] == LANE
                  and a.shape[-2] == plan.rows]
    if not mat_shapes:
        return [f"kernel round scan carry holds no (…, {plan.rows}, {LANE}) "
                "plan matrix — the flatten-once layout is not riding the "
                "scan carry"]
    return []


# ---------------------------------------------------------------- round tracing
def toy_params(n_workers: int, sizes=(1500, 96), dense: bool = True):
    """A tiny worker-stacked param tree (f32, explicit dtypes)."""
    shape = (lambda s: (n_workers, s)) if dense else (lambda s: (s,))
    return {f"w{i}": jnp.zeros(shape(s), jnp.float32)
            for i, s in enumerate(sizes)}


def toy_grads_fn(params, batch):
    """loss, grads ≡ something cheap and f32-pure for tracing."""
    loss = sum(jnp.sum(l * l) for l in jax.tree_util.tree_leaves(params))
    grads = jax.tree_util.tree_map(lambda l: l + batch.mean(), params)
    return loss.astype(jnp.float32), grads


def trace_round(opt, params, p: int, *, kernel: bool = False, x64: bool = False,
                grads_fn: Callable = toy_grads_fn):
    """``jax.make_jaxpr`` of one fused round (no execution, no devices).

    ``x64=True`` traces under ``jax_enable_x64`` so latent f64 operands
    surface as real f64 avals (see :func:`check_no_f64`).
    """
    state = opt.init(params)
    n_w = next(iter(jax.tree_util.tree_leaves(params))).shape[0]
    batches = jnp.zeros((p, n_w, 4), jnp.float32)

    def one_round(params, state, batches):
        if kernel:
            return opt.kernel_round(state, params, grads_fn, batches)
        return opt.round(state, params, grads_fn, batches)

    if x64:
        from jax.experimental import enable_x64
        ctx = enable_x64
    else:
        ctx = _null_ctx
    with ctx():
        return jax.make_jaxpr(one_round)(params, state, batches)


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# ------------------------------------------------------------------ aggregate
def check_round_contract(opt, params, *, kernel: bool = False,
                         schedule_period: Optional[int] = None,
                         expected_ppermutes: Optional[int] = None,
                         dense: bool = True,
                         overlap: bool = False) -> List[str]:
    """Run every applicable jaxpr check on one optimizer round trace.

    ``dense=True`` (the DenseComm simulation) additionally requires zero
    collectives; sharded traces (built elsewhere, inside shard_map) pass
    ``dense=False`` with an ``expected_ppermutes`` count instead.
    ``overlap=True`` swaps the boundary check for the overlapped-round
    variant: collectives precede the p-step scan instead of following it
    (dense overlap still requires zero collectives — stricter).
    """
    p = opt.config.p
    jx = trace_round(opt, params, p, kernel=kernel)
    out = []
    out += check_no_host_callbacks(jx)
    out += check_round_scan(jx, p)
    if dense:
        out += check_dense_no_collectives(jx)
    elif overlap:
        out += check_overlap_boundary(jx, p=p, expected=expected_ppermutes)
    else:
        out += check_gossip_boundary(jx, expected=expected_ppermutes)
    if schedule_period is not None:
        out += check_schedule_switch(jx, schedule_period)
    if kernel:
        from repro.kernels import ops as kops
        plan = kops.KernelPlan.for_tree(params, worker_dim=True)
        out += check_kernel_flatten_once(jx, plan, p)
    if dense and getattr(opt.comm, "membership", None) is not None:
        out += check_membership_mask(opt.comm)
    # f64 needs its own trace under the x64 config
    out += check_no_f64(trace_round(opt, params, p, kernel=kernel, x64=True))
    return out
