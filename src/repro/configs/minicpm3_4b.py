"""minicpm3-4b — MiniCPM3 [hf:openbmb/MiniCPM3-4B].

62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448, with MLA
(multi-head latent attention): q_lora 768, kv_lora 256, qk nope/rope 64/32,
v_head 64 — the compressed-KV-cache attention of DeepSeek-V2 lineage.
"""
from repro.configs.base import LayerSpec, ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="minicpm3-4b", arch_type="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=6400, vocab=73448,
        use_mla=True, q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        pattern=(LayerSpec("mla", "dense"),),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="hf:openbmb/MiniCPM3-4B",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="A"),
                  optim=OptimCfg())
