"""Compiled-HLO round-contract checks (AOT lowering, nothing executed).

Three invariants on the sharded ``TrainPack.train_round`` executable:

* **donation honored** — every ``donate_argnums`` entry must appear in the
  module's ``input_output_alias`` map (an unhonored donation silently
  doubles the parameter+state memory footprint);
* **collective allowlist** — the only substantive collectives are the
  gossip's ``collective-permute`` set; a stray all-gather / all-reduce is
  exactly the silent regression that erases the periodic-communication
  advantage (tiny scalar all-reduces — the loss mean — are exempt);
* **accounted ≡ shipped** — per-round ``collective-permute`` wire bytes
  parsed from HLO must equal ``opt.bytes_per_comm_round`` for the codec,
  a compile-time re-proof of the wire-codec byte accounting.

All checks take HLO text (``lowered.compile().as_text()``) so they run in
interpret mode on CPU with forced host devices — no accelerator needed.
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import jax

from repro.analysis.hlo_parse import (CollectiveStats, donated_aliases,
                                      parse_collectives)

__all__ = ["compile_round_text", "check_donation",
           "check_collectives_allowed", "check_wire_bytes",
           "check_hier_wire_bytes", "check_sharded_round"]

# an all-reduce at or below this payload is bookkeeping (the scalar loss
# mean over workers), not gossip traffic
SCALAR_ALLREDUCE_BYTES = 256


def compile_round_text(pack) -> str:
    """AOT-compile the canonical hot path and return the optimized HLO."""
    lowered = pack.train_round.lower(pack.params_struct, pack.state_struct,
                                     pack.round_batch_struct)
    return lowered.compile().as_text()


def check_donation(hlo_text: str, n_donated: int) -> List[str]:
    """``donate_argnums`` must materialize as input/output aliases.

    ``n_donated`` is the number of donated *buffers* (flattened leaves of
    the donated argnums).  XLA may legitimately skip aliasing a buffer
    whose shape/dtype cannot match any output, so the check requires the
    alias map to cover at least 90% of the donated set — an empty or
    near-empty map means the donation was dropped altogether.
    """
    aliases = donated_aliases(hlo_text)
    if n_donated == 0:
        return []
    if len(aliases) == 0:
        return ["donation dropped: input_output_alias is empty but "
                f"{n_donated} buffer(s) were donated"]
    if len(aliases) < 0.9 * n_donated:
        return [f"donation partially honored: {len(aliases)} aliased "
                f"buffer(s) out of {n_donated} donated"]
    return []


def check_collectives_allowed(
        stats: CollectiveStats,
        allowed: Iterable[str] = ("collective-permute",),
        scalar_allreduce_ok: bool = True,
        node_allreduce_group: Optional[int] = None) -> List[str]:
    """No collectives beyond the expected gossip set.

    ``allowed`` ops pass unconditionally; an ``all-reduce`` whose payload
    is ≤ ``SCALAR_ALLREDUCE_BYTES`` passes when ``scalar_allreduce_ok``
    (the per-round loss mean).  On a hierarchical round,
    ``node_allreduce_group`` additionally admits all-reduces whose replica
    group is exactly one node (the intra-node exact average) — a
    substantive all-reduce over any *other* group size is still a
    violation (psum inside the node, ppermute between nodes, nothing
    else).  Everything else is a contract violation.
    """
    allowed = set(allowed)
    out = []
    for call in stats.calls:
        if call.op in allowed:
            continue
        if (scalar_allreduce_ok and call.op == "all-reduce"
                and call.result_bytes <= SCALAR_ALLREDUCE_BYTES):
            continue
        if (node_allreduce_group is not None and call.op == "all-reduce"
                and call.group == int(node_allreduce_group)):
            continue
        out.append(f"unexpected collective in the round: {call.op} "
                   f"({call.result_bytes} B payload, group {call.group}) — "
                   f"{call.line[:120]}")
    return out


def check_wire_bytes(stats: CollectiveStats, expected: int,
                     label: str = "") -> List[str]:
    """collective-permute bytes per device ≡ ``bytes_per_comm_round``.

    Only valid on a mesh where one device is one worker (TP=1): with model
    parallelism each worker's wire bytes are split across its TP shards
    and the per-device total no longer equals the per-worker accounting.
    """
    got = int(stats.wire_bytes.get("collective-permute", 0))
    if got != int(expected):
        who = f" [{label}]" if label else ""
        return [f"wire bytes{who}: HLO ships {got} B/device/round but "
                f"bytes_per_comm_round accounts {int(expected)} B"]
    return []


def check_hier_wire_bytes(stats: CollectiveStats, levels: dict,
                          *, node_size: int, check_intra: bool = True,
                          label: str = "") -> List[str]:
    """Per-level accounted ≡ shipped on a two-level round.

    * inter level: ``collective-permute`` operand bytes per device must
      equal ``levels["inter_site"]`` (the op-site payload — on the
      leader-pruned layout every device runs the op, non-leaders shipping
      zeros, so the HLO accounting is payload × inter-degree regardless
      of amortization);
    * intra level: when ``check_intra``, the summed ring-effective wire
      bytes of every node-group all-reduce must equal
      ``levels["intra_wire"]`` (tree path only — the kernel layout's
      intra average covers lane-padded rows, inflating the op beyond the
      accounted leaf bytes).
    """
    who = f" [{label}]" if label else ""
    out = []
    got_cp = int(stats.wire_bytes.get("collective-permute", 0))
    if got_cp != int(levels["inter_site"]):
        out.append(f"hier inter wire{who}: HLO ships {got_cp} B/device of "
                   f"collective-permute but the level accounting expects "
                   f"{int(levels['inter_site'])} B")
    if check_intra:
        # every node-group all-reduce is intra traffic, including the tiny
        # norm-scale leaves (the scalar loss mean has a full-axis group and
        # never lands here)
        got_ar = sum(c.wire_bytes * c.mult for c in stats.calls
                     if c.op == "all-reduce" and c.group == int(node_size))
        if abs(got_ar - float(levels["intra_wire"])) > 1.0:
            out.append(f"hier intra wire{who}: HLO ships {got_ar:.0f} "
                       f"B/device of node-group all-reduce but the level "
                       f"accounting expects {float(levels['intra_wire']):.0f} B")
    return out


def _count_donated_leaves(pack) -> int:
    return sum(len(jax.tree_util.tree_leaves(t))
               for t in (pack.params_struct, pack.state_struct))


def check_sharded_round(pack, *, check_bytes: bool = True,
                        expected_wire_bytes: Optional[int] = None,
                        label: str = "") -> List[str]:
    """All HLO checks on one built ``TrainPack`` (donation + allowlist +
    accounted≡shipped).  ``check_bytes=False`` skips the byte equality —
    required on meshes with model parallelism (see :func:`check_wire_bytes`).
    """
    txt = compile_round_text(pack)
    stats = parse_collectives(txt)
    out = []
    out += check_donation(txt, _count_donated_leaves(pack))
    top = pack.opt.comm.topology_at(0)
    hier = (top.name == "hierarchical"
            and getattr(pack.opt.comm, "membership", None) is None)
    per_worker = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
        pack.params_struct)
    if hier:
        # two-level contract: psum inside the node, ppermute between nodes
        node_size = int(top.axis_sizes[1])
        out += check_collectives_allowed(
            stats, node_allreduce_group=node_size)
        if check_bytes:
            levels = pack.opt.hier_bytes_per_level(per_worker)
            out += check_hier_wire_bytes(
                stats, levels, node_size=node_size,
                check_intra=not pack.opt.config.use_kernel, label=label)
        return out
    out += check_collectives_allowed(stats)
    if check_bytes:
        if expected_wire_bytes is None:
            # params_struct is worker-stacked; the wire ships one worker's
            # leaves per device, so the accounting runs on the unstacked tree
            expected_wire_bytes = pack.opt.bytes_per_comm_round(per_worker)
        out += check_wire_bytes(stats, expected_wire_bytes, label=label)
    return out
