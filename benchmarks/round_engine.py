"""Round-engine micro-benchmark: per-step dispatch vs fused-round scan.

The *per-step* driver is the seed implementation: one jitted step per
Python iteration (the gossip hidden behind a traced ``lax.cond``) and a
host sync on the loss every step.  The *fused* driver is the round engine
the trainers now use: one jitted ``lax.scan`` over whole rounds with a
single host sync per log block.  The model is deliberately small so
dispatch/sync overhead — the thing the round engine removes — dominates.

Derived: steps/sec for both drivers and the fused/per-step speedup at each
communication period p, plus a time-varying-topology variant (one-peer
exponential schedule) that must run the same fused path at the same rate —
the per-round W is selected *inside* the jitted scan, so the schedule may
not add dispatch overhead.

The ``overlap`` section times the communication-hiding round contract:
at p ≥ 4 the overlapped fused round must run at ≈ the local-compute-only
rate (``gossip=False`` on the same driver), because the one-round-stale
exchange is issued once per round off the scan's critical path.  On this
CPU simulation the stale W-matmul is the entire comm cost, so the parity
ratio is the structural floor — on a real interconnect the hidden term is
the transfer latency itself.  The claim row
``round_engine/claim_overlap_hiding`` carries ``overlap_local_parity``
(min over p of overlap/local steps-per-sec), gated by
``tools/bench_compare.py`` against the committed
``BENCH_round_engine.json``.  ``ROUND_STEPS`` trims the grid for CI.
"""
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import make_optimizer
from repro.core.gossip import DenseComm
from repro.core.topology import one_peer_exponential_schedule, ring
from repro.train.trainer import SimTrainer

K, D, REPEATS = 8, 64, 3
STEPS = int(os.environ.get("ROUND_STEPS", "512"))


def loss_fn(params, batch):
    h = jnp.tanh(batch @ params["w1"])
    return 0.5 * jnp.mean((h @ params["w2"] - batch) ** 2), {}


def stacked_params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    one = {"w1": jax.random.normal(k1, (D, D)) * 0.1,
           "w2": jax.random.normal(k2, (D, D)) * 0.1}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), one)


_BATCHES = None


def batch_fn(t):
    return _BATCHES[t]


def _precompute_batches(steps):
    """Host-side batch generation stays outside the clock for both drivers."""
    global _BATCHES
    _BATCHES = [
        jax.device_put(jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(5), t), (K, 4, D)))
        for t in range(steps)]
    jax.block_until_ready(_BATCHES)


def _best_of(run, steps):
    """Compile on the first call, then report the best of REPEATS — the
    shared-CPU container is noisy and we want the dispatch floor."""
    run()
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return steps / best


def _time_per_step(opt, steps=STEPS):
    """Seed-style loop: jitted opt.step per iteration + float(loss) sync."""
    grad = jax.vmap(jax.value_and_grad(lambda p, b: loss_fn(p, b)[0]))

    def step_fn(state, params, batch):
        losses, grads = grad(params, batch)
        params, state = opt.step(state, params, grads)
        return params, state, losses.mean()

    stepj = jax.jit(step_fn)

    def run():
        params = stacked_params()
        state = opt.init(params)
        for t in range(steps):
            params, state, loss = stepj(state, params, batch_fn(t))
            float(loss)                        # the per-step host sync
    return _best_of(run, steps)


def _time_fused(opt, steps=STEPS):
    """Round engine: SimTrainer block scan, one host sync per log block."""
    trainer = SimTrainer(loss_fn, opt)

    def run():
        trainer.train(stacked_params(), batch_fn, steps, log_every=steps,
                      verbose=False)
    return _best_of(run, steps)


def _time_round_driver(opt, gossip=True, steps=None):
    """One jitted scan over whole rounds of ``opt.round`` — the identical
    driver for the sync, overlap and local-compute-only (``gossip=False``)
    variants, so their ratio isolates the round-boundary cost."""
    steps = steps or STEPS
    grad = jax.vmap(jax.value_and_grad(lambda p_, b: loss_fn(p_, b)[0]))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    p = opt.config.p
    rounds = steps // p
    batches = jnp.stack([
        jnp.stack([_BATCHES[r * p + i] for i in range(p)])
        for r in range(rounds)])           # (rounds, p, K, 4, D)

    @jax.jit
    def run_all(params, state, batches):
        def body(carry, rb):
            params, state = carry
            params, state, losses = opt.round(state, params, grads_fn, rb,
                                              gossip=gossip)
            return (params, state), losses.mean()
        (params, state), losses = jax.lax.scan(body, (params, state),
                                               batches)
        return params, state, losses

    def run():
        params = stacked_params()
        state = opt.init(params)
        jax.block_until_ready(run_all(params, state, batches))
    return _best_of(run, rounds * p)


def overlap_section(results):
    """Overlap ≈ local-compute parity at p ≥ 4 (the hiding claim)."""
    parities = {}
    for p in [4, 8]:
        opt_sync = make_optimizer("pd_sgdm", DenseComm(ring(K)), eta=0.05,
                                  mu=0.9, p=p)
        opt_ov = make_optimizer("pd_sgdm", DenseComm(ring(K)), eta=0.05,
                                mu=0.9, p=p, overlap=True)
        local = _time_round_driver(opt_sync, gossip=False)
        sync = _time_round_driver(opt_sync)
        overlap = _time_round_driver(opt_ov)
        parities[p] = overlap / local
        results[f"overlap_{p}"] = (local, sync, overlap)
        csv_row(f"round_engine/overlap_round_p{p}", 1e6 / overlap,
                f"steps_per_s={overlap:.1f};"
                f"vs_local_compute={overlap / local:.2f};"
                f"vs_sync_round={overlap / sync:.2f}")
    csv_row("round_engine/claim_overlap_hiding", 0.0,
            f"overlap_local_parity={min(parities.values()):.2f};"
            f"ps={'+'.join(str(p) for p in parities)}")


def main():
    results = {}
    _precompute_batches(STEPS)
    for p in [1, 4, 8, 16]:
        opt = make_optimizer("pd_sgdm", DenseComm(ring(K)), eta=0.05,
                             mu=0.9, p=p)
        per_step = _time_per_step(opt)
        fused = _time_fused(opt)
        speedup = fused / per_step
        results[p] = (per_step, fused, speedup)
        csv_row(f"round_engine/per_step_p{p}", 1e6 / per_step,
                f"steps_per_s={per_step:.1f}")
        csv_row(f"round_engine/fused_round_p{p}", 1e6 / fused,
                f"steps_per_s={fused:.1f};speedup_vs_per_step={speedup:.2f}")
    best = max(v[2] for pp, v in results.items() if pp >= 4)
    csv_row("round_engine/max_speedup_p_ge_4", 0.0, f"speedup={best:.2f}")

    # scheduled topology through the identical fused path: round-indexed
    # (T, K, K) weight select inside the scan, no retrace, no extra dispatch
    opt_sched = make_optimizer(
        "pd_sgdm", DenseComm(one_peer_exponential_schedule(K)),
        eta=0.05, mu=0.9, p=4)
    fused_sched = _time_fused(opt_sched)
    static_fused = results[4][1]
    ratio = fused_sched / static_fused
    csv_row("round_engine/fused_round_sched_p4", 1e6 / fused_sched,
            f"steps_per_s={fused_sched:.1f};vs_static_ring={ratio:.2f}")
    results["sched"] = (None, fused_sched, ratio)

    overlap_section(results)
    return results


def _write_json() -> str:
    """Standalone runs commit their own baseline (the overlap-hiding claim
    row is the bench_compare gate)."""
    from benchmarks.common import collected_rows
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_round_engine.json")
    rows = [r for r in collected_rows()
            if r["name"].startswith("round_engine/")]
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": ["round"],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "steps": STEPS,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
    print(f"bench_json,0.0,path={os.path.relpath(_write_json())}")
