"""Core contribution: PD-SGDM / CPD-SGDM decentralized optimizers.

Public API::

    from repro.core import (topology, make_compressor, DenseComm, ShardedComm,
                            PDSGDM, PDSGDMConfig, CPDSGDM, CPDSGDMConfig,
                            make_optimizer)
"""
from repro.core import schedules, topology, wire
from repro.core.baselines import CSGDM, choco_sgd, d_sgd, make_optimizer, pd_sgd
from repro.core.compression import (Compressor, IdentityCompressor,
                                    QSGDCompressor, RandKCompressor,
                                    SignCompressor, TopKCompressor,
                                    contraction_ratio, make_compressor)
from repro.core.cpdsgdm import CPDSGDM, CPDSGDMConfig
from repro.core.gossip import (CommBackend, DenseComm, HierarchicalComm,
                               ShardedComm, hier_bytes_per_round)
from repro.core.pdsgdm import PDSGDM, PDSGDMConfig
from repro.core.topology import (MembershipSchedule, Topology,
                                 TopologySchedule, full_membership,
                                 hierarchical, hierarchical_schedule,
                                 make_schedule, make_topology,
                                 membership_from_events, spectral_gap)
from repro.core.tracking import (MTDSGDMConfig, MTDSGDm, QGDSGDMConfig,
                                 QGDSGDm)
from repro.core.wire import WireCodec, make_codec

__all__ = [
    "topology", "schedules", "wire",
    "Topology", "TopologySchedule", "make_topology", "make_schedule",
    "spectral_gap", "hierarchical", "hierarchical_schedule",
    "MembershipSchedule", "full_membership", "membership_from_events",
    "Compressor", "IdentityCompressor", "SignCompressor", "TopKCompressor",
    "RandKCompressor", "QSGDCompressor", "make_compressor", "contraction_ratio",
    "WireCodec", "make_codec",
    "CommBackend", "DenseComm", "ShardedComm", "HierarchicalComm",
    "hier_bytes_per_round",
    "PDSGDM", "PDSGDMConfig", "CPDSGDM", "CPDSGDMConfig",
    "MTDSGDm", "MTDSGDMConfig", "QGDSGDm", "QGDSGDMConfig",
    "CSGDM", "d_sgd", "pd_sgd", "choco_sgd", "make_optimizer",
]
