"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compression import sign_pack as _sign_pack
from repro.core.compression import sign_unpack as _sign_unpack

__all__ = ["momentum_update_ref", "sign_pack_ref", "sign_unpack_ref",
           "gossip_mix_ref"]


def momentum_update_ref(x, m, g, lr, *, mu, wd=0.0, nesterov=False):
    x = x.astype(jnp.float32)
    m = m.astype(jnp.float32)
    g = g.astype(jnp.float32) + wd * x
    m_new = mu * m + g
    d = (g + mu * m_new) if nesterov else m_new
    return x - lr * d, m_new


def sign_pack_ref(x, block: int = 1024):
    """(rows, block) → (packed (rows, block//8) u8, scales (rows,) f32)."""
    rows = x.shape[0]
    packed, scales = jax.vmap(lambda r: _sign_pack(r, block))(x)
    return packed.reshape(rows, block // 8), scales.reshape(rows)


def sign_unpack_ref(packed, scales, block: int = 1024):
    rows = packed.shape[0]
    return jax.vmap(
        lambda p, s: _sign_unpack(p.reshape(1, block // 8), s.reshape(1),
                                  block, (block,), jnp.float32, block)
    )(packed, scales.reshape(rows))


def gossip_mix_ref(tensors, weights):
    acc = jnp.zeros_like(tensors[0], dtype=jnp.float32)
    for w, t in zip(weights, tensors):
        acc = acc + jnp.float32(w) * t.astype(jnp.float32)
    return acc
