"""Batched serving loop: prefill + greedy/temperature decode."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["generate"]


def generate(model, params, prompt_tokens, max_new: int,
             temperature: float = 0.0, key=None,
             max_len: Optional[int] = None):
    """prompt_tokens: (b, s) int32 -> (b, s + max_new) int32.

    Prefill runs once over the prompt; decode is one jitted step per token.
    """
    b, s = prompt_tokens.shape
    total = max_len or (s + max_new)
    logits, cache = jax.jit(
        functools.partial(model.prefill_fast, max_len=total)
    )(params, {"tokens": prompt_tokens})

    dstep = jax.jit(functools.partial(model.decode_step, max_positions=total))
    toks = prompt_tokens
    if key is None:
        key = jax.random.PRNGKey(0)

    def sample(lg, k):
        if temperature <= 0.0:
            return lg.argmax(-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    nxt = sample(logits, key)
    for i in range(max_new):
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
        if i == max_new - 1:
            break
        key, sub = jax.random.split(key)
        logits, cache = dstep(params, cache, nxt, jnp.int32(s + i))
        nxt = sample(logits, sub)
    return toks
