"""Production mesh construction (TPU v5e pods; CPU host devices for dry-run).

Importing this module never touches jax device state — meshes are built
inside functions only.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "HW"]


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""
    PEAK_FLOPS_BF16 = 197e12        # per chip
    HBM_BW = 819e9                  # bytes/s per chip
    ICI_BW = 50e9                   # bytes/s per link
    HBM_BYTES = 16e9                # per chip
    VMEM_BYTES = 16 * 2 ** 20       # ~16 MiB per core


def make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5 explicit-axes API
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for the multi-device subprocess tests (8 host devices)."""
    if multi_pod:
        return make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return make_mesh((n_data, n_model), ("data", "model"))
