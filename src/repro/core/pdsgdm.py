"""PD-SGDM — Periodic Decentralized Momentum SGD (paper Algorithm 1).

Per worker k, per iteration t::

    m⁽ᵏ⁾ₜ   = μ m⁽ᵏ⁾ₜ₋₁ + ∇F(x⁽ᵏ⁾ₜ; ξ⁽ᵏ⁾ₜ)
    x⁽ᵏ⁾ₜ₊½ = x⁽ᵏ⁾ₜ − η m⁽ᵏ⁾ₜ
    x⁽ᵏ⁾ₜ₊₁ = Σⱼ w_kj x⁽ʲ⁾ₜ₊½      if mod(t+1, p) == 0   (gossip)
            = x⁽ᵏ⁾ₜ₊½              otherwise

The optimizer is backend-agnostic: with :class:`~repro.core.gossip.DenseComm`
leaves carry a leading worker dim (simulation / paper-faithful experiments);
with :class:`~repro.core.gossip.ShardedComm` it runs inside ``shard_map`` on
per-worker shards and gossip lowers to ``collective-permute``.

Weight decay follows the paper's experimental setup (PyTorch SGD semantics:
decay folded into the gradient before the momentum update).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.gossip import CommBackend

__all__ = ["PDSGDMConfig", "PDSGDM"]


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


@dataclasses.dataclass(frozen=True)
class PDSGDMConfig:
    eta: float = 0.1                 # step size η (peak LR if schedule given)
    mu: float = 0.9                  # momentum coefficient μ ∈ (0, 1)
    p: int = 4                       # communication period (p > 1 in paper)
    weight_decay: float = 0.0
    nesterov: bool = False           # beyond-paper option (off by default)
    lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    use_kernel: bool = False         # fused Pallas momentum update

    def lr(self, step):
        if self.lr_schedule is None:
            return jnp.asarray(self.eta, jnp.float32)
        return self.eta * self.lr_schedule(step)


class PDSGDM:
    """Algorithm 1.

    ``step = local_step ∘ maybe_communicate`` is the per-iteration form;
    ``round`` is the fused form (p local steps + one unconditional gossip in
    a single ``lax.scan``) that the trainers execute.
    """

    def __init__(self, config: PDSGDMConfig, comm: CommBackend):
        if not (0.0 <= config.mu < 1.0):
            raise ValueError("momentum μ must be in [0, 1)")
        if config.p < 1:
            raise ValueError("communication period p must be ≥ 1")
        self.config = config
        self.comm = comm

    # -- state ---------------------------------------------------------------
    def init(self, params):
        return {
            "m": _tree_map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    # -- local computation (Alg. 1 lines 2-4) ---------------------------------
    def local_step(self, state, params, grads):
        cfg = self.config
        lr = cfg.lr(state["step"]).astype(jnp.float32)
        mu = jnp.float32(cfg.mu)
        wd = jnp.float32(cfg.weight_decay)

        if cfg.use_kernel:
            from repro.kernels import ops as kops
            new_params, new_m = kops.momentum_update_tree(
                params, state["m"], grads, mu=cfg.mu, lr=lr,
                weight_decay=cfg.weight_decay, nesterov=cfg.nesterov)
        else:
            def upd(x, m, g):
                g32 = g.astype(jnp.float32) + wd * x.astype(jnp.float32)
                m_new = mu * m + g32
                d = (g32 + mu * m_new) if cfg.nesterov else m_new
                x_new = x.astype(jnp.float32) - lr * d
                return x_new.astype(x.dtype), m_new

            xs, treedef = jax.tree_util.tree_flatten(params)
            ms = treedef.flatten_up_to(state["m"])
            gs = treedef.flatten_up_to(grads)
            pairs = [upd(x, m, g) for x, m, g in zip(xs, ms, gs)]
            new_params = treedef.unflatten([x for x, _ in pairs])
            new_m = treedef.unflatten([m for _, m in pairs])

        new_state = dict(state)   # preserve subclass state (e.g. CPD's x̂)
        new_state["m"] = new_m
        new_state["step"] = state["step"] + 1
        return new_params, new_state

    # -- communication (Alg. 1 lines 5-9) --------------------------------------
    def round_index(self, state):
        """0-based index of the gossip round being applied.

        ``comm_round`` runs after the local step(s) advanced the counter to
        ``t+1 = (r+1)·p``, so ``r = step // p − 1``.  Time-varying topology
        schedules key on this — and because it is derived from the
        checkpointed step counter, resume restores the schedule phase
        bit-identically with no extra persisted cursor.
        """
        return state["step"] // self.config.p - 1

    def comm_round(self, state, params):
        """One gossip round (unconditional), with round ``r``'s topology."""
        return self.comm.mix(params, r=self.round_index(state)), state

    def is_comm_step(self, state):
        """mod(t+1, p) == 0, evaluated *after* the local step incremented t."""
        return (state["step"] % self.config.p) == 0

    def maybe_communicate(self, state, params):
        do = self.is_comm_step(state)
        params, state = jax.lax.cond(
            do,
            lambda s, p: self.comm_round(s, p),
            lambda s, p: (p, s),
            state, params)
        return params, state

    # -- full iteration ---------------------------------------------------------
    def step(self, state, params, grads):
        params, state = self.local_step(state, params, grads)
        params, state = self.maybe_communicate(state, params)
        return params, state

    # -- fused round (the canonical hot path) -----------------------------------
    def round(self, state, params, grads_fn, batches, *,
              local_step=None, comm_round=None):
        """One whole round, fused: ``lax.scan`` of p local steps then exactly
        one unconditional gossip round — no per-step ``lax.cond``, no per-step
        Python dispatch.

        ``grads_fn(params, batch) -> (loss, grads)``; ``batches`` carries a
        leading scan dim of length p.  ``local_step``/``comm_round`` default
        to the optimizer's own methods (DenseComm simulation); the sharded
        runtime passes ``shard_map``-wrapped versions so the identical scan
        structure drives both backends.

        Returns ``(params, state, losses)`` with ``losses`` stacked over the
        p local steps.
        """
        if local_step is None:
            local_step = self.local_step
        if comm_round is None:
            comm_round = self.comm_round

        def body(carry, batch):
            params, state = carry
            loss, grads = grads_fn(params, batch)
            params, state = local_step(state, params, grads)
            return (params, state), loss

        (params, state), losses = jax.lax.scan(body, (params, state), batches)
        params, state = comm_round(state, params)
        return params, state, losses

    # -- comm-cost model ----------------------------------------------------------
    def bytes_per_comm_round(self, params, r: int = 0) -> int:
        from repro.core.gossip import gossip_bytes_per_round
        return gossip_bytes_per_round(params, self.comm, r=r)

    def bytes_per_round_cycle(self, params) -> tuple:
        """Per-round bytes over one schedule cycle (1-tuple when static);
        the trainers accumulate these round-robin for comm-MB accounting."""
        return tuple(self.bytes_per_comm_round(params, r=r)
                     for r in range(self.comm.period))
