"""Fig. 2: loss/accuracy versus MB communicated.

Paper claim: larger p ⇒ less communication at the same final quality, and
CPD-SGDM needs far fewer MB than full-precision PD-SGDM per round.
Derived: MB to reach the target loss.
"""
from benchmarks.common import csv_row, make_opt, train_resnet
from repro.core import SignCompressor

TARGET = 1.2   # synthetic-CIFAR loss target reachable by all methods


def _mb_to_target(hist, target=TARGET):
    for loss, mb in zip(hist.loss, hist.comm_mb):
        if loss <= target:
            return mb
    return float("nan")


def main():
    rows = {}
    for label, opt in [
        ("pd_sgdm_p4", make_opt("pd_sgdm", p=4)),
        ("pd_sgdm_p8", make_opt("pd_sgdm", p=8)),
        ("pd_sgdm_p16", make_opt("pd_sgdm", p=16)),
        ("cpd_sgdm_p4_sign", make_opt("cpd_sgdm", p=4,
                                      compressor=SignCompressor(block=64))),
        ("cpd_sgdm_p16_sign", make_opt("cpd_sgdm", p=16,
                                       compressor=SignCompressor(block=64))),
    ]:
        # round engine: per-step losses still land in the history (one
        # device sync per log block), so mb-to-target stays step-accurate
        hist, s_per_step = train_resnet(opt, steps=60, log_every=5)
        mb = _mb_to_target(hist)
        rows[label] = (hist.comm_mb[-1], hist.loss[-1])
        csv_row(f"fig2/{label}", s_per_step * 1e6,
                f"total_mb={hist.comm_mb[-1]:.2f};"
                f"mb_to_loss{TARGET}={mb:.2f};final={hist.loss[-1]:.4f}")
    # headline: CPD p=16 uses less than PD p=16 (paper's final comparison)
    ratio = rows["cpd_sgdm_p16_sign"][0] / max(rows["pd_sgdm_p16"][0], 1e-9)
    csv_row("fig2/cpd_over_pd_bytes_ratio_p16", 0.0, f"ratio={ratio:.4f}")
    return rows


if __name__ == "__main__":
    main()
