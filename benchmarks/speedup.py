"""Linear speedup (Corollaries 1 & 2): loss after a fixed number of
iterations improves with worker count K (more data consumed per iteration),
approaching the centralized trend — the paper's O(1/√(KT)) regime.

Derived: final loss at K ∈ {1, 2, 4, 8} for PD-SGDM and CPD-SGDM.
"""
import jax.numpy as jnp

from benchmarks.common import csv_row, make_opt, train_resnet
from repro.core import SignCompressor


def main():
    rows = {}
    for opt_name in ["pd_sgdm", "cpd_sgdm"]:
        finals = {}
        for K in [1, 2, 4, 8]:
            comp = SignCompressor(block=64) if opt_name == "cpd_sgdm" else None
            opt = make_opt(opt_name, k=K, p=4, compressor=comp)
            hist, s_per_step = train_resnet(opt, k=K, steps=40)
            finals[K] = hist.loss[-1]
            csv_row(f"speedup/{opt_name}_K{K}", s_per_step * 1e6,
                    f"final_loss={hist.loss[-1]:.4f}")
        # monotone trend: more workers => lower loss at same iteration count
        monotone = all(finals[a] >= finals[b] - 0.15
                       for a, b in [(1, 4), (2, 8), (1, 8)])
        csv_row(f"speedup/{opt_name}_monotone", 0.0,
                f"K1={finals[1]:.3f};K8={finals[8]:.3f};monotone={monotone}")
        rows[opt_name] = finals
    return rows


if __name__ == "__main__":
    main()
