"""arctic-480b — Snowflake Arctic base [hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56 heads (GQA kv=8), dense d_ff 4864, vocab 32000,
MoE 128 experts top-2 *in parallel with* a dense residual FFN per layer
(Arctic's "dense-MoE hybrid" residual architecture).
"""
from repro.configs.base import LayerSpec, ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="arctic-480b", arch_type="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab=32000,
        n_experts=128, top_k=2,
        pattern=(LayerSpec("attn", "dense+moe"),),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="hf:Snowflake/snowflake-arctic-base",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="B"),
                  optim=OptimCfg())
