"""Unified decoder-only model covering all assigned architecture families.

A model is a repeating *block pattern* (``ModelCfg.pattern``) of layers, each
``LayerSpec(mixer, ffn)`` with mixer ∈ {attn, mla, mamba} and ffn ∈ {dense,
moe, dense+moe, none}.  The pattern is repeated ``n_repeats`` times and the
repeats are ``lax.scan``-ned with stacked params — this keeps the HLO size
O(pattern) instead of O(n_layers), which matters for the 80-layer configs in
the multi-pod dry-run.

Input modalities (per the assignment's stub carve-out): ``tokens`` (LM),
``embeds`` (audio: precomputed codec-frame embeddings), ``vlm`` (precomputed
patch embeddings prefix + text tokens).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelCfg
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.attention import AttnCfg
from repro.models.layers import (dense, dense_init, embed, embedding_init,
                                 layernorm, layernorm_init, mlp, mlp_init,
                                 nonparametric_layernorm, rmsnorm,
                                 rmsnorm_init, rope_freqs)
from repro.models.mamba2 import Mamba2Cfg
from repro.models.moe import MoECfg

__all__ = ["Model", "make_model"]


def _noshd(x, *names):
    return x


# ---------------------------------------------------------------------------- norms
def _norm_init(cfg: ModelCfg, dtype):
    if cfg.norm == "rmsnorm":
        return lambda: rmsnorm_init(cfg.d_model, dtype)
    if cfg.norm == "layernorm":
        return lambda: layernorm_init(cfg.d_model, dtype)
    if cfg.norm == "nonparametric":
        return lambda: {}
    raise ValueError(cfg.norm)


def _norm_apply(cfg: ModelCfg):
    if cfg.norm == "rmsnorm":
        return rmsnorm
    if cfg.norm == "layernorm":
        return layernorm
    if cfg.norm == "nonparametric":
        return lambda p, x: nonparametric_layernorm(x)
    raise ValueError(cfg.norm)


class Model:
    """Functional model: ``init``, ``apply`` (logits), ``loss``, serving ops."""

    def __init__(self, cfg: ModelCfg, shd: Callable = _noshd):
        self.cfg = cfg
        self.shd = shd
        self.param_dtype = jnp.dtype(cfg.param_dtype)
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)
        self.attn_cfg = AttnCfg(
            d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias, window=cfg.window,
            rope_theta=cfg.rope_theta,
            q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
            v_head_dim=cfg.v_head_dim)
        self.mamba_cfg = Mamba2Cfg(
            d_model=cfg.d_model, d_state=cfg.ssm_state,
            headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
            chunk=cfg.ssm_chunk, bcast_groups=cfg.ssm_bcast_groups)
        self.moe_cfg = MoECfg(
            d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            router_aux_weight=cfg.router_aux_weight, gated=cfg.gated_mlp,
            n_groups=cfg.moe_groups)

    # ------------------------------------------------------------------ init
    def _layer_init(self, key, spec: LayerSpec):
        cfg = self.cfg
        dtype = self.param_dtype
        kmix, kffn, _ = jax.random.split(key, 3)
        ninit = _norm_init(cfg, dtype)
        p: Dict = {"norm_mix": ninit()}
        if spec.mixer == "attn":
            p["attn"] = attn_lib.attention_init(kmix, self.attn_cfg, dtype)
        elif spec.mixer == "mla":
            p["attn"] = attn_lib.mla_init(kmix, self.attn_cfg, dtype)
        elif spec.mixer == "mamba":
            p["mamba"] = mamba_lib.mamba2_init(kmix, self.mamba_cfg, dtype)
        else:
            raise ValueError(spec.mixer)
        if spec.ffn != "none":
            p["norm_ffn"] = ninit()
        if spec.ffn in ("dense", "dense+moe"):
            p["mlp"] = mlp_init(kffn, cfg.d_model, cfg.d_ff, dtype,
                                gated=cfg.gated_mlp)
        if spec.ffn in ("moe", "dense+moe"):
            kmoe = jax.random.fold_in(kffn, 1)
            p["moe"] = moe_lib.moe_init(kmoe, self.moe_cfg, dtype)
        return p

    def init(self, key) -> Dict:
        cfg = self.cfg
        dtype = self.param_dtype
        kemb, khead, kblocks, knorm = jax.random.split(key, 4)
        params: Dict = {}
        params["embed"] = embedding_init(kemb, cfg.vocab, cfg.d_model, dtype)
        # stacked block params: one stack per pattern position
        blocks = {}
        for pos, spec in enumerate(cfg.pattern):
            keys = jax.random.split(
                jax.random.fold_in(kblocks, pos), cfg.n_repeats)
            blocks[f"pos{pos}"] = jax.vmap(
                partial(self._layer_init, spec=spec))(keys)
        params["blocks"] = blocks
        params["final_norm"] = _norm_init(cfg, dtype)()
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(khead, cfg.d_model, cfg.vocab, dtype)
        return params

    # ------------------------------------------------------------------ layers
    def _rope(self, max_len: int):
        return rope_freqs(self.attn_cfg.head_dim
                          if not self.cfg.use_mla else self.cfg.qk_rope_dim,
                          max_len, self.cfg.rope_theta)

    def _apply_layer(self, spec: LayerSpec, lp, x, cos, sin, positions):
        cfg = self.cfg
        nap = _norm_apply(cfg)
        h = nap(lp["norm_mix"], x)
        if spec.mixer == "attn":
            mix = attn_lib.attention_apply(lp["attn"], h, self.attn_cfg,
                                           cos, sin, positions,
                                           shd=self.shd)
        elif spec.mixer == "mla":
            mix = attn_lib.mla_apply(lp["attn"], h, self.attn_cfg,
                                     cos, sin, positions)
        else:
            mix = mamba_lib.mamba2_apply(lp["mamba"], h, self.mamba_cfg)
        x = x + mix
        aux = jnp.zeros((), jnp.float32)
        if spec.ffn == "none":
            return x, aux
        h = nap(lp["norm_ffn"], x)
        out = 0.0
        if spec.ffn in ("dense", "dense+moe"):
            out = out + mlp(lp["mlp"], h)
        if spec.ffn in ("moe", "dense+moe"):
            mo, aux = moe_lib.moe_apply(lp["moe"], h, self.moe_cfg, self.shd)
            out = out + mo
        x = self.shd(x + out, "batch", "seq", "embed")
        return x, aux

    def _block(self, x, block_params, cos, sin, positions):
        aux_total = jnp.zeros((), jnp.float32)
        for pos, spec in enumerate(self.cfg.pattern):
            x, aux = self._apply_layer(spec, block_params[f"pos{pos}"],
                                       x, cos, sin, positions)
            aux_total = aux_total + aux
        return x, aux_total

    # ------------------------------------------------------------------ embed in
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        cd = self.compute_dtype
        if cfg.input_mode == "tokens":
            x = embed(params["embed"], batch["tokens"]).astype(cd)
        elif cfg.input_mode == "embeds":
            x = batch["embeds"].astype(cd)     # stub frontend output
        elif cfg.input_mode == "vlm":
            tok = embed(params["embed"], batch["tokens"]).astype(cd)
            x = jnp.concatenate([batch["patch_embeds"].astype(cd), tok],
                                axis=1)
        else:
            raise ValueError(cfg.input_mode)
        return self.shd(x, "batch", "seq", "embed")

    # ------------------------------------------------------------------ forward
    def apply(self, params, batch, remat: str = "none"):
        """Full-sequence forward.  Returns (logits_f32, aux_loss)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        cos, sin = self._rope(s)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def block_fn(carry, block_params):
            x, aux = carry
            x, a = self._block(x, block_params, cos, sin, positions)
            return (x, aux + a), None

        if remat == "full":
            block_fn = jax.checkpoint(block_fn, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(
            block_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"])

        x = _norm_apply(cfg)(params["final_norm"], x)
        head = (params["embed"]["table"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, head,
                            preferred_element_type=jnp.float32)
        return self.shd(logits, "batch", "seq", "vocab"), aux

    def loss(self, params, batch, remat: str = "none"):
        """Next-token cross entropy over ``labels`` (-1 = masked)."""
        logits, aux = self.apply(params, batch, remat=remat)
        labels = batch["labels"]
        if self.cfg.input_mode == "vlm":
            # image-prefix positions carry no labels
            pad = jnp.full(
                (labels.shape[0], logits.shape[1] - labels.shape[1]),
                -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ serving
    def _layer_cache(self, spec: LayerSpec, batch: int, max_len: int):
        cd = self.compute_dtype
        if spec.mixer == "attn":
            return attn_lib.init_kv_cache(self.attn_cfg, batch, max_len, cd)
        if spec.mixer == "mla":
            return attn_lib.init_mla_cache(self.attn_cfg, batch, max_len, cd)
        return mamba_lib.init_mamba_cache(self.mamba_cfg, batch, cd)

    def init_cache(self, batch: int, max_len: int):
        """Stacked (over repeats) cache per pattern position."""
        out = {}
        for pos, spec in enumerate(self.cfg.pattern):
            one = self._layer_cache(spec, batch, max_len)
            out[f"pos{pos}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.cfg.n_repeats,) + a.shape), one)
        return out

    def _prefill_layer(self, spec: LayerSpec, lp, x, cos, sin, positions,
                       max_len: int):
        cfg = self.cfg
        nap = _norm_apply(cfg)
        h = nap(lp["norm_mix"], x)
        if spec.mixer == "attn":
            mix, cache = attn_lib.attention_prefill(
                lp["attn"], h, self.attn_cfg, cos, sin, max_len, positions,
                shd=self.shd)
        elif spec.mixer == "mla":
            mix, cache = attn_lib.mla_prefill(
                lp["attn"], h, self.attn_cfg, cos, sin, max_len, positions)
        else:
            mix, cache = mamba_lib.mamba2_apply(
                lp["mamba"], h, self.mamba_cfg, return_state=True)
        x = x + mix
        if spec.ffn == "none":
            return x, cache
        h = nap(lp["norm_ffn"], x)
        out = 0.0
        if spec.ffn in ("dense", "dense+moe"):
            out = out + mlp(lp["mlp"], h)
        if spec.ffn in ("moe", "dense+moe"):
            mo, _ = moe_lib.moe_apply(lp["moe"], h, self.moe_cfg, self.shd)
            out = out + mo
        return x + out, cache

    def prefill_fast(self, params, batch, max_len: Optional[int] = None):
        """One-pass prompt processing: last-token logits + populated cache.

        Unlike :meth:`prefill` (sequential, example-scale), this runs the
        normal full-sequence forward and packs each layer's K/V (or SSM
        state) into the decode-cache layout — the production prefill path.
        """
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        max_len = max_len or s
        cos, sin = self._rope(max_len)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def block_fn(x, block_params):
            caches = {}
            for pos_i, spec in enumerate(cfg.pattern):
                x, c = self._prefill_layer(
                    spec, block_params[f"pos{pos_i}"], x, cos, sin,
                    positions, max_len)
                caches[f"pos{pos_i}"] = c
            return x, caches

        x, cache = jax.lax.scan(block_fn, x, params["blocks"])
        x = _norm_apply(cfg)(params["final_norm"], x[:, -1:, :])
        head = (params["embed"]["table"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, head,
                            preferred_element_type=jnp.float32)
        return logits[:, 0, :], cache

    def _decode_layer(self, spec: LayerSpec, lp, x, cache, pos, cos, sin):
        cfg = self.cfg
        nap = _norm_apply(cfg)
        h = nap(lp["norm_mix"], x)
        if spec.mixer == "attn":
            mix, cache = attn_lib.attention_decode(
                lp["attn"], h, cache, pos, self.attn_cfg, cos, sin)
        elif spec.mixer == "mla":
            mix, cache = attn_lib.mla_decode(
                lp["attn"], h, cache, pos, self.attn_cfg, cos, sin)
        else:
            mix, cache = mamba_lib.mamba2_decode(
                lp["mamba"], h, cache, self.mamba_cfg)
        x = x + mix
        if spec.ffn == "none":
            return x, cache
        h = nap(lp["norm_ffn"], x)
        out = 0.0
        if spec.ffn in ("dense", "dense+moe"):
            out = out + mlp(lp["mlp"], h)
        if spec.ffn in ("moe", "dense+moe"):
            mo, _ = moe_lib.moe_apply(lp["moe"], h, self.moe_cfg, self.shd)
            out = out + mo
        return x + out, cache

    def decode_step(self, params, cache, tokens_or_embeds, pos,
                    max_positions: Optional[int] = None):
        """One new token for every sequence in the batch.

        ``tokens_or_embeds``: (b,) int32 tokens, or (b, 1, d) embeds.
        ``pos``: scalar int32 — current position (same for whole batch).
        ``max_positions``: static bound on positions (RoPE table size);
        defaults to the cache length — must be passed explicitly for
        sliding-window caches whose ring is shorter than the sequence.
        Returns (logits (b, vocab) f32, new cache).
        """
        cfg = self.cfg
        cd = self.compute_dtype
        if jnp.issubdtype(tokens_or_embeds.dtype, jnp.integer):
            x = embed(params["embed"], tokens_or_embeds[:, None]).astype(cd)
        else:
            x = tokens_or_embeds.astype(cd)
        max_len = max_positions or self._cache_len(cache)
        cos, sin = self._rope(max_len)

        def block_fn(x, scanned):
            block_params, blk_cache = scanned
            new_cache = {}
            for p_i, spec in enumerate(cfg.pattern):
                x, c = self._decode_layer(
                    spec, block_params[f"pos{p_i}"], x,
                    blk_cache[f"pos{p_i}"], pos, cos, sin)
                new_cache[f"pos{p_i}"] = c
            return x, new_cache

        x, new_cache = jax.lax.scan(block_fn, x, (params["blocks"], cache))
        x = _norm_apply(cfg)(params["final_norm"], x)
        head = (params["embed"]["table"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, head,
                            preferred_element_type=jnp.float32)
        return logits[:, 0, :], new_cache

    def _cache_len(self, cache) -> int:
        for pos, spec in enumerate(self.cfg.pattern):
            if spec.mixer == "attn":
                return cache[f"pos{pos}"]["k"].shape[2]
            if spec.mixer == "mla":
                return cache[f"pos{pos}"]["ckv"].shape[2]
        return 1  # pure-SSM: rope tables unused

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Run the prompt, build a cache, return last-position logits.

        Simple implementation: full forward for logits + per-layer cache
        writes via teacher-forced decode of the K/V projections.  Attention
        caches hold the prompt; SSM caches hold the final state (computed by
        stepping the recurrence — adequate for the example serving loop).
        """
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        max_len = max_len or s
        cache = self.init_cache(b, max_len)
        logits = None

        def step(i, carry):
            cache, last_logits = carry
            tok_x = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1)
            lg, cache = self._decode_embeds(params, cache, tok_x, i)
            return cache, lg

        # sequential prefill (example-scale only; training uses apply()).
        cache, logits = jax.lax.fori_loop(
            0, s, step, (cache, jnp.zeros((b, cfg.vocab), jnp.float32)))
        return logits, cache

    def _decode_embeds(self, params, cache, x, pos):
        cfg = self.cfg
        max_len = self._cache_len(cache)
        cos, sin = self._rope(max_len)

        def block_fn(x, scanned):
            block_params, blk_cache = scanned
            new_cache = {}
            for p_i, spec in enumerate(cfg.pattern):
                x, c = self._decode_layer(
                    spec, block_params[f"pos{p_i}"], x,
                    blk_cache[f"pos{p_i}"], pos, cos, sin)
                new_cache[f"pos{p_i}"] = c
            return x, new_cache

        x, new_cache = jax.lax.scan(block_fn, x, (params["blocks"], cache))
        x = _norm_apply(cfg)(params["final_norm"], x)
        head = (params["embed"]["table"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, head,
                            preferred_element_type=jnp.float32)
        return logits[:, 0, :], new_cache


def make_model(cfg: ModelCfg, shd: Callable = _noshd) -> Model:
    return Model(cfg, shd)
