"""Benchmark orchestrator — one section per paper table/figure or subsystem.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run fig1 fig3   # a subset

Sections
--------
  fig1      PD-SGDM vs C-SGDM/D-SGD/PD-SGD loss trajectories (paper Fig. 1)
  fig2      communication-cost model: bytes on the wire per method (Fig. 2)
  fig3      CPD-SGDM compressed gossip vs full precision (Fig. 3)
  speedup   steps/sec scaling over worker count K
  round     per-step dispatch vs fused-round scan (the round engine)
  toposweep static ring vs time-varying topologies at equal bytes-on-wire
  kernels   Pallas kernel microbenchmarks (interpret mode) vs jnp references
  kernel_path  per-leaf jnp round vs per-step kernel vs flatten-once fused
               round (interpret-parity layout comparison)
  wire      bytes/round and round-time per wire codec on the fused path
            (also writes its own BENCH_wire_codecs.json when standalone)
  noniid    heterogeneity sweep: Dirichlet-α × p × optimizer, judged on
            the global loss of the averaged model (MT-DSGDm vs PD-SGDM
            vs QG vs D-PSGD; standalone writes BENCH_noniid.json)
  elastic   churn sweep: survivor loss / consensus / wire bytes vs. the
            kill+straggle rate under seeded chaos scripts (standalone
            writes BENCH_elastic.json)
  pretrain  hierarchical two-level gossip vs. flat ring on the LM
            pretraining driver: analytic comm rows for the ~100M model
            plus end-to-end runs of examples/pretrain_decentralized.py
            (standalone writes BENCH_pretrain.json; env knobs
            PRETRAIN_STEPS / PRETRAIN_MODEL)
  embedding sparse embedding-row wire on the power-law (Zipf) lookup
            workload: bytes/round vs rows touched (batch sweep), flat in
            table size (table sweep), plus a fused sparse round timing
            (standalone writes BENCH_embedding.json)
  roofline  dry-run HLO analysis against TPU v5e hardware ceilings

Output formats
--------------
Human-readable: every section prints ``name,us_per_call,derived`` CSV rows
to stdout, where ``derived`` is a ``k1=v1;k2=v2`` string of
section-specific metrics (steps/sec, speedups, final losses, ...).

Machine-readable: after the selected sections run, the same rows are
written to ``benchmarks/BENCH_<tag>.json`` (tag from ``$BENCH_TAG``,
default ``latest``) so later PRs can diff perf trajectories without
scraping stdout.  Schema (version 1)::

    {
      "schema": 1,
      "created_unix": <int>,          # stamp of the run
      "sections": ["fig1", ...],      # what was executed — any subset of
                                      # SECTIONS below, kernel_path /
                                      # noniid / elastic included
      "jax": "0.4.37",                # toolchain provenance
      "backend": "cpu",               # jax.default_backend()
      "wall_s": <float>,              # total wall clock
      "rows": [                       # csv rows, structured
        {"name": "round_engine/fused_round_p4",
         "us_per_call": 123.4,
         "derived": {"steps_per_s": 8100.0, "speedup_vs_per_step": 1.5}},
        {"name": "kernel_path/speedup_p4",   # flatten-once layout win
         "us_per_call": 0.0,
         "derived": {"fused_vs_perstep_parity": 1.5, "fused_vs_jnp": 1.2}},
        {"name": "noniid/claim_alpha0.1",    # heterogeneity claim row
         "us_per_call": 0.0,
         "derived": {"mt_minus_pd_best": -0.01, "mt_le_pd": 1.0}},
        {"name": "elastic/claim_survivors",  # chaos-sweep claim row
         "us_per_call": 0.0,
         "derived": {"survivors_bounded": 1.0, "cells": 12.0}},
        {"name": "pretrain/claim_inter_reduction",  # two-level comm claim
         "us_per_call": 0.0,
         "derived": {"inter_reduction_f32": 8.0,
                     "inter_reduction_bf16": 16.0, "reduction_ok": 1.0}},
        {"name": "pretrain/claim_equal_loss",  # end-to-end LM driver claim
         "us_per_call": 0.0,
         "derived": {"hier_loss_ok": 1.0, "train_comm_reduction": 8.0}},
        {"name": "embedding/claim_bytes_scale",  # sparse-wire scaling claim
         "us_per_call": 0.0,
         "derived": {"bytes_scale_with_touched": 1.0,
                     "sparse_vs_dense_x": 99.0,
                     "bytes_flat_in_table": 1.0}},
        ...
      ]
    }

Standalone section runs also write their own committed baselines
(``BENCH_kernel_path.json``, ``BENCH_wire_codecs.json``,
``BENCH_noniid.json``, ``BENCH_elastic.json``, ``BENCH_pretrain.json``,
``BENCH_embedding.json``) which ``tools/bench_compare.py`` gates fresh
runs against.

``derived`` values parse to floats where possible; free-form fragments are
kept under ``"note"``.  Rows are append-only within a run; compare runs by
joining on ``name``.  The fused-round rows (``round_engine/*``) are the
regression gate: new execution-path work must not lower their
``steps_per_s``.
"""
import json
import os
import sys
import time

SECTIONS = ["fig1", "fig2", "fig3", "speedup", "round", "toposweep",
            "kernels", "kernel_path", "wire", "noniid", "elastic",
            "pretrain", "embedding", "roofline"]


def _write_bench_json(sections, wall_s) -> str:
    """Persist the collected rows as benchmarks/BENCH_<tag>.json."""
    import jax

    from benchmarks.common import collected_rows
    tag = os.environ.get("BENCH_TAG", "latest")
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        f"BENCH_{tag}.json")
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": sections,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "wall_s": wall_s,
        "rows": collected_rows(),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def main() -> None:
    want = [a for a in sys.argv[1:] if a in SECTIONS] or SECTIONS
    print("name,us_per_call,derived")
    t0 = time.time()
    if "fig1" in want:
        from benchmarks import fig1_pdsgdm
        fig1_pdsgdm.main()
    if "fig2" in want:
        from benchmarks import fig2_comm_cost
        fig2_comm_cost.main()
    if "fig3" in want:
        from benchmarks import fig3_cpdsgdm
        fig3_cpdsgdm.main()
    if "speedup" in want:
        from benchmarks import speedup
        speedup.main()
    if "round" in want:
        from benchmarks import round_engine
        round_engine.main()
    if "toposweep" in want:
        from benchmarks import topology_sweep
        topology_sweep.main()
    if "kernels" in want:
        from benchmarks import kernels_micro
        kernels_micro.main()
    if "kernel_path" in want:
        from benchmarks import kernel_path
        kernel_path.main()
    if "wire" in want:
        from benchmarks import wire_codecs
        wire_codecs.main()
    if "noniid" in want:
        from benchmarks import noniid_sweep
        noniid_sweep.main()
    if "elastic" in want:
        from benchmarks import elastic_sweep
        elastic_sweep.main()
    if "pretrain" in want:
        from benchmarks import pretrain_sweep
        pretrain_sweep.main()
    if "embedding" in want:
        from benchmarks import embedding_wire
        embedding_wire.main()
    if "roofline" in want:
        from benchmarks import roofline
        roofline.main()
    wall = time.time() - t0
    path = _write_bench_json(want, wall)
    print(f"bench_json,0.0,path={os.path.relpath(path)}")
    print(f"total_wall_s,{wall*1e6:.0f},sections={want}")


if __name__ == '__main__':
    main()
