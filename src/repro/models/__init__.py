"""Model zoo: unified decoder (attn/MLA/mamba/MoE patterns) + ResNet20."""
from repro.models.transformer import Model, make_model

__all__ = ["Model", "make_model"]
