"""End-to-end behaviour: the paper's claims at test scale (Fig. 1-3 logic)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPDSGDMConfig, CPDSGDM, PDSGDM, PDSGDMConfig,
                        SignCompressor, make_optimizer)
from repro.core.gossip import DenseComm
from repro.core.topology import complete, ring
from repro.data.synthetic import ClassStreamCfg, LMStreamCfg, class_batch, lm_batch
from repro.models.resnet import resnet20_init, resnet20_loss
from repro.train.trainer import SimTrainer

K = 8


def _resnet_params(K):
    p = resnet20_init(jax.random.PRNGKey(0), width=4)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), p)


def _train(opt, steps=40, seed=0):
    # per-worker batch 16 + eta 0.1 = the paper-matching regime (see
    # benchmarks/common.py); smaller settings leave PD-SGDM mid-transient.
    cfg = ClassStreamCfg(batch=16, n_workers=K, seed=seed)
    trainer = SimTrainer(resnet20_loss, opt)
    params = _resnet_params(K)
    params, state, hist = trainer.train(
        params, lambda t: class_batch(cfg, t), steps, log_every=5)
    return hist


@pytest.mark.slow
def test_pdsgdm_matches_csgdm_loss():
    """Fig. 1: PD-SGDM(p∈{4,8}) reaches ≈ the same loss as C-SGDM."""
    res = {}
    for name, p in [("c_sgdm", 1), ("pd_sgdm", 4), ("pd_sgdm", 8)]:
        comm = DenseComm(complete(K) if name == "c_sgdm" else ring(K))
        opt = make_optimizer(name, comm, eta=0.1, mu=0.9, p=p,
                             weight_decay=1e-4)
        res[(name, p)] = _train(opt, steps=90)
    base = res[("c_sgdm", 1)].loss[-1]
    for key, hist in res.items():
        assert hist.loss[-1] < hist.loss[0] - 1.0, key  # learning happened
        assert hist.loss[-1] < base + 0.5, (key, hist.loss[-1], base)


@pytest.mark.slow
def test_cpdsgdm_matches_pdsgdm_with_less_comm():
    """Fig. 2-3: sign-compressed CPD-SGDM ≈ PD-SGDM loss, ≪ bytes."""
    ring8 = DenseComm(ring(K))
    pd = make_optimizer("pd_sgdm", ring8, eta=0.1, mu=0.9, p=4)
    cpd = make_optimizer("cpd_sgdm", ring8, eta=0.1, mu=0.9, p=4,
                         gamma=0.4, compressor=SignCompressor(block=64))
    # CPD's compressed consensus has a longer transient than PD (the x̂
    # error-feedback needs rounds to lock on) — give it 150 steps, and
    # compare tail minima (single-batch losses bounce by ~0.4 late in
    # training at this scale).
    h_pd = _train(pd, steps=90)
    h_cpd = _train(cpd, steps=150)
    assert min(h_cpd.loss[-6:]) < h_cpd.loss[0] - 1.5
    assert min(h_cpd.loss[-6:]) < min(h_pd.loss[-6:]) + 0.75
    # ~16-32× fewer bytes per round
    assert h_cpd.comm_mb[-1] < h_pd.comm_mb[-1] / 10.0


def test_lm_training_decreases_loss():
    from repro.configs.base import ModelCfg
    from repro.models import make_model
    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    model = make_model(mcfg)
    Kw = 4
    params = jax.vmap(lambda k: model.init(jax.random.PRNGKey(0)))(
        jax.random.split(jax.random.PRNGKey(0), Kw))
    opt = make_optimizer("pd_sgdm", DenseComm(ring(Kw)), eta=0.3, mu=0.9,
                         p=4)
    trainer = SimTrainer(lambda p, b: model.loss(p, b), opt)
    cfg = LMStreamCfg(vocab=256, seq_len=32, batch=4, n_workers=Kw)
    _, _, hist = trainer.train(params, lambda t: lm_batch(cfg, t), 40)
    assert hist.loss[-1] < hist.loss[0] - 0.5, hist.loss


def test_comm_accounting_scales_with_p():
    """Doubling p halves communicated bytes (same steps)."""
    ring8 = DenseComm(ring(K))
    h4 = _train(make_optimizer("pd_sgd", ring8, eta=0.05, p=4), steps=32)
    h8 = _train(make_optimizer("pd_sgd", ring8, eta=0.05, p=8), steps=32)
    assert h4.comm_mb[-1] == pytest.approx(2 * h8.comm_mb[-1], rel=0.15)
