"""Elastic K→K' checkpoint re-partitioning and revival warm-starts.

The checkpoint contract (``repro.checkpoint.checkpoint``) stores
worker-stacked trees for a fixed fleet size K.  This module extends it to
elastic membership:

* ``restore_elastic`` — restore a round-boundary checkpoint written by a
  K-worker fleet into a K'-worker template.  Survivors (slots < min(K, K'))
  keep their own shard bit-for-bit; joiners warm-start params *and the
  full optimizer state* from a live donor's shard (``donor_map``).  With
  K' == K this is exactly ``checkpoint.restore`` — resume stays
  bit-identical for surviving workers at the round boundary.

* ``warm_start_worker`` — in-fleet revival: copy one live donor's slot
  over a rejoining worker's slot in worker-stacked params/state (the
  chaos harness applies this *before* the revival round runs).

CPD-SGDM's ``xhat_nbrs`` copies need care in both operations: a copy held
by worker k for its (ax, sh) neighbour must equal that *neighbour's* x̂,
not the donor's copy of the donor's neighbour.  Because the commit
protocol keeps every stored copy exactly equal to its owner's x̂ at round
boundaries, the copies are simply re-derived from the re-partitioned x̂ —
no neighbour state is ever guessed.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["donor_map", "pick_donor", "repartition", "restore_elastic",
           "warm_start_worker"]

tmap = jax.tree_util.tree_map

_NBR_KEY_RE = re.compile(r"ax(\d+)_sh([+-]\d+)")


def donor_map(old_k: int, new_k: int) -> np.ndarray:
    """(new_k,) source slot per new slot: identity for survivors, wrapped
    neighbour shards for joiners (slot K+j warm-starts from worker j)."""
    return np.arange(new_k) % old_k


def pick_donor(live, joiner: int) -> int:
    """Nearest live worker on the ring order — the donor a rejoining
    worker warm-starts from."""
    live = np.asarray(live, dtype=bool)
    K = live.shape[0]
    for d in range(1, K):
        for cand in ((joiner + d) % K, (joiner - d) % K):
            if live[cand]:
                return int(cand)
    raise ValueError("no live donor in the fleet")


def _reindex(tree, k_from: int, donors: np.ndarray):
    """Re-index every worker-stacked leaf (leading dim ``k_from``) by
    ``donors``; scalars and non-worker leaves pass through untouched."""
    idx = jnp.asarray(donors)

    def f(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == k_from:
            return jnp.take(jnp.asarray(leaf), idx, axis=0)
        return leaf

    return tmap(f, tree)


def repartition(tree, old_k: int, new_k: int,
                donors: Optional[np.ndarray] = None):
    """Re-partition a worker-stacked tree from ``old_k`` to ``new_k``
    slots (``donor_map`` by default).  ``xhat_nbrs`` sub-dicts, if present
    at the top level, must be fixed up by the caller (``restore_elastic``
    re-derives them from x̂)."""
    if donors is None:
        donors = donor_map(old_k, new_k)
    return _reindex(tree, old_k, donors)


def _derive_nbrs(xhat, keys, new_k: int) -> Dict[str, Any]:
    """Rebuild the per-shift neighbour copies from the canonical x̂:
    copy[(ax, sh)][w] = x̂[(w + sh) % K'] — exact, because the commit
    protocol keeps every stored copy equal to its owner's x̂."""
    nbrs = {}
    for key in keys:
        m = _NBR_KEY_RE.fullmatch(key)
        if m is None:
            raise ValueError(f"unrecognized xhat_nbrs key {key!r}")
        sh = int(m.group(2))
        recv = jnp.asarray((np.arange(new_k) + sh) % new_k)
        nbrs[key] = tmap(lambda h: jnp.take(h, recv, axis=0), xhat)
    return nbrs


def _resize_worker_dim(tree, k_from: int, k_to: int):
    """Shape-only template resize of the worker dim (structs, not data)."""
    def f(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == k_from:
            return jax.ShapeDtypeStruct((k_to,) + tuple(leaf.shape[1:]),
                                        leaf.dtype)
        return jax.ShapeDtypeStruct(tuple(getattr(leaf, "shape", ())),
                                    leaf.dtype)
    return tmap(f, tree)


def _peek_worker_count(ckpt_dir: str, step: int) -> int:
    """Leading dim of the checkpoint's first params leaf = the fleet size
    that wrote it."""
    import os
    data = np.load(os.path.join(ckpt_dir, f"step_{step:08d}", "params.npz"))
    return int(data["leaf_0"].shape[0])


def restore_elastic(ckpt_dir: str, step: int, *, params_template,
                    state_template, comm=None) -> Dict[str, Any]:
    """Restore ``{"params", "opt_state"}`` from a checkpoint written by an
    old fleet into (possibly differently sized) new-fleet templates.

    Same size → exact ``checkpoint.restore`` (bit-identical resume).
    K→K': every worker-stacked leaf is re-indexed through ``donor_map``
    (grow: joiners clone a live neighbour's params + full optimizer state;
    shrink: the surviving prefix keeps its own shards), the step counter
    passes through unchanged (round/schedule/membership phase all derive
    from it), and CPD's ``xhat_nbrs`` are re-derived from the
    re-partitioned x̂ under the new fleet's shift set.

    ``comm`` (the new fleet's backend) is required only when the state
    carries ``xhat_nbrs`` and the size changed: the *old* fleet's copy
    keys are rebuilt from the same topology family at the old size.
    """
    from repro.checkpoint import checkpoint as ckpt

    new_k = jax.tree_util.tree_leaves(params_template)[0].shape[0]
    old_k = _peek_worker_count(ckpt_dir, step)
    if old_k == new_k:
        return ckpt.restore(ckpt_dir, step, {
            "params": params_template, "opt_state": state_template})

    donors = donor_map(old_k, new_k)
    old_params_t = _resize_worker_dim(params_template, new_k, old_k)
    old_state_t = {}
    for name, sub in state_template.items():
        if name == "xhat_nbrs":
            if comm is None:
                raise ValueError(
                    "restore_elastic: re-partitioning xhat_nbrs needs the "
                    "new fleet's comm backend (comm=...)")
            from repro.core.topology import make_topology
            top = comm.topology
            if len(top.axis_sizes) != 1:
                raise ValueError(
                    "elastic re-partitioning needs a single worker axis")
            old_top = make_topology(top.name, (old_k,))
            proto = next(iter(sub.values()))
            old_state_t[name] = {
                f"ax{ax}_sh{sh:+d}": _resize_worker_dim(proto, new_k, old_k)
                for (ax, sh, _w) in old_top.shifts if sh != 0}
        else:
            old_state_t[name] = _resize_worker_dim(sub, new_k, old_k)

    restored = ckpt.restore(ckpt_dir, step, {
        "params": old_params_t, "opt_state": old_state_t})
    params = _reindex(restored["params"], old_k, donors)
    state = {}
    for name, sub in restored["opt_state"].items():
        if name == "xhat_nbrs":
            continue               # re-derived below, from the new x̂
        state[name] = _reindex(sub, old_k, donors)
    if "xhat_nbrs" in state_template:
        state["xhat_nbrs"] = _derive_nbrs(
            state["xhat"], sorted(state_template["xhat_nbrs"]), new_k)
    return {"params": params, "opt_state": state}


def warm_start_worker(params, state, *, joiner: int, donor: int):
    """Clone ``donor``'s slot over ``joiner``'s in worker-stacked trees —
    params and the complete optimizer state (momentum, x̂, tracking
    correction, QG buffers).  The chaos harness applies this at a revival
    round *before* the round runs, so the rejoined worker's first exchange
    already carries a live model.  ``xhat_nbrs``, if present, is re-derived
    from the patched x̂ (copies ≡ owner x̂ at round boundaries)."""
    K = jax.tree_util.tree_leaves(params)[0].shape[0]

    def cp(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == K:
            return leaf.at[joiner].set(leaf[donor])
        return leaf

    new_params = tmap(cp, params)
    new_state = {}
    for name, sub in state.items():
        if name == "xhat_nbrs":
            continue
        new_state[name] = tmap(cp, sub)
    if "xhat_nbrs" in state:
        new_state["xhat_nbrs"] = _derive_nbrs(
            new_state["xhat"], sorted(state["xhat_nbrs"]), K)
    return new_params, new_state
