"""Checkpoint round-trip: save → restore → train continues bit-identically.

Regression test for the seed defect where ``ShardedTrainer`` checkpointed
only ``{"m", "step"}`` — restoring a CPD-SGDM run silently reset the
``xhat``/``xhat_nbrs`` error-compensation state.  The subprocess forces 8
host devices so the checkpoint carries real sharded state (including the
per-neighbour x̂ copies of the packed-sign gossip path).

The fast-tier parametrized tests below cover *every* optimizer family:
each one's full state tree must round-trip through the npz checkpoint
bit-for-bit, and ``runtime._state_spec`` must know how to shard every
state key — an optimizer growing a new state entry without teaching
``_state_spec`` fails here, not in a multi-device nightly.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import make_compressor, make_optimizer
from repro.core.gossip import DenseComm, ShardedComm
from repro.core.topology import ring
from repro.launch.runtime import _state_spec

_OPTIMIZERS = [
    ("pd_sgdm", {}, {"m", "step"}),
    ("cpd_sgdm", {"gamma": 0.5, "compressor": make_compressor("sign")},
     {"m", "step", "xhat"}),
    ("mt_dsgdm", {}, {"m", "step", "c", "g_prev"}),
    ("mt_dsgdm", {"compressor": make_compressor("sign")},
     {"m", "step", "c", "g_prev"}),
    ("qg_dsgdm", {}, {"m", "step", "xprev"}),
    # overlap=True grows the DelayedMixState tree (in-flight payload +
    # staleness phase) — it must checkpoint like any other state entry
    ("pd_sgdm", {"overlap": True}, {"m", "step", "mix"}),
    ("mt_dsgdm", {"overlap": True}, {"m", "step", "c", "g_prev", "mix"}),
    ("qg_dsgdm", {"overlap": True}, {"m", "step", "xprev", "mix"}),
    ("cpd_sgdm", {"gamma": 0.5, "compressor": make_compressor("identity"),
                  "overlap": True}, {"m", "step", "xhat", "mix"}),
]
_OPT_IDS = ["pd", "cpd", "mt", "mt_compressed", "qg",
            "pd_overlap", "mt_overlap", "qg_overlap", "cpd_overlap"]


def _dense_opt(name, kw):
    return make_optimizer(name, DenseComm(ring(8)), eta=0.05, mu=0.9,
                          p=2, **kw)


@pytest.mark.parametrize("name,kw,keys", _OPTIMIZERS, ids=_OPT_IDS)
def test_checkpoint_roundtrip_all_optimizers(tmp_path, name, kw, keys):
    """Full optimizer state → npz → restore is bit-identical, for every
    family — the save path must never silently drop a state tree."""
    opt = _dense_opt(name, kw)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 12))}
    state = opt.init(params)
    assert set(state) == keys, f"{name}: state keys drifted: {set(state)}"
    # make every leaf non-trivial so equality is meaningful
    g = {"w": jnp.ones((8, 12)) * 0.1}
    if kw.get("overlap"):
        # the per-step overlap path embeds the exchange at comm steps:
        # 4 steps = 2 rounds, leaving a non-trivial in-flight payload
        # (phase armed) in state["mix"]
        for _ in range(4):
            params, state = opt.step(state, params, g)
        assert int(state["mix"]["phase"]) == 1
    else:
        for _ in range(3):
            params, state = opt.step(state, params, g)
        params, state = opt.comm_round(state, params)
    ckpt.save(str(tmp_path), 3, params=params, opt_state=state)
    out = ckpt.restore(str(tmp_path), 3, {
        "params": jax.eval_shape(lambda: params),
        "opt_state": jax.eval_shape(lambda: state)})
    for a, b in zip(jax.tree_util.tree_leaves(out["opt_state"]),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(out["params"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name,kw,keys", _OPTIMIZERS, ids=_OPT_IDS)
def test_state_spec_covers_every_state_key(name, kw, keys):
    """``runtime._state_spec`` raises KeyError on any state entry it has
    no sharding rule for — run it over every family's sharded state tree
    (the sharded CPD state includes ``xhat_nbrs``)."""
    if name == "cpd_sgdm" and kw.get("overlap"):
        # the config-validation contract: CPD overlap is dense-only (the
        # x̂_nbrs replica copies break under a stale consensus)
        with pytest.raises(ValueError, match="dense-only"):
            make_optimizer(name, ShardedComm(ring(8), axis_names=("w",)),
                           eta=0.05, mu=0.9, p=2, **kw)
        return
    opt = make_optimizer(name, ShardedComm(ring(8), axis_names=("w",)),
                         eta=0.05, mu=0.9, p=2, **kw)
    params = {"w": jax.ShapeDtypeStruct((1, 12), jnp.float32)}
    state_struct = jax.eval_shape(opt.init, params)
    spec = _state_spec(state_struct, {"w": "PSPEC"})
    assert set(spec) == set(state_struct)
    for k in state_struct:
        if k == "step":
            continue
        sub = spec[k]
        if k == "mix":
            # payload trees shard like params; the phase scalar replicates
            assert set(sub) == set(state_struct[k])
            for kk, leaf in sub.items():
                if kk != "phase":
                    assert leaf == {"w": "PSPEC"}
            continue
        leaves = (sub.values() if k == "xhat_nbrs" else [sub])
        for leaf in leaves:
            assert leaf == {"w": "PSPEC"} or leaf["w"] == "PSPEC"

_SCRIPT_RESUME = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.train.trainer import ShardedTrainer

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    run = RunCfg(model=mcfg,
                 parallel=ParallelCfg(profile="A", remat="none"),
                 optim=OptimCfg(name="cpd_sgdm", eta=0.05, mu=0.9, p=2,
                                weight_decay=1e-4))
    mesh = make_debug_mesh(4, 2)
    pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
    K = pack.layout.n_workers

    # full optimizer state must be on disk, not just m/step
    assert "xhat" in pack.state_struct and "xhat_nbrs" in pack.state_struct

    def batch_fn(t):
        return train_batch_arrays(mcfg, K, 2, 16,
                                  jax.random.fold_in(jax.random.PRNGKey(1), t))

    STEPS = 8
    with mesh:
        # A: uninterrupted run
        outA = ShardedTrainer(pack).train(jax.random.PRNGKey(0), batch_fn,
                                          STEPS, log_every=4, verbose=False)
        with tempfile.TemporaryDirectory() as d:
            # B: train to the midpoint, checkpointing there ...
            ShardedTrainer(pack, ckpt_dir=d, ckpt_every=4).train(
                jax.random.PRNGKey(0), batch_fn, STEPS // 2,
                log_every=4, verbose=False)
            # ... then resume from disk and finish
            outB = ShardedTrainer(pack, ckpt_dir=d).train(
                jax.random.PRNGKey(0), batch_fn, STEPS,
                log_every=4, verbose=False, resume=True)
            assert outB["steps_run"] == STEPS // 2, outB["steps_run"]

    for a, b in zip(
            jax.tree_util.tree_leaves((outA["params"], outA["state"])),
            jax.tree_util.tree_leaves((outB["params"], outB["state"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("RESUME_OK")

    # --- off-boundary resume: the checkpoint lands in a per-step tail
    # (t=5 with p=2), so the resumed run must realign on the per-step path
    # before re-entering fused rounds — same trajectory, same schedule.
    STEPS2 = 9
    with mesh:
        outC = ShardedTrainer(pack).train(jax.random.PRNGKey(0), batch_fn,
                                          STEPS2, log_every=4, verbose=False)
        with tempfile.TemporaryDirectory() as d:
            ShardedTrainer(pack, ckpt_dir=d, ckpt_every=5).train(
                jax.random.PRNGKey(0), batch_fn, 5,
                log_every=4, verbose=False)
            outD = ShardedTrainer(pack, ckpt_dir=d).train(
                jax.random.PRNGKey(0), batch_fn, STEPS2,
                log_every=4, verbose=False, resume=True)
            assert outD["steps_run"] == STEPS2 - 5, outD["steps_run"]
    for a, b in zip(
            jax.tree_util.tree_leaves((outC["params"], outC["state"])),
            jax.tree_util.tree_leaves((outD["params"], outD["state"]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=2e-6)
    print("RESUME_TAIL_OK")
""")


_SCRIPT_RESUME_SCHED = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.train.trainer import ShardedTrainer

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    run = RunCfg(model=mcfg,
                 parallel=ParallelCfg(profile="A", remat="none",
                                      topology_schedule="one_peer_exp"),
                 optim=OptimCfg(name="pd_sgdm", eta=0.05, mu=0.9, p=2,
                                weight_decay=1e-4))
    mesh = make_debug_mesh(4, 2)
    pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
    K = pack.layout.n_workers
    sched = pack.opt.comm.schedule
    T = sched.period
    assert T == 2, T     # K=4 one-peer exp: offsets 1, 2

    def batch_fn(t):
        return train_batch_arrays(mcfg, K, 2, 16,
                                  jax.random.fold_in(jax.random.PRNGKey(1), t))

    # 4 rounds = 2 cycles; checkpoint after round 1, i.e. MID-cycle
    # (schedule phase 1 of 2).  A resume that reset the phase to round 0
    # would re-apply W_0 where W_1 belongs and diverge.
    STEPS = 8
    with mesh:
        outA = ShardedTrainer(pack).train(jax.random.PRNGKey(0), batch_fn,
                                          STEPS, log_every=4, verbose=False)
        with tempfile.TemporaryDirectory() as d:
            ShardedTrainer(pack, ckpt_dir=d, ckpt_every=2).train(
                jax.random.PRNGKey(0), batch_fn, 2,
                log_every=4, verbose=False)
            outB = ShardedTrainer(pack, ckpt_dir=d).train(
                jax.random.PRNGKey(0), batch_fn, STEPS,
                log_every=4, verbose=False, resume=True)
            assert outB["steps_run"] == STEPS - 2, outB["steps_run"]
        # the phase is derived from the checkpointed step counter, so the
        # restored state must place the next gossip at W_{step//p mod T}
        assert int(np.asarray(outB["state"]["step"])) == STEPS

    for a, b in zip(
            jax.tree_util.tree_leaves((outA["params"], outA["state"])),
            jax.tree_util.tree_leaves((outB["params"], outB["state"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the witness that bitwise equality proves phase restoration: the
    # resumed run's first gossip is round 1, and W1 really differs from W0
    # (a phase-reset would have applied W0 there instead).
    assert not np.allclose(sched.at(0).W, sched.at(1).W)
    print("RESUME_SCHED_OK")
""")


_SCRIPT_RESUME_MT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.train.trainer import ShardedTrainer

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    # MT-DSGDm under a time-varying schedule: the tracking state (c,
    # g_prev) must be on disk AND the dual (x, c) gossip must resume at
    # the correct schedule phase.  Checkpoint after round 1 = MID-cycle.
    run = RunCfg(model=mcfg,
                 parallel=ParallelCfg(profile="A", remat="none",
                                      topology_schedule="one_peer_exp"),
                 optim=OptimCfg(name="mt_dsgdm", eta=0.05, mu=0.9, p=2,
                                weight_decay=1e-4))
    mesh = make_debug_mesh(4, 2)
    pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
    K = pack.layout.n_workers
    assert "c" in pack.state_struct and "g_prev" in pack.state_struct
    assert pack.opt.comm.schedule.period == 2

    def batch_fn(t):
        return train_batch_arrays(mcfg, K, 2, 16,
                                  jax.random.fold_in(jax.random.PRNGKey(1), t))

    STEPS = 8
    with mesh:
        outA = ShardedTrainer(pack).train(jax.random.PRNGKey(0), batch_fn,
                                          STEPS, log_every=4, verbose=False)
        with tempfile.TemporaryDirectory() as d:
            ShardedTrainer(pack, ckpt_dir=d, ckpt_every=2).train(
                jax.random.PRNGKey(0), batch_fn, 2,
                log_every=4, verbose=False)
            outB = ShardedTrainer(pack, ckpt_dir=d).train(
                jax.random.PRNGKey(0), batch_fn, STEPS,
                log_every=4, verbose=False, resume=True)
            assert outB["steps_run"] == STEPS - 2, outB["steps_run"]

    for a, b in zip(
            jax.tree_util.tree_leaves((outA["params"], outA["state"])),
            jax.tree_util.tree_leaves((outB["params"], outB["state"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("RESUME_MT_OK")
""")


_SCRIPT_RESUME_OVERLAP = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train
    from repro.train.trainer import ShardedTrainer

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    # overlap=True: the checkpoint at step 4 (a round boundary) carries a
    # LIVE in-flight payload — state["mix"]["buf"] is the round-2 snapshot
    # whose exchange lands in round 3.  Kill/restore there must continue
    # bit-identically: a resume that dropped or re-snapshotted the payload
    # would mix the wrong matrix one round later.
    run = RunCfg(model=mcfg,
                 parallel=ParallelCfg(profile="A", remat="none"),
                 optim=OptimCfg(name="{name}", eta=0.05, mu=0.9, p=2,
                                weight_decay=1e-4, overlap=True))
    mesh = make_debug_mesh(4, 2)
    pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
    K = pack.layout.n_workers
    assert "mix" in pack.state_struct

    def batch_fn(t):
        return train_batch_arrays(mcfg, K, 2, 16,
                                  jax.random.fold_in(jax.random.PRNGKey(1), t))

    STEPS = 8
    with mesh:
        outA = ShardedTrainer(pack).train(jax.random.PRNGKey(0), batch_fn,
                                          STEPS, log_every=4, verbose=False)
        with tempfile.TemporaryDirectory() as d:
            ShardedTrainer(pack, ckpt_dir=d, ckpt_every=4).train(
                jax.random.PRNGKey(0), batch_fn, STEPS // 2,
                log_every=4, verbose=False)
            outB = ShardedTrainer(pack, ckpt_dir=d).train(
                jax.random.PRNGKey(0), batch_fn, STEPS,
                log_every=4, verbose=False, resume=True)
            assert outB["steps_run"] == STEPS // 2, outB["steps_run"]

    # the restored in-flight payload was non-trivial (phase armed) ...
    assert int(np.asarray(outB["state"]["mix"]["phase"])) == 1
    # ... and the continued trajectory is bitwise the uninterrupted one
    for a, b in zip(
            jax.tree_util.tree_leaves((outA["params"], outA["state"])),
            jax.tree_util.tree_leaves((outB["params"], outB["state"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("RESUME_OVERLAP_OK")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_cpdsgdm_resume_bit_identical():
    out = _run(_SCRIPT_RESUME)
    assert "RESUME_OK" in out
    assert "RESUME_TAIL_OK" in out


@pytest.mark.parametrize("name", ["pd_sgdm", "mt_dsgdm", "qg_dsgdm"])
def test_overlap_resume_bit_identical_with_inflight_payload(name):
    """Mid-overlap kill/restore: the checkpoint carries a live in-flight
    payload (DelayedMixState), and the resumed run mixes it one round
    later exactly as the uninterrupted run — bit-identical, for every
    overlap-capable optimizer family on the sharded backend."""
    out = _run(_SCRIPT_RESUME_OVERLAP.replace("{name}", name))
    assert "RESUME_OVERLAP_OK" in out


def test_mt_dsgdm_resume_bit_identical_mid_schedule():
    """MT-DSGDm resume from a mid-cycle checkpoint of a time-varying
    topology run: the tracking trees (c, g_prev) are checkpointed like
    CPD's x̂ and the dual (x, c) gossip continues at the restored schedule
    phase — the resumed trajectory is bitwise identical."""
    out = _run(_SCRIPT_RESUME_MT)
    assert "RESUME_MT_OK" in out


def test_scheduled_topology_resume_restores_phase():
    """Resume from a mid-cycle checkpoint of a time-varying topology run:
    the schedule phase (round index = step // p) is derived from the
    checkpointed step counter, so training continues bit-identically —
    the phase is restored, not reset to round 0."""
    out = _run(_SCRIPT_RESUME_SCHED)
    assert "RESUME_SCHED_OK" in out
