"""Canned-HLO units for the two-level round contract: the parser must
classify psum-inside-node (all-reduce with a node-sized replica group)
vs. collective-permute-between-nodes, and the per-level byte check must
hold accounted ≡ shipped.  Pure text — no jax tracing, no devices."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_check import (check_collectives_allowed,
                                      check_hier_wire_bytes)
from repro.analysis.hlo_parse import parse_collectives
from repro.core.gossip import DenseComm, hier_bytes_per_round
from repro.core.topology import hierarchical

# One two-level round on K = 8 (2 nodes × 4), payload f32[1024] (4096 B):
# grouped all-reduce (intra average) → leader collective-permute (inter)
# → grouped all-reduce (rebroadcast), plus the scalar loss mean over the
# full worker axis.  Replica groups use the brace form the node-grouped
# collectives lower to.
CANNED_F32 = """
HloModule jit_hier_round

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %avg = f32[1024]{0} all-reduce(%a), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %cp = f32[1024]{0} collective-permute(%avg), source_target_pairs={{0,4},{4,0}}, channel_id=1
  %reb = f32[1024]{0} all-reduce(%cp), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %loss = f32[] all-reduce(%l), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}
"""

# the bf16 wire ships as a u16 bitcast (2048 B) — converts pinned off the
# collective by the integer bitcast, see ShardedComm._wire_cast
CANNED_BF16 = CANNED_F32.replace(
    "%cp = f32[1024]{0} collective-permute",
    "%cp = u16[1024]{0} collective-permute")

_TREE = [jax.ShapeDtypeStruct((1024,), jnp.float32)]


def _levels(wire_dtype="float32"):
    return hier_bytes_per_round(
        _TREE, DenseComm(hierarchical(2, 4), wire_dtype=wire_dtype))


def test_parser_classifies_levels_by_group():
    st = parse_collectives(CANNED_F32)
    by_group = {}
    for c in st.calls:
        if c.op == "all-reduce":
            by_group.setdefault(c.group, []).append(c)
    assert len(by_group[4]) == 2        # intra: node-sized replica groups
    assert len(by_group[8]) == 1        # the full-axis scalar loss mean
    assert st.counts["collective-permute"] == 1
    cp = next(c for c in st.calls if c.op == "collective-permute")
    assert cp.result_bytes == 1024 * 4
    assert cp.wire_bytes == 1024 * 4    # point-to-point: wire = payload


def test_allowed_needs_node_group_opt_in():
    st = parse_collectives(CANNED_F32)
    # default contract: the substantive node all-reduces are violations
    errs = check_collectives_allowed(st)
    assert len(errs) == 2 and all("all-reduce" in e for e in errs)
    # node_allreduce_group admits exactly the node-sized groups; the
    # scalar loss mean still rides the scalar exemption
    assert check_collectives_allowed(st, node_allreduce_group=4) == []
    # a wrong node size admits nothing
    errs = check_collectives_allowed(st, node_allreduce_group=2)
    assert len(errs) == 2


def test_hier_wire_bytes_accounted_equals_shipped():
    st = parse_collectives(CANNED_F32)
    assert check_hier_wire_bytes(st, _levels(), node_size=4) == []


def test_hier_wire_bytes_bf16():
    st = parse_collectives(CANNED_BF16)
    lv = _levels("bfloat16")
    assert lv["inter_site"] == 1024 * 2
    assert check_hier_wire_bytes(st, lv, node_size=4) == []
    # the f32 accounting must reject the halved wire (and vice versa)
    assert check_hier_wire_bytes(st, _levels(), node_size=4)
    assert check_hier_wire_bytes(parse_collectives(CANNED_F32), lv,
                                 node_size=4)


def test_hier_wire_bytes_flags_intra_mismatch():
    # drop the rebroadcast: intra traffic is half the accounted figure
    st = parse_collectives(CANNED_F32.replace(
        "  %reb = f32[1024]{0} all-reduce(%cp), "
        "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add\n", ""))
    errs = check_hier_wire_bytes(st, _levels(), node_size=4)
    assert len(errs) == 1 and "intra" in errs[0]
    # check_intra=False (the kernel layout's padded rows) skips it
    assert check_hier_wire_bytes(st, _levels(), node_size=4,
                                 check_intra=False) == []


def test_hier_wire_bytes_tiny_node_leaves_are_intra():
    """Node-group all-reduces below the scalar exemption (tiny norm-scale
    leaves) still count as intra traffic — the byte check must not drop
    them."""
    extra = ("  %norm.avg = f32[32]{0} all-reduce(%s), "
             "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add\n"
             "  %norm.reb = f32[32]{0} all-reduce(%norm.avg), "
             "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add\n")
    st = parse_collectives(CANNED_F32.replace("  %loss", extra + "  %loss"))
    lv = hier_bytes_per_round(
        [jax.ShapeDtypeStruct((1024,), jnp.float32),
         jax.ShapeDtypeStruct((32,), jnp.float32)],
        DenseComm(hierarchical(2, 4)))
    # inter accounting includes the 32-elem leaf the canned cp doesn't
    # ship — only the intra side balances here
    errs = check_hier_wire_bytes(st, lv, node_size=4)
    assert len(errs) == 1 and "inter" in errs[0]
    got = sum(c.wire_bytes * c.mult for c in st.calls
              if c.op == "all-reduce" and c.group == 4)
    assert got == pytest.approx(lv["intra_wire"])
