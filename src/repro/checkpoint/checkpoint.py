"""Pytree checkpointing: npz payload + JSON treedef manifest.

Works for params, optimizer state (incl. CPD's x̂ trees), and data-stream
cursors.  Arrays are gathered to host (fine at example scale; a real
multi-host deployment would swap in a distributed array serializer behind
the same ``save``/``restore`` interface).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step"]

_STEP_RE = re.compile(r"step_(\d+)")


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload, dtypes = {}, {}
    for i, l in enumerate(leaves):
        arr = np.asarray(l)
        dtypes[f"leaf_{i}"] = arr.dtype.name
        if arr.dtype.name in _VIEW_AS:
            # npz cannot serialize extension dtypes: store a bit-view and
            # record the logical dtype in the manifest
            arr = arr.view(_VIEW_AS[arr.dtype.name])
        payload[f"leaf_{i}"] = arr
    return payload, dtypes, treedef


_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def save(ckpt_dir: str, step: int, **trees) -> str:
    """save(dir, step, params=..., opt_state=..., ...) -> path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    manifest = {"step": step, "trees": {}, "dtypes": {}}
    for name, tree in trees.items():
        payload, dtypes, treedef = _flatten(tree)
        np.savez(os.path.join(path, f"{name}.npz"), **payload)
        manifest["trees"][name] = str(treedef)
        manifest["dtypes"][name] = dtypes
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return path


def restore(ckpt_dir: str, step: int, templates: Dict[str, Any]):
    """Restore named trees using structure templates (e.g. from init)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for name, template in templates.items():
        data = np.load(os.path.join(path, f"{name}.npz"))
        dtypes = manifest.get("dtypes", {}).get(name, {})
        leaves_t, treedef = jax.tree_util.tree_flatten(template)
        leaves = []
        for i in range(len(leaves_t)):
            arr = data[f"leaf_{i}"]
            dt = dtypes.get(f"leaf_{i}")
            if dt in _VIEW_AS:
                import ml_dtypes
                arr = arr.view(getattr(ml_dtypes, dt))
            leaves.append(jnp.asarray(arr))
        for l, t in zip(leaves, leaves_t):
            if hasattr(t, "shape") and tuple(l.shape) != tuple(t.shape):
                raise ValueError(
                    f"{name}: checkpoint leaf {l.shape} != template {t.shape}")
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.fullmatch(d))]
    return max(steps) if steps else None
