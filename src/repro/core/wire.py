"""First-class wire codecs: one pack/unpack subsystem for every compressor.

A :class:`WireCodec` is the *wire format* of a δ-contraction operator: the
concrete pytree-of-arrays payload that crosses the interconnect, plus the
pack/unpack maps between a parameter-drift tensor and that payload.  The
compressor (``repro.core.compression``) owns the math Q(x); the codec owns
the bytes — and ``Q = unpack ∘ pack`` *by construction*, so the simulated
semantics, the shipped payload, and the byte accounting can never drift
apart.

Payload layouts (per leaf of ``n`` elements, ``nb = ceil(n / block)``):

===========  =====================================================  ==========
codec        payload (dict of arrays)                               bytes
===========  =====================================================  ==========
identity     ``vals``   f32 (n,)                                    4·n
sign         ``bits``   u8 (nb, block/8), ``scales`` f32 (nb,)      nb·(block/8+4)
topk         ``idx``    i32 (nb, W), ``vals`` f32 (nb, W)           nb·W·8
randk        ``vals``   f32 (k,)  — ``idx`` derived from the key    k·4
qsgd         ``levels`` u8 (nb, block·bits/8), ``norms`` f32 (nb,)  nb·(block·bits/8+4)
sparse_rows  ``rowidx`` i32 (R,) + inner payload of the gathered    R·(4+row)
             (R, block) row matrix (f32 / sign / qsgd rows)
===========  =====================================================  ==========

with ``W = max(1, ceil(fraction·block))`` (top-k slot width, uniform across
blocks so the payload is rectangular — tail blocks fill unused slots with
``(idx 0, val 0)`` placeholders that unpack to nothing),
``bits = qsgd_bits(levels)`` ∈ {2, 4, 8} (smallest byte-divisor holding the
``2·levels+1`` symmetric quantization levels), and for the sparse-rows
codec ``R = min(max_rows, nb)`` (the static touched-row budget) and
``row`` the inner codec's per-row bytes — ``4·block`` (f32),
``block/8 + 4`` (sign), ``block·bits/8 + 4`` (qsgd).  See
``docs/WIRE_FORMATS.md`` for the full reference table.

Two execution domains share one semantics:

* **per-leaf** (``pack`` / ``unpack``): pure jnp on any leaf shape, any
  ``block`` — the tree-form comm path and the dense simulation.  Blockwise
  codecs reshape the leaf to padded ``(nb, block)`` rows and call the
  canonical rows implementations below (:func:`topk_rows`,
  :func:`qsgd_rows`, ``compression.sign_pack``).
* **rows** (``rows_pack`` / ``rows_unpack``): the Pallas kernels on the
  flatten-once ``(rows, 1024)`` layout (``repro.kernels``), available when
  ``rows_supported`` and ``block == 1024``.  Per-leaf row alignment
  (``KernelPlan``) makes the kernel blocks identical to the per-leaf
  blocks, so the two domains are bit-exact against each other.

``wire(payload)`` is the subset of entries that actually ship: rand-k's
indices are derived from the round key shared by sender and receiver, so
only the values cross the wire (``unpack`` re-derives the indices when the
payload arrives without them).  ``wire_bytes(n)`` is computed from the
payload shapes themselves, so *accounted bytes ≡ shipped bytes* holds by
construction (asserted in ``tests/test_wire.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (Compressor, IdentityCompressor,
                                    QSGDCompressor, RandKCompressor,
                                    SIGN_BLOCK, SignCompressor,
                                    SparseRowsCompressor, TopKCompressor,
                                    sign_pack, sign_unpack, sign_wire_bytes)

__all__ = [
    "WireCodec", "IdentityCodec", "SignCodec", "TopKCodec", "RandKCodec",
    "QSGDCodec", "SparseRowsCodec", "make_codec", "topk_rows",
    "topk_rows_unpack", "qsgd_rows", "qsgd_rows_unpack", "qsgd_bits",
    "sign_rows", "sign_rows_unpack", "sparse_row_select", "topk_width",
    "payload_nbytes", "wire_key",
]

Payload = Dict[str, jnp.ndarray]


def wire_key(r, leaf_i: int):
    """PRNG key for leaf ``leaf_i``'s payload in communication round ``r``.

    Folds the leaf index and the round but *not* the worker id: the key is
    shared knowledge across the graph, which is what lets rand-k receivers
    re-derive the kept coordinates with zero extra communication (and keeps
    the two backends key-equivalent).  Shared by every optimizer that ships
    codec payloads (CPD-SGDM's drift wire, MT-DSGDm's correction wire).
    """
    base = jax.random.PRNGKey(17)
    return jax.random.fold_in(jax.random.fold_in(base, leaf_i), r)


# --------------------------------------------------------------- rows kernels
# Canonical pure-jnp rows implementations.  These are the per-leaf *and* the
# oracle semantics; the Pallas kernels (repro.kernels.topk_select /
# qsgd_quant) must match them bit-exactly (tests/test_kernels.py).

def _row_counts(n: int, block: int) -> jnp.ndarray:
    """(nb,) f32 valid-element count per padded row of one n-element leaf.
    Identical to ``KernelPlan.row_counts`` restricted to that leaf."""
    nb = -(-n // block)
    c = np.full((nb,), float(block), np.float32)
    c[-1] = float(n - (nb - 1) * block)
    return jnp.asarray(c)


def _to_rows(x: jnp.ndarray, block: int):
    """Leaf → zero-padded f32 (nb, block) rows + valid counts."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(-1, block), _row_counts(n, block)


def topk_width(fraction: float, block: int) -> int:
    """Top-k payload slot width: uniform across blocks (and across every
    leaf of a kernel plan) so payload matrices are rectangular."""
    return max(1, int(np.ceil(fraction * block)))


def topk_rows(x: jnp.ndarray, counts: Optional[jnp.ndarray] = None, *,
              fraction: float, width: Optional[int] = None):
    """Blockwise magnitude top-k select on (R, B) rows.

    Returns ``(idx (R, W) int32, vals (R, W) f32)``.  Slot ``j`` of a row is
    *active* iff ``j < ceil(fraction · counts[row])`` — the kept-coordinate
    count follows the row's true (non-padding) length; inactive slots are
    ``(0, 0.0)`` placeholders.  Ordering is |x| descending with ties broken
    by lower index (``lax.top_k`` stability == the kernel's iterative
    lowest-index argmax).
    """
    R, B = x.shape
    W = width if width is not None else topk_width(fraction, B)
    x = x.astype(jnp.float32)
    if counts is None:
        counts = jnp.full((R,), float(B), jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(x), W)
    vals = jnp.take_along_axis(x, idx, axis=1)
    k_active = jnp.ceil(
        jnp.float32(fraction) * counts.reshape(R, 1)).astype(jnp.int32)
    active = jnp.arange(W, dtype=jnp.int32)[None, :] < k_active
    return (jnp.where(active, idx, 0).astype(jnp.int32),
            jnp.where(active, vals, 0.0))


def topk_rows_unpack(idx: jnp.ndarray, vals: jnp.ndarray,
                     block: int) -> jnp.ndarray:
    """Inverse scatter of :func:`topk_rows` → (R, block) f32.  Placeholder
    slots carry val 0.0, so a scatter-*add* makes them vanish even when
    their idx collides with a real selection."""
    R = idx.shape[0]
    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    return jnp.zeros((R, block), jnp.float32).at[rows, idx].add(vals)


def qsgd_bits(levels: int) -> int:
    """Bits per element packing the 2·levels+1 symmetric quantization
    levels: the smallest divisor of 8 that holds them (so whole elements
    pack into bytes)."""
    need = 2 * levels + 1
    for b in (2, 4, 8):
        if (1 << b) >= need:
            return b
    raise ValueError(f"qsgd levels={levels} needs > 8 bits; use ≤ 127")


def qsgd_rows(x: jnp.ndarray, *, levels: int):
    """Blockwise QSGD quantize + bit-pack on (R, B) rows.

    Per row: ``norm = max |x|``; levels ``u = round(x/norm · s) + s`` ∈
    [0, 2s] packed ``8/bits`` per byte.  Returns
    ``(packed (R, B·bits/8) u8, norms (R,) f32)``.  Deterministic nearest
    rounding (the contraction variant); padding zeros quantize to the
    center level and unpack back to exactly 0.
    """
    R, B = x.shape
    bits = qsgd_bits(levels)
    vpb = 8 // bits
    assert B % vpb == 0, (B, bits)
    x = x.astype(jnp.float32)
    s = jnp.float32(levels)
    norm = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    # scale formed first, then exactly one elementwise multiply: the
    # ``x / norm · s`` chain would be reassociated differently by XLA in
    # the fused-round jit than in the Pallas lowering (1-ulp drift near
    # rounding ties); this form leaves the compiler nothing to reassociate
    qscale = s / jnp.maximum(norm, 1e-30)
    u = (jnp.round(x * qscale) + s).astype(jnp.uint8)
    grouped = u.reshape(R, B // vpb, vpb)
    weights = (jnp.uint8(1) << (jnp.uint8(bits)
                                * jnp.arange(vpb, dtype=jnp.uint8)))
    packed = jnp.sum(grouped * weights, axis=-1).astype(jnp.uint8)
    return packed, norm.reshape(R)


def qsgd_rows_unpack(packed: jnp.ndarray, norms: jnp.ndarray, *,
                     levels: int, block: int) -> jnp.ndarray:
    """Inverse of :func:`qsgd_rows` → (R, block) f32 = (u − s)·(1/s)·norm.

    Bit-determinism contract (the kernel mirrors every step): the 1/s
    reciprocal is a precomputed f32 constant, not a division (XLA
    strength-reduces constant divisions inconsistently across lowerings);
    the scale is formed per row before the single elementwise multiply (no
    reassociation freedom); and the result passes through a select on
    ``norm > 0`` so empty/padding rows decode to exact +0.  Every
    *materialized* value matches the Pallas kernel bit-for-bit; note that
    XLA-CPU may still contract the final multiply into a downstream add
    (fma) when this whole expression is fused into a larger consumer — a
    ≤1-ulp, consumer-side effect (see tests/test_kernels.py).
    """
    R = packed.shape[0]
    bits = qsgd_bits(levels)
    vpb = 8 // bits
    mask = jnp.uint8((1 << bits) - 1)
    shifts = jnp.uint8(bits) * jnp.arange(vpb, dtype=jnp.uint8)
    u = (packed[:, :, None] >> shifts) & mask
    s = jnp.float32(levels)
    inv_s = jnp.float32(np.float32(1.0) / np.float32(levels))
    norms = norms.reshape(R, 1)
    scale = inv_s * norms
    vals = (u.reshape(R, block).astype(jnp.float32) - s) * scale
    return jnp.where(norms > 0, vals, 0.0)


def _tree_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Fixed binary-tree sum over the last axis.  ``jnp.sum``'s reduction
    strategy (and hence its float summation order) varies with the operand
    shape, so the same 1024-lane row summed as part of a (1, B) per-leaf
    matrix and a (N·S, B) collapsed kernel matrix can differ by 1 ulp.
    Here every step is an elementwise add of the two halves — XLA has no
    reassociation freedom — so the result is bit-identical regardless of
    how many rows ride along.  The sparse wire uses this for its row-norm
    selection and sign-inner scales, keeping the per-leaf and kernel
    payloads exact against each other."""
    n = x.shape[-1]
    p = 1 << max(n - 1, 0).bit_length()
    if p != n:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p - n)])
    while x.shape[-1] > 1:
        x = x[..., 0::2] + x[..., 1::2]
    return x[..., 0]


def sign_rows(x: jnp.ndarray, counts: Optional[jnp.ndarray] = None):
    """Blockwise scaled-sign pack on (R, B) rows — the sparse wire's inner
    sign codec (padding assumed zero; ``counts`` is each row's true
    length, the scale divisor).  Returns
    ``(packed (R, B/8) u8, scales (R,) f32)``.  The scale sum is the
    shape-independent :func:`_tree_sum`, and the count divisor is applied
    as an explicit reciprocal multiply: when the gathered counts are
    constant-foldable (single-row leaf) XLA strength-reduces a division
    to exactly this form, so spelling it out keeps data-dependent and
    folded paths bit-identical (same trick as ``qsgd_rows_unpack``).
    (The Pallas sign kernel's own scale keeps ``jnp.sum`` semantics; the
    two sign wires are distinct formats and never compared bitwise.)"""
    R, B = x.shape
    x = x.astype(jnp.float32)
    if counts is None:
        counts = jnp.full((R,), float(B), jnp.float32)
    counts = jnp.asarray(counts, jnp.float32).reshape(R)
    scales = _tree_sum(jnp.abs(x)) * (jnp.float32(1.0)
                                      / jnp.maximum(counts, 1.0))
    bits = (x >= 0).astype(jnp.uint8).reshape(R, B // 8, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    packed = jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)
    return packed, scales


def sign_rows_unpack(packed: jnp.ndarray, scales: jnp.ndarray, *,
                     block: int) -> jnp.ndarray:
    """Inverse of :func:`sign_rows` → (R, block) f32 = scale·sign.  A
    zero row packs to scale 0 and decodes to exact ±0 everywhere (adding
    it is the identity); padding lanes decode to ±scale and are discarded
    by the per-leaf ``[:n]`` slice / ``KernelPlan.unflatten``, exactly as
    the dense sign codec's are."""
    R = packed.shape[0]
    bytes_ = packed.reshape(R, block // 8, 1)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bytes_ >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    return signs.reshape(R, block) * scales.reshape(R, 1)


def sparse_row_select(x: jnp.ndarray, budget: int) -> jnp.ndarray:
    """The touched-row selector of the sparse wire: indices of the
    ``budget`` largest rows of (R, B) ``x`` by squared L2 row norm, sorted
    ascending (i32).  ``lax.top_k`` returns distinct indices, so a payload
    never carries duplicate rows; untouched (all-zero) rows have norm 0
    and are only selected when fewer than ``budget`` rows are touched —
    they ship zeros and decode to exact 0, so an under-full budget is
    lossless padding, not error.  Norms use the shape-independent
    :func:`_tree_sum` so the per-leaf and kernel paths select identical
    rows (a 1-ulp norm drift could flip a selection near a tie)."""
    norms = _tree_sum(jnp.square(x.astype(jnp.float32)))
    _, idx = jax.lax.top_k(norms, budget)
    return jnp.sort(idx).astype(jnp.int32)


# ------------------------------------------------------------------- codecs
@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Wire format of one compressor: payload layout + pack/unpack maps.

    ``pack``/``unpack`` are the per-leaf jnp domain (any shape, vmap-able
    over a stacked worker dim); ``rows_pack``/``rows_unpack`` the Pallas
    (rows, 1024) kernel domain, available iff :attr:`rows_supported`.
    ``wire(payload)`` is what ships; ``wire_bytes(n)`` its exact size.
    """

    name: str = "codec"
    block: int = 0

    @property
    def rows_supported(self) -> bool:
        """Whether the (rows, 1024) Pallas kernel path exists for this
        codec (the caller additionally requires ``block == kernels.LANE``)."""
        return False

    # -- per-leaf (tree) domain -------------------------------------------
    def pack(self, x: jnp.ndarray, key=None) -> Payload:
        raise NotImplementedError

    def unpack(self, payload: Payload, n: int, shape, dtype,
               key=None) -> jnp.ndarray:
        raise NotImplementedError

    # -- (rows, 1024) kernel domain ---------------------------------------
    def rows_pack(self, mat, counts=None, *, interpret=None,
                  plan=None) -> Payload:
        raise NotImplementedError(f"{self.name}: no kernel wire format")

    def rows_unpack(self, payload: Payload, *, interpret=None, plan=None):
        raise NotImplementedError(f"{self.name}: no kernel wire format")

    def rows_wire(self, payload: Payload, plan) -> Payload:
        """Trim a rows-domain payload to its wire extent before a neighbour
        exchange.  Default (dense rows payloads): slice every array to
        ``plan.used_rows`` so block-alignment padding never ships.  Compact
        payloads (sparse rows) override to the identity."""
        u = plan.used_rows
        return {k: v[..., :u, :] for k, v in payload.items()}

    def rows_unwire(self, wire: Payload, plan) -> Payload:
        """Receiver-side inverse of :meth:`rows_wire`: re-pad each array to
        the kernel row extent for the unpack kernel."""
        return {k: plan.pad_wire(v) for k, v in wire.items()}

    # -- accounting --------------------------------------------------------
    def wire(self, payload: Payload) -> Payload:
        """The payload entries that actually cross the wire (drops entries
        the receiver re-derives from the shared key)."""
        return payload

    def wire_bytes(self, n: int) -> int:
        """Exact shipped bytes for an n-element leaf — Σ nbytes of the
        :meth:`wire` arrays, padding blocks included (they really ship)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IdentityCodec(WireCodec):
    """Uncompressed wire.  CPD-SGDM's q is the f32 drift x − x̂, so the
    honest payload is f32 regardless of the parameter dtype."""

    name: str = "identity"

    def pack(self, x, key=None):
        return {"vals": x.reshape(-1).astype(jnp.float32)}

    def unpack(self, payload, n, shape, dtype, key=None):
        return payload["vals"].reshape(shape).astype(dtype)

    def wire_bytes(self, n):
        return 4 * int(n)


@dataclasses.dataclass(frozen=True)
class SignCodec(WireCodec):
    """Blockwise scaled sign: 1 bit/element + one f32 scale per block."""

    name: str = "sign"
    block: int = SIGN_BLOCK

    @property
    def rows_supported(self):
        return True

    def pack(self, x, key=None):
        bits, scales = sign_pack(x, self.block)
        return {"bits": bits, "scales": scales}

    def unpack(self, payload, n, shape, dtype, key=None):
        return sign_unpack(payload["bits"], payload["scales"], n, shape,
                           dtype, self.block)

    def rows_pack(self, mat, counts=None, *, interpret=None, plan=None):
        from repro.kernels import ops as kops
        bits, scales = kops.sign_pack(mat, counts=counts,
                                      interpret=interpret)
        return {"bits": bits, "scales": scales}

    def rows_unpack(self, payload, *, interpret=None, plan=None):
        from repro.kernels import ops as kops
        return kops.sign_unpack(payload["bits"], payload["scales"],
                                interpret=interpret)

    def wire_bytes(self, n):
        return sign_wire_bytes(n, self.block)


@dataclasses.dataclass(frozen=True)
class TopKCodec(WireCodec):
    """Blockwise top-k: W = ceil(fraction·block) (idx, val) slots per block;
    active slots follow each block's true length."""

    name: str = "topk"
    fraction: float = 0.01
    block: int = SIGN_BLOCK

    @property
    def width(self) -> int:
        return topk_width(self.fraction, self.block)

    @property
    def rows_supported(self):
        # the select kernel unrolls W per-row argmax steps; its unroll cap
        # is the kernel's to own (lazy import: core stays kernel-free)
        from repro.kernels.topk_select import MAX_WIDTH
        return self.width <= MAX_WIDTH

    def pack(self, x, key=None):
        rows, counts = _to_rows(x, self.block)
        idx, vals = topk_rows(rows, counts, fraction=self.fraction,
                              width=self.width)
        return {"idx": idx, "vals": vals}

    def unpack(self, payload, n, shape, dtype, key=None):
        q = topk_rows_unpack(payload["idx"], payload["vals"], self.block)
        return q.reshape(-1)[:n].reshape(shape).astype(dtype)

    def rows_pack(self, mat, counts=None, *, interpret=None, plan=None):
        from repro.kernels import ops as kops
        idx, vals = kops.topk_pack(mat, counts=counts,
                                   fraction=self.fraction,
                                   interpret=interpret)
        return {"idx": idx, "vals": vals}

    def rows_unpack(self, payload, *, interpret=None, plan=None):
        from repro.kernels import ops as kops
        return kops.topk_unpack(payload["idx"], payload["vals"],
                                interpret=interpret)

    def wire_bytes(self, n):
        nb = -(-int(n) // self.block)
        return nb * self.width * (4 + 4)     # int32 idx + f32 val per slot


@dataclasses.dataclass(frozen=True)
class RandKCodec(WireCodec):
    """Random-k with key-derived coordinates: sender and receiver run the
    same ``derive_idx(key, n)``, so only the k values ship — zero index
    bytes on the wire.  The key folds (leaf, round) but *not* the worker
    id: it is shared knowledge across the whole graph."""

    name: str = "randk"
    fraction: float = 0.01

    def k(self, n: int) -> int:
        return max(1, int(np.ceil(self.fraction * int(n))))

    def derive_idx(self, key, n: int) -> jnp.ndarray:
        if key is None:
            key = jax.random.PRNGKey(0)
        return jax.random.choice(key, n, shape=(self.k(n),), replace=False)

    def pack(self, x, key=None):
        flat = x.reshape(-1).astype(jnp.float32)
        idx = self.derive_idx(key, flat.shape[0])
        return {"idx": idx, "vals": flat[idx]}

    def unpack(self, payload, n, shape, dtype, key=None):
        idx = payload.get("idx")
        if idx is None:                      # wire payload: re-derive
            idx = self.derive_idx(key, n)
        flat = jnp.zeros((n,), jnp.float32).at[idx].set(payload["vals"])
        return flat.reshape(shape).astype(dtype)

    def wire(self, payload):
        return {"vals": payload["vals"]}

    def wire_bytes(self, n):
        return self.k(n) * 4


@dataclasses.dataclass(frozen=True)
class QSGDCodec(WireCodec):
    """Blockwise s-level quantization, bit-packed uintN levels + one f32
    norm per block (deterministic nearest-rounding contraction variant)."""

    name: str = "qsgd"
    levels: int = 7
    block: int = SIGN_BLOCK

    @property
    def bits(self) -> int:
        return qsgd_bits(self.levels)

    @property
    def rows_supported(self):
        return True

    def pack(self, x, key=None):
        rows, _ = _to_rows(x, self.block)
        packed, norms = qsgd_rows(rows, levels=self.levels)
        return {"levels": packed, "norms": norms}

    def unpack(self, payload, n, shape, dtype, key=None):
        q = qsgd_rows_unpack(payload["levels"], payload["norms"],
                             levels=self.levels, block=self.block)
        return q.reshape(-1)[:n].reshape(shape).astype(dtype)

    def rows_pack(self, mat, counts=None, *, interpret=None, plan=None):
        from repro.kernels import ops as kops
        packed, norms = kops.qsgd_pack(mat, levels=self.levels,
                                       interpret=interpret)
        return {"levels": packed, "norms": norms}

    def rows_unpack(self, payload, *, interpret=None, plan=None):
        from repro.kernels import ops as kops
        return kops.qsgd_unpack(payload["levels"], payload["norms"],
                                levels=self.levels, interpret=interpret)

    def wire_bytes(self, n):
        nb = -(-int(n) // self.block)
        return nb * (self.block * self.bits // 8 + 4)


@dataclasses.dataclass(frozen=True)
class SparseRowsCodec(WireCodec):
    """Touched-rows wire: (row index, row values) pairs — push-by-key for
    embedding-dominated workloads.

    Each leaf is viewed as its blockwise ``(nb, block)`` rows (identical to
    the flatten-once kernel rows when ``block == LANE``); the payload ships
    the ``R = min(max_rows, nb)`` top rows by squared L2 norm as an i32
    ``rowidx`` vector plus the ``inner`` codec's payload of the gathered
    ``(R, block)`` row matrix (``"f32"`` raw rows / ``"sign"`` /
    ``"qsgd"``).  Untouched rows decode to exact 0, so when at most R rows
    are non-zero — the power-law embedding regime — the f32 wire is
    *lossless* (Q(x) = x) at ``R·(4 + 4·block)`` bytes instead of ``4·n``.

    Rows domain: selection and the inner codec run in jnp on the compact
    gathered matrix (identical code to the per-leaf path, so the two
    domains are bit-exact by construction); the Pallas gather/scatter pair
    (``repro.kernels.row_gather``) only moves rows.  Both rows entry points
    require the :class:`~repro.kernels.ops.KernelPlan`: per-leaf budgets
    come from the plan's row segments, keeping kernel payloads identical
    to the per-leaf payloads leaf by leaf.  ``rows_wire`` is the identity —
    the payload is already compact, nothing to trim.
    """

    name: str = "sparse_rows"
    max_rows: int = 64
    inner: str = "f32"     # "f32" | "sign" | "qsgd"
    levels: int = 7        # inner="qsgd" quantization levels
    block: int = SIGN_BLOCK

    @property
    def rows_supported(self):
        return True

    def budget(self, n: int) -> int:
        """Static shipped-row count for an n-element leaf."""
        return min(self.max_rows, -(-int(n) // self.block))

    def plan_budget(self, plan) -> int:
        """Total shipped rows S on a kernel plan: Σ per-leaf budgets."""
        return sum(min(self.max_rows, s.n_rows) for s in plan.slots)

    def plan_select(self, mat, plan) -> jnp.ndarray:
        """Global touched-row indices on the flatten-once layout,
        (..., S) i32: per-leaf top-budget selection (squared-L2 row norm,
        sorted ascending) offset by the leaf's ``row_start``.  Leaf row
        segments are disjoint and ordered, so the concatenation is
        globally distinct and sorted — the scatter kernel's contract."""
        norms = _tree_sum(jnp.square(mat.astype(jnp.float32)))
        parts = []
        for s in plan.slots:
            seg = norms[..., s.row_start:s.row_start + s.n_rows]
            _, li = jax.lax.top_k(seg, min(self.max_rows, s.n_rows))
            parts.append(jnp.sort(li, axis=-1).astype(jnp.int32)
                         + jnp.int32(s.row_start))
        return jnp.concatenate(parts, axis=-1)

    # -- inner (value) codec on the gathered (..., R, block) row matrix ----
    # Row-independent jnp in *both* domains (kernels only move rows), so
    # the per-leaf and kernel payload values are bit-exact for free.
    def _inner_pack(self, g, gcnt) -> Payload:
        lead, s = g.shape[:-2], g.shape[-2]
        if self.inner == "f32":
            return {"rows": g.astype(jnp.float32)}
        g2 = g.reshape(-1, self.block)
        if self.inner == "sign":
            bits, scales = sign_rows(g2, gcnt.reshape(-1))
            return {"bits": bits.reshape(lead + (s, self.block // 8)),
                    "scales": scales.reshape(lead + (s,))}
        if self.inner == "qsgd":
            packed, norms = qsgd_rows(g2, levels=self.levels)
            return {"levels": packed.reshape(lead + (s, packed.shape[-1])),
                    "norms": norms.reshape(lead + (s,))}
        raise ValueError(f"unknown sparse inner codec {self.inner!r}")

    def _inner_unpack(self, payload: Payload) -> jnp.ndarray:
        if self.inner == "f32":
            return payload["rows"].astype(jnp.float32)
        if self.inner == "sign":
            bits = payload["bits"]
            lead, s = bits.shape[:-2], bits.shape[-2]
            g = sign_rows_unpack(bits.reshape(-1, self.block // 8),
                                 payload["scales"].reshape(-1),
                                 block=self.block)
            return g.reshape(lead + (s, self.block))
        if self.inner == "qsgd":
            lv = payload["levels"]
            lead, s = lv.shape[:-2], lv.shape[-2]
            g = qsgd_rows_unpack(lv.reshape(-1, lv.shape[-1]),
                                 payload["norms"].reshape(-1),
                                 levels=self.levels, block=self.block)
            return g.reshape(lead + (s, self.block))
        raise ValueError(f"unknown sparse inner codec {self.inner!r}")

    def _row_payload_bytes(self) -> int:
        """Exact wire bytes per shipped row, excluding the i32 index."""
        if self.inner == "f32":
            return 4 * self.block
        if self.inner == "sign":
            return self.block // 8 + 4
        if self.inner == "qsgd":
            return self.block * qsgd_bits(self.levels) // 8 + 4
        raise ValueError(f"unknown sparse inner codec {self.inner!r}")

    # -- per-leaf (tree) domain -------------------------------------------
    def pack(self, x, key=None):
        rows, counts = _to_rows(x, self.block)
        idx = sparse_row_select(rows, self.budget(x.size))
        g = jnp.take(rows, idx, axis=0)
        gcnt = jnp.take(counts, idx, axis=0)
        return {"rowidx": idx, **self._inner_pack(g, gcnt)}

    def unpack(self, payload, n, shape, dtype, key=None):
        nb = -(-int(n) // self.block)
        g = self._inner_unpack(payload)
        q = jnp.zeros((nb, self.block), jnp.float32).at[
            payload["rowidx"]].add(g)
        return q.reshape(-1)[:n].reshape(shape).astype(dtype)

    # -- (rows, 1024) kernel domain ---------------------------------------
    def rows_pack(self, mat, counts=None, *, interpret=None, plan=None):
        if plan is None:
            raise ValueError("sparse_rows rows_pack needs the KernelPlan: "
                             "per-leaf row segments set the index budgets")
        from repro.kernels import ops as kops
        if counts is None:
            counts = plan.row_counts()
        idx = self.plan_select(mat, plan)
        g = kops.row_gather(mat, idx, counts=counts, interpret=interpret)
        gcnt = jnp.take(jnp.asarray(counts, jnp.float32).reshape(plan.rows),
                        idx, axis=0)
        return {"rowidx": idx, **self._inner_pack(g, gcnt)}

    def rows_unpack(self, payload, *, interpret=None, plan=None):
        if plan is None:
            raise ValueError("sparse_rows rows_unpack needs the KernelPlan: "
                             "the scatter extent is the plan's row count")
        from repro.kernels import ops as kops
        return kops.row_scatter(payload["rowidx"], self._inner_unpack(payload),
                                rows=plan.rows, interpret=interpret)

    def rows_wire(self, payload, plan):
        return dict(payload)         # already compact: every entry ships

    def rows_unwire(self, wire, plan):
        return dict(wire)

    # -- accounting --------------------------------------------------------
    def wire_bytes(self, n):
        return self.budget(n) * (4 + self._row_payload_bytes())


def make_codec(comp: Compressor) -> WireCodec:
    """The wire codec paired with a compressor instance."""
    if isinstance(comp, SignCompressor):
        return SignCodec(block=comp.block)
    if isinstance(comp, TopKCompressor):
        return TopKCodec(fraction=comp.fraction, block=comp.block)
    if isinstance(comp, RandKCompressor):
        return RandKCodec(fraction=comp.fraction)
    if isinstance(comp, QSGDCompressor):
        return QSGDCodec(levels=comp.levels, block=comp.block)
    if isinstance(comp, SparseRowsCompressor):
        return SparseRowsCodec(max_rows=comp.max_rows, inner=comp.inner,
                               levels=comp.levels, block=comp.block)
    if isinstance(comp, IdentityCompressor):
        return IdentityCodec()
    raise TypeError(f"no wire codec for compressor {comp!r}")


def payload_nbytes(payload: Payload) -> int:
    """Σ nbytes over a (possibly abstract) payload tree — the shipped-bytes
    side of the accounted ≡ shipped assertion."""
    return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
               for a in jax.tree_util.tree_leaves(payload))
