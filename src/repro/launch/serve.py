"""Serving launcher: batched greedy generation on a smoke-scale model.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --batch 4 \
      --prompt-len 16 --max-new 16
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import time

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_smoke_config
    from repro.models import make_model
    from repro.serve.serving import generate

    run = get_smoke_config(args.arch)
    model = make_model(run.model)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len),
        0, run.model.vocab)
    t0 = time.time()
    out = generate(model, params, prompts, args.max_new,
                   temperature=args.temperature)
    dt = time.time() - t0
    print(f"arch={args.arch} generated {out.shape} "
          f"({args.batch * args.max_new / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
