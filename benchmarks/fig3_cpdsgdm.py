"""Fig. 3: CPD-SGDM (sign-compressed) vs full-precision PD-SGDM (p=4).

Paper claim: CPD-SGDM converges to ≈ the same loss despite compressing
every communicated parameter to ~1 bit.
"""
from benchmarks.common import csv_row, make_opt, train_resnet
from repro.core import QSGDCompressor, SignCompressor, TopKCompressor


def main():
    results = {}
    cases = [
        ("pd_sgdm_p4_full", make_opt("pd_sgdm", p=4)),
        ("cpd_sgdm_p4_sign", make_opt("cpd_sgdm", p=4,
                                      compressor=SignCompressor(block=64))),
        ("cpd_sgdm_p4_qsgd4bit", make_opt("cpd_sgdm", p=4,
                                          # levels=7 is the 4-bit wire; 8
                                          # would round up to 8 bits/elem
                                          compressor=QSGDCompressor(levels=7))),
        ("cpd_sgdm_p4_top10pct", make_opt("cpd_sgdm", p=4, gamma=0.2,
                                          compressor=TopKCompressor(
                                              fraction=0.1))),
        ("choco_sgd_sign", make_opt("choco_sgd",
                                    compressor=SignCompressor(block=64))),
    ]
    for label, opt in cases:
        # fused round engine (choco_sgd has p=1: every "round" is one step)
        hist, s_per_step = train_resnet(opt, steps=70, log_every=5)
        results[label] = hist.loss[-1]
        csv_row(f"fig3/{label}", s_per_step * 1e6,
                f"final_loss={hist.loss[-1]:.4f};comm_mb={hist.comm_mb[-1]:.2f}")
    gap = abs(results["cpd_sgdm_p4_sign"] - results["pd_sgdm_p4_full"])
    csv_row("fig3/sign_vs_full_gap", 0.0, f"gap={gap:.4f}")
    return results


if __name__ == "__main__":
    main()
