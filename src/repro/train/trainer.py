"""Training loops.

``SimTrainer`` — single-process decentralized simulation (DenseComm, worker
dim stacked).  This is the paper-faithful experimental harness used by the
Fig. 1-3 benchmarks: any loss function (ResNet20 or an LM), any optimizer
from ``repro.core``, with per-round communication-cost accounting (MB on the
wire, honouring periodicity p, topology degree, and compression ratio).

``ShardedTrainer`` — drives the production ``TrainPack`` built by
``repro.launch.runtime`` (mesh-sharded, ppermute gossip), with checkpointing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.cpdsgdm import CPDSGDM
from repro.core.pdsgdm import PDSGDM

__all__ = ["SimTrainer", "History", "ShardedTrainer"]


@dataclasses.dataclass
class History:
    steps: List[int] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)
    eval_metric: List[float] = dataclasses.field(default_factory=list)

    def rows(self):
        for i, s in enumerate(self.steps):
            yield {"step": s, "loss": self.loss[i],
                   "comm_mb": self.comm_mb[i],
                   "eval": self.eval_metric[i] if self.eval_metric else None}


class SimTrainer:
    """Decentralized training simulation over K stacked workers."""

    def __init__(self, loss_fn: Callable, opt: PDSGDM):
        self.loss_fn = loss_fn
        self.opt = opt
        self._grad = jax.vmap(jax.value_and_grad(
            lambda p, b: loss_fn(p, b)[0]))

        def step_fn(state, params, batch):
            losses, grads = self._grad(params, batch)
            params, state = opt.step(state, params, grads)
            return params, state, losses.mean()

        self._step = jax.jit(step_fn)

    def bytes_per_round(self, params) -> int:
        return self.opt.bytes_per_comm_round(
            jax.tree_util.tree_map(lambda x: x[0], params))

    def train(self, params, batch_fn: Callable[[int], dict], steps: int,
              log_every: int = 10,
              eval_fn: Optional[Callable] = None,
              verbose: bool = False) -> tuple:
        state = self.opt.init(params)
        hist = History()
        per_round = self.bytes_per_round(params)
        comm_bytes = 0
        p = self.opt.config.p
        for t in range(steps):
            batch = batch_fn(t)
            params, state, loss = self._step(state, params, batch)
            if (t + 1) % p == 0:
                comm_bytes += per_round
            if t % log_every == 0 or t == steps - 1:
                hist.steps.append(t)
                hist.loss.append(float(loss))
                hist.comm_mb.append(comm_bytes / 2 ** 20)
                if eval_fn is not None:
                    avg = jax.tree_util.tree_map(
                        lambda x: x.mean(0, keepdims=True).repeat(
                            x.shape[0], 0), params)
                    hist.eval_metric.append(float(eval_fn(avg)))
                if verbose:
                    print(f"step {t:5d} loss {float(loss):.4f} "
                          f"comm {comm_bytes/2**20:.1f} MB")
        return params, state, hist


class ShardedTrainer:
    """Production loop over a ``TrainPack`` (sharded arrays, checkpoints)."""

    def __init__(self, pack, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 0):
        self.pack = pack
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every

    def train(self, key, batch_fn: Callable[[int], dict], steps: int,
              log_every: int = 10, verbose: bool = True) -> Dict:
        from repro.checkpoint import checkpoint as ckpt
        params, state = self.pack.init_fn(key)
        hist = History()
        t0 = time.time()
        for t in range(steps):
            batch = batch_fn(t)
            params, state, loss = self.pack.train_step(params, state, batch)
            if t % log_every == 0 or t == steps - 1:
                hist.steps.append(t)
                hist.loss.append(float(loss))
                hist.comm_mb.append(0.0)
                if verbose:
                    print(f"step {t:5d} loss {float(loss):.4f} "
                          f"({time.time()-t0:.1f}s)")
            if (self.ckpt_dir and self.ckpt_every
                    and (t + 1) % self.ckpt_every == 0):
                ckpt.save(self.ckpt_dir, t + 1, params=params,
                          opt_state={"m": state["m"], "step": state["step"]})
        return {"params": params, "state": state, "history": hist}
