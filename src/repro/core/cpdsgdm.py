"""CPD-SGDM — Communication-efficient PD-SGDM (paper Algorithm 2).

Local loop identical to PD-SGDM; at a communication round (mod(t+1,p)==0)::

    x⁽ᵏ⁾ₜ₊₁ = x⁽ᵏ⁾ₜ₊½ + γ Σⱼ w_kj (x̂⁽ʲ⁾ₜ − x̂⁽ᵏ⁾ₜ)        (line 6, consensus)
    q⁽ᵏ⁾ₜ   = Q(x⁽ᵏ⁾ₜ₊₁ − x̂⁽ᵏ⁾ₜ)                        (line 7, compress)
    send q⁽ᵏ⁾ / recv q⁽ʲ⁾ for j ∈ N_k                    (line 8)
    x̂⁽ʲ⁾ₜ₊₁ = x̂⁽ʲ⁾ₜ + q⁽ʲ⁾                              (line 9, error comp.)

Key TPU adaptation: what crosses the interconnect is the compressor's
*wire codec* payload (``repro.core.wire``) — bit-packed signs + scales,
(idx, val) top-k slots, key-derived rand-k values, or uintN QSGD levels —
never the full-precision tensor.  The HLO ``collective-permute`` genuinely
moves the compressed bytes for **every** operator, so the dry-run roofline
and the comm-MB accounting reflect the paper's compression claim rather
than modelling it (``bytes_per_comm_round`` is computed from the payload
array shapes themselves: accounted ≡ shipped by construction).

Three wire execution paths, one dispatch:

* **kernel wire** — codec has a (rows, 1024) Pallas format and its block
  equals the kernel lane: one flatten-once pack, payload sliced to the
  used rows, per-neighbour exchange, one unpack per source.  Used on both
  backends (DenseComm simulates the exchange, ShardedComm ships through
  ``ppermute``), and entirely matrix-domain inside ``kernel_round``.
* **per-leaf codec wire** — any codec, any block: jnp pack/unpack per
  leaf, the payload tree shipped generically through ``ppermute`` (rand-k
  ships only values; indices are re-derived from the shared round key).
* **legacy apply** (``packed_wire=False``) — Q applied leaf-wise, the f32
  result shipped at full precision; the debugging/ablation baseline.

Auxiliary copies: each worker stores x̂ for itself and for each neighbour
(``xhat_nbrs``), updated only from received compressed payloads —
neighbours' x̂ are never shipped at full precision (that would defeat the
point).  In the dense simulation backend all copies coincide, so only the
canonical stacked x̂ is stored.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from functools import partial

from repro.core.compression import Compressor, SignCompressor
from repro.core.gossip import (CommBackend, DenseComm, ShardedComm,
                               worker_mask_like)
from repro.core.pdsgdm import PDSGDM, PDSGDMConfig
from repro.core.wire import make_codec, wire_key

__all__ = ["CPDSGDMConfig", "CPDSGDM"]

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class CPDSGDMConfig(PDSGDMConfig):
    gamma: float = 0.4               # consensus step size γ (paper: 0.4/0.5)
    # ship the codec payload over the wire (False = legacy debug path:
    # apply Q leaf-wise and ship the full-precision f32 result)
    packed_wire: bool = True


class CPDSGDM(PDSGDM):
    """Algorithm 2.  Inherits the local momentum step from PD-SGDM."""

    def __init__(self, config: CPDSGDMConfig, comm: CommBackend,
                 compressor: Optional[Compressor] = None):
        super().__init__(config, comm)
        self.compressor = compressor if compressor is not None else SignCompressor()
        try:
            self.codec = make_codec(self.compressor)
        except TypeError:                # custom operator without a codec
            self.codec = None
        if config.overlap and isinstance(comm, ShardedComm):
            raise ValueError(
                "CPD-SGDM overlap=True is dense-only: the xhat_nbrs "
                "error-compensation copies must stay bitwise consistent "
                "with each owner's x̂ (Alg. 2 line 9), and a one-round-"
                "stale consensus breaks that replica contract — a copy-"
                "holder would mix a snapshot its owner has already moved "
                "past.  Run overlap with PD/MT/QG on the sharded backend, "
                "or CPD synchronously.")
        if config.overlap and config.use_kernel:
            raise ValueError(
                "CPD-SGDM overlap=True does not compose with use_kernel: "
                "the delayed consensus + codec wire run on the tree path "
                "(dense simulation only).")
        if isinstance(comm, ShardedComm) and comm.topology.name == "complete":
            raise ValueError(
                "CPD-SGDM sharded backend needs a shift-structured topology "
                "(ring/torus/exponential); 'complete' has no neighbour state.")
        if isinstance(comm, ShardedComm) and comm.topology.name == "hierarchical":
            raise ValueError(
                "CPD-SGDM does not compose with the sharded hierarchical "
                "backend: the xhat_nbrs error-compensation copies track "
                "per-neighbour wires, and the two-level round (exact intra "
                "psum + leader ppermute) has no per-edge codec lane.  Use "
                "PD/MT/QG with node_size (optionally with inter_codec), or "
                "run CPD on a flat topology.")
        if isinstance(comm, ShardedComm) and comm.period > 1:
            raise ValueError(
                "CPD-SGDM sharded backend requires a static topology: the "
                "xhat_nbrs error-compensation copies track a fixed neighbour "
                "set (Alg. 2 line 9).  Time-varying schedules run on the "
                "dense backend, or use PD-SGDM on the sharded one.")
        if (isinstance(comm, ShardedComm) and comm.membership is not None
                and comm.topology.perms):
            raise ValueError(
                "CPD-SGDM sharded elastic membership needs a "
                "shift-structured topology: perm graphs key no per-shift "
                "xhat_nbrs copies to commit-gate.")
        # Elastic membership: precompute the per-round commit masks —
        # worker s updates its x̂ (and ships q) in round l iff s and every
        # copy-holder of s (its out-neighbours) are active.  Otherwise the
        # update is skipped *symmetrically*: s's own x̂ stays put and the
        # pruned ppermute delivers zero payloads, which every codec decodes
        # to exactly 0, so stored neighbour copies never drift from the
        # owner's x̂ — the skipped round's drift is simply absorbed by the
        # next committed q (error feedback).
        if comm.membership is not None:
            Lc = comm.round_cycle
            self._commit_np = np.stack(
                [self._commit_mask(comm.topology_at(l), comm.active_at(l))
                 for l in range(Lc)])
            self._commit_jnp = jnp.asarray(self._commit_np)
        else:
            self._commit_np = None
            self._commit_jnp = None

    # -- elastic membership: commit masks ---------------------------------------
    @staticmethod
    def _commit_mask(top, act) -> np.ndarray:
        """(K,) bool: worker ``s`` commits its error-compensation update in
        a round where only ``act`` workers exchange."""
        act = np.asarray(act, dtype=bool)   # host: static mask  # lint: allow
        K = top.n_workers
        grid = top.axis_sizes
        ok = act.copy()
        for (ax, sh, _w) in top.shifts:
            if sh == 0:
                continue
            n = grid[ax]
            for s in range(K):
                # the copy-holder of s along (ax, sh) receives from d+sh=s
                idx = list(np.unravel_index(s, grid))
                idx[ax] = (idx[ax] - sh) % n
                d = int(np.ravel_multi_index(idx, grid))
                if d != s and not act[d]:
                    ok[s] = False
        for (ax, recv, _w) in top.perms:
            for d in range(K):
                idx = list(np.unravel_index(d, grid))
                idx[ax] = recv[idx[ax]]
                s = int(np.ravel_multi_index(idx, grid))
                if s != d and not act[d]:
                    ok[s] = False
        return ok

    def _commit_at(self, r):
        """(K,) bool commit mask under a traced round index."""
        tab = self._commit_jnp
        if tab.shape[0] == 1:
            return tab[0]
        return tab[jnp.mod(jnp.asarray(r), tab.shape[0])]

    # -- state -----------------------------------------------------------------
    def init(self, params):
        state = super().init(params)
        f32 = lambda t: tmap(lambda x: x.astype(jnp.float32), t)
        # x̂₀ = x₀: the first round's q then encodes only the local drift.
        state["xhat"] = f32(params)
        if isinstance(self.comm, ShardedComm):
            state["xhat_nbrs"] = {
                self._key(ax, sh): f32(params)
                for (ax, sh, _w) in self.comm.nonself_shifts()
            }
        return state

    @staticmethod
    def _key(ax: int, sh: int) -> str:
        return f"ax{ax}_sh{sh:+d}"

    # -- wire dispatch -----------------------------------------------------------
    # shared with MT-DSGDm's correction wire: one key derivation for every
    # codec payload in the repo (see repro.core.wire.wire_key)
    _wire_key = staticmethod(wire_key)

    def _kernel_wire(self) -> bool:
        """Whether the wire payload is produced by the Pallas codec kernels
        on the flatten-once (rows, 1024) layout — the production wire
        format on *both* backends (DenseComm simulates the exchange;
        ShardedComm ships the payload through ``ppermute``).  Requires the
        codec's block to equal the kernel lane width so the kernel blocks
        are identical to the per-leaf jnp codec's blocks."""
        from repro.kernels import ops as kops
        return (self.config.packed_wire and self.codec is not None
                and self.codec.rows_supported
                and self.codec.block == kops.LANE)

    def _payload_wire(self) -> bool:
        """Per-leaf jnp codec wire: the generic payload path for codecs
        without a (matching) kernel format — any sign/top-k/QSGD block
        width, rand-k, identity."""
        return self.config.packed_wire and self.codec is not None

    # -- legacy Q (packed_wire=False debug path) ----------------------------------
    def _apply_Q(self, tree, r):
        """Q leaf-wise; per-worker under the dense (worker-stacked) backend.
        Keys are the shared wire keys, so this path and the payload path
        draw identical rand-k coordinates."""
        comp = self.compressor

        def per_leaf(i, leaf):
            key = self._wire_key(r, i)
            if isinstance(self.comm, DenseComm):
                return jax.vmap(lambda xl: comp.apply(xl, key))(leaf)
            return comp.apply(leaf, key)

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        q = [per_leaf(i, l) for i, l in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(treedef, q)

    # -- communication round (Alg. 2 lines 6-9) ------------------------------------
    def comm_round(self, state, params):
        r = self.round_index(state)
        if (isinstance(self.comm, ShardedComm)
                and self.comm.membership is not None):
            return self._comm_round_elastic_sharded(state, params, r)
        return self._comm_round_at(state, params, r)

    def _comm_round_at(self, state, params, r):
        cfg = self.config
        gamma = jnp.float32(cfg.gamma)
        xhat = state["xhat"]

        # line 6: consensus from *locally stored* copies — zero communication.
        if isinstance(self.comm, ShardedComm):
            mixhat = tmap(lambda x: x * jnp.float32(self.comm.self_weight()), xhat)
            for (ax, sh, w) in self.comm.nonself_shifts():
                nbr = state["xhat_nbrs"][self._key(ax, sh)]
                mixhat = tmap(lambda a, b: a + jnp.float32(w) * b, mixhat, nbr)
        else:
            mixhat = self.comm.mix(xhat, r=r)
        params_new = tmap(
            lambda x, mh, h: (x.astype(jnp.float32) + gamma * (mh - h)).astype(x.dtype),
            params, mixhat, xhat)

        diff = tmap(lambda x, h: x.astype(jnp.float32) - h, params_new, xhat)

        new_state = dict(state)
        if self._kernel_wire():
            self._comm_kernel_wire(new_state, xhat, diff)
        elif self._payload_wire():
            self._comm_payload_wire(new_state, xhat, diff, r)
        else:
            q = self._apply_Q(diff, r)
            new_state["xhat"] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                                     xhat, q)
            if isinstance(self.comm, ShardedComm):
                nbrs = dict(state["xhat_nbrs"])
                for (ax, sh, _w) in self.comm.nonself_shifts():
                    k = self._key(ax, sh)
                    q_recv = self.comm.receive_tree(q, ax, sh)
                    nbrs[k] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                                   nbrs[k], q_recv)
                new_state["xhat_nbrs"] = nbrs

        # Elastic membership, dense backend: commit-gate the x̂ update so
        # the canonical copies stay in lock-step with what the sharded
        # backend's stored-copy protocol would hold (a non-committing
        # worker's x̂ is frozen; its drift rides into the next q).  The
        # consensus above already used the masked W.
        if (isinstance(self.comm, DenseComm)
                and self.comm.membership is not None):
            cm = self._commit_at(r)
            new_state["xhat"] = tmap(
                lambda h_new, h_old: jnp.where(
                    worker_mask_like(cm, h_new), h_new, h_old),
                new_state["xhat"], xhat)

        return params_new, new_state

    # -- overlapped rounds (dense backend) --------------------------------------
    # The in-flight payload is the x̂ snapshot cut after the previous
    # round's error-compensation update (line 9): x̂ only moves at round
    # boundaries, so the stale consensus γ(W̃·x̂_buf − x̂_buf) lands the
    # same consensus mass as the synchronous line 6 — but the mix is
    # issued at round start with no dependence on the round's compute, and
    # under elastic membership the mask is the *delivery* round's
    # (payload from a worker that died in flight is dropped with
    # renormalization).  The q wire (lines 7-9) stays at the boundary: q
    # encodes the round's own drift and cannot be issued early.
    def overlap_begin(self, state):
        mix = state["mix"]
        r = self.round_index(state)
        gate = (mix["phase"] > 0).astype(jnp.float32)
        gamma = jnp.float32(self.config.gamma)
        mixed = self.comm.stale_mix(mix["buf"], r=r)
        dx = tmap(lambda mh, h: gamma * (mh - h) * gate, mixed, mix["buf"])
        return {"dx": dx}

    def overlap_apply(self, state, params, delta):
        r = self.round_index(state)
        xhat = state["xhat"]
        params_new = tmap(
            lambda x, d: (x.astype(jnp.float32) + d).astype(x.dtype),
            params, delta["dx"])
        diff = tmap(lambda x, h: x.astype(jnp.float32) - h,
                    params_new, xhat)
        new_state = dict(state)
        if self._kernel_wire():
            self._comm_kernel_wire(new_state, xhat, diff)
        elif self._payload_wire():
            self._comm_payload_wire(new_state, xhat, diff, r)
        else:
            q = self._apply_Q(diff, r)
            new_state["xhat"] = tmap(
                lambda h, qq: h + qq.astype(jnp.float32), xhat, q)
        if self.comm.membership is not None:
            cm = self._commit_at(r)
            new_state["xhat"] = tmap(
                lambda h_new, h_old: jnp.where(
                    worker_mask_like(cm, h_new), h_new, h_old),
                new_state["xhat"], xhat)
        new_state["mix"] = self._snapshot_mix(new_state, params_new)
        return params_new, new_state

    def _snapshot_mix(self, state, params):
        # the payload is x̂ (post line-9), not the params: line 6's
        # consensus mixes x̂ copies
        return {"buf": state["xhat"], "phase": jnp.ones((), jnp.int32)}

    # -- elastic membership round (sharded) -----------------------------------------
    def _comm_round_elastic_sharded(self, state, params, r):
        """Select round ``r``'s liveness pattern with ``lax.switch`` — each
        branch is a statically-masked round, so all patterns live in one
        compiled executable, exactly like the topology-schedule programs."""
        Lc = self.comm.round_cycle
        if Lc == 1:
            return self._comm_round_masked(0, state, params, r)
        idx = jnp.mod(jnp.asarray(r, jnp.int32), Lc)
        branches = [partial(self._comm_round_masked, l) for l in range(Lc)]
        return jax.lax.switch(idx, branches, state, params, r)

    def _comm_round_masked(self, l, state, params, r):
        """Alg. 2 lines 6-9 with only round ``l``'s active workers
        exchanging: consensus over stored copies with dead in-neighbours
        masked (lost mass to self, rows stay stochastic), commit-gated x̂
        updates, and payload ppermutes pruned to committing sources."""
        comm = self.comm
        act = comm.active_at(l)
        if act.all():
            return self._comm_round_at(state, params, r)
        commit = self._commit_np[l]
        cfg = self.config
        gamma = jnp.float32(cfg.gamma)
        xhat = state["xhat"]
        top = comm.topology_at(l)
        n = top.n_workers
        idx = jax.lax.axis_index(comm.axis_names[0])
        ks = np.arange(n)

        # line 6: consensus from stored copies, per-edge coefficients from
        # the shift entries themselves (aliasing-safe — never read off the
        # masked matrix), dead edges zeroed, lost mass folded into self.
        off = np.zeros(n)
        terms = []
        for (ax, sh, w) in comm.nonself_shifts():
            if sh % n == 0:   # self-aliased shift: its copy IS own x̂ —
                continue      # absorbed by the 1 − Σ diagonal below
            src = (ks + sh) % n
            coeff = np.where(act & act[src], w, 0.0)
            off += coeff
            terms.append((self._key(ax, sh),
                          jnp.asarray(coeff.astype(np.float32))[idx]))
        diag = jnp.asarray((1.0 - off).astype(np.float32))[idx]
        mixhat = tmap(lambda h: h * diag, xhat)
        for key, cv in terms:
            mixhat = tmap(lambda a, b: a + cv * b,
                          mixhat, state["xhat_nbrs"][key])
        params_new = tmap(
            lambda x, mh, h: (x.astype(jnp.float32)
                              + gamma * (mh - h)).astype(x.dtype),
            params, mixhat, xhat)
        diff = tmap(lambda x, h: x.astype(jnp.float32) - h, params_new, xhat)

        commit_self = jnp.asarray(commit)[idx]
        new_state = dict(state)
        if self._kernel_wire():
            self._comm_kernel_wire_masked(new_state, xhat, diff,
                                          commit, commit_self)
        elif self._payload_wire():
            self._comm_payload_wire_masked(new_state, xhat, diff, r,
                                           commit, commit_self)
        else:
            q = self._apply_Q(diff, r)
            new_state["xhat"] = tmap(
                lambda h, qq: jnp.where(commit_self,
                                        h + qq.astype(jnp.float32), h),
                xhat, q)
            nbrs = dict(state["xhat_nbrs"])
            for (ax, sh, _w) in comm.nonself_shifts():
                k = self._key(ax, sh)
                q_recv = tmap(
                    lambda leaf: comm._receive_from_committed(
                        leaf, ax, sh, commit), q)
                nbrs[k] = tmap(lambda h, qq: h + qq.astype(jnp.float32),
                               nbrs[k], q_recv)
            new_state["xhat_nbrs"] = nbrs
        return params_new, new_state

    def _comm_kernel_wire_masked(self, new_state, xhat, diff,
                                 commit, commit_self):
        """Kernel-wire lines 7-9 under membership: identical to
        :meth:`_comm_kernel_wire` except the x̂ update is commit-gated and
        each neighbour exchange is pruned to committing sources — whose
        receivers decode the zero payload to exactly 0."""
        from repro.kernels import ops as kops
        plan = kops.KernelPlan.for_tree(diff, worker_dim=False)
        interp = self.config.kernel_interpret
        payload = self.codec.rows_pack(plan.flatten(diff),
                                       counts=plan.row_counts(),
                                       interpret=interp, plan=plan)
        q_self = plan.unflatten(self.codec.rows_unpack(payload,
                                                       interpret=interp,
                                                       plan=plan),
                                dtype=jnp.float32)
        new_state["xhat"] = tmap(
            lambda h, q: jnp.where(commit_self, h + q, h), xhat, q_self)
        wire = self.codec.rows_wire(payload, plan)
        nbrs = dict(new_state["xhat_nbrs"])
        for (ax, sh, _w) in self.comm.nonself_shifts():
            k = self._key(ax, sh)
            recv = self.codec.rows_unwire(
                {name: self.comm._receive_from_committed(arr, ax, sh, commit)
                 for name, arr in wire.items()}, plan)
            q_recv = plan.unflatten(
                self.codec.rows_unpack(recv, interpret=interp, plan=plan),
                dtype=jnp.float32)
            nbrs[k] = tmap(lambda h, q: h + q, nbrs[k], q_recv)
        new_state["xhat_nbrs"] = nbrs

    def _comm_payload_wire_masked(self, new_state, xhat, diff, r,
                                  commit, commit_self):
        """Per-leaf codec wire under membership: commit-gated x̂, pruned
        payload ppermutes (zero payloads decode to 0 for every codec)."""
        codec = self.codec
        leaves, treedef = jax.tree_util.tree_flatten(diff)
        payloads, keys, q_self = [], [], []
        for i, leaf in enumerate(leaves):
            key = self._wire_key(r, i)
            payload = codec.pack(leaf, key)
            q = codec.unpack(payload, leaf.size, leaf.shape, jnp.float32,
                             key=key)
            payloads.append(payload)
            keys.append(key)
            q_self.append(q)
        new_state["xhat"] = jax.tree_util.tree_unflatten(
            treedef, [jnp.where(commit_self, h + q, h) for h, q in zip(
                treedef.flatten_up_to(xhat), q_self)])
        nbrs = dict(new_state["xhat_nbrs"])
        for (ax, sh, _w) in self.comm.nonself_shifts():
            k = self._key(ax, sh)
            q_recv = []
            for leaf, payload, key in zip(leaves, payloads, keys):
                recv = self.comm.receive_payload_committed(
                    codec.wire(payload), ax, sh, commit)
                q_recv.append(codec.unpack(recv, leaf.size, leaf.shape,
                                           jnp.float32, key=key))
            nbrs[k] = jax.tree_util.tree_unflatten(
                treedef, [h + q for h, q in zip(
                    treedef.flatten_up_to(nbrs[k]), q_recv)])
        new_state["xhat_nbrs"] = nbrs

    def _comm_kernel_wire(self, new_state, xhat, diff):
        """Lines 7-9 on the flatten-once kernel layout: one Pallas codec
        pack, one payload tree per neighbour exchange, sliced to the rows
        that carry data so the wire bytes equal the accounted blocks
        exactly (alignment padding never ships)."""
        from repro.kernels import ops as kops
        dense = isinstance(self.comm, DenseComm)
        plan = kops.KernelPlan.for_tree(diff, worker_dim=dense)
        interp = self.config.kernel_interpret
        payload = self.codec.rows_pack(plan.flatten(diff),
                                       counts=plan.row_counts(),
                                       interpret=interp, plan=plan)
        q_self = plan.unflatten(self.codec.rows_unpack(payload,
                                                       interpret=interp,
                                                       plan=plan),
                                dtype=jnp.float32)
        new_state["xhat"] = tmap(lambda h, q: h + q, xhat, q_self)
        if isinstance(self.comm, ShardedComm):
            wire = self.codec.rows_wire(payload, plan)
            nbrs = dict(new_state["xhat_nbrs"])
            for (ax, sh, _w) in self.comm.nonself_shifts():
                k = self._key(ax, sh)
                recv = self.codec.rows_unwire(
                    {name: self.comm._receive_from(arr, ax, sh)
                     for name, arr in wire.items()}, plan)
                q_recv = plan.unflatten(
                    self.codec.rows_unpack(recv, interpret=interp, plan=plan),
                    dtype=jnp.float32)
                nbrs[k] = tmap(lambda h, q: h + q, nbrs[k], q_recv)
            new_state["xhat_nbrs"] = nbrs

    def _comm_payload_wire(self, new_state, xhat, diff, r):
        """Lines 7-9 with per-leaf jnp codec payloads: the generic wire for
        every operator and block width.  DenseComm packs/unpacks per
        stacked worker (simulating the exchange); ShardedComm ships each
        payload's :meth:`~repro.core.wire.WireCodec.wire` entries through
        one ``ppermute`` each — rand-k indices never cross the wire."""
        codec = self.codec
        dense = isinstance(self.comm, DenseComm)
        leaves, treedef = jax.tree_util.tree_flatten(diff)
        payloads, keys, q_self = [], [], []
        for i, leaf in enumerate(leaves):
            key = self._wire_key(r, i)
            shape = leaf.shape[1:] if dense else leaf.shape
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            if dense:
                payload = jax.vmap(lambda xl: codec.pack(xl, key))(leaf)
                q = jax.vmap(lambda p: codec.unpack(p, n, shape,
                                                    jnp.float32, key=key)
                             )(payload)
            else:
                payload = codec.pack(leaf, key)
                q = codec.unpack(payload, n, shape, jnp.float32, key=key)
            payloads.append(payload)
            keys.append(key)
            q_self.append(q)
        new_state["xhat"] = jax.tree_util.tree_unflatten(
            treedef, [h + q for h, q in zip(
                treedef.flatten_up_to(xhat), q_self)])
        if isinstance(self.comm, ShardedComm):
            nbrs = dict(new_state["xhat_nbrs"])
            for (ax, sh, _w) in self.comm.nonself_shifts():
                k = self._key(ax, sh)
                q_recv = []
                for leaf, payload, key in zip(leaves, payloads, keys):
                    recv = self.comm.receive_payload(codec.wire(payload),
                                                     ax, sh)
                    q_recv.append(codec.unpack(recv, leaf.size, leaf.shape,
                                               jnp.float32, key=key))
                nbrs[k] = jax.tree_util.tree_unflatten(
                    treedef, [h + q for h, q in zip(
                        treedef.flatten_up_to(nbrs[k]), q_recv)])
            new_state["xhat_nbrs"] = nbrs

    # -- kernel round (flatten-once matrix domain) --------------------------------
    @property
    def kernel_comm_supported(self) -> bool:
        """Matrix-domain comm needs the kernel wire format — and full
        membership: under churn the round falls back to the tree comm at
        the boundary, where the commit-gated paths live.  Other
        compressors fall back likewise."""
        return self._kernel_wire() and self.comm.membership is None

    def mat_state(self, plan, state) -> dict:
        mats = super().mat_state(plan, state)
        if self._kernel_wire():
            mats["xhat"] = plan.flatten(state["xhat"])
            if isinstance(self.comm, ShardedComm):
                mats["xhat_nbrs"] = {k: plan.flatten(v)
                                     for k, v in state["xhat_nbrs"].items()}
        return mats

    def unmat_state(self, plan, mats, state, step) -> dict:
        new_state = super().unmat_state(plan, mats, state, step)
        if "xhat" in mats:
            new_state["xhat"] = plan.unflatten(mats["xhat"],
                                               dtype=jnp.float32)
        if "xhat_nbrs" in mats:
            new_state["xhat_nbrs"] = {
                k: plan.unflatten(v, dtype=jnp.float32)
                for k, v in mats["xhat_nbrs"].items()}
        return new_state

    def comm_round_mat(self, x_mat, mats, counts, r, *, plan=None):
        """Alg. 2 lines 6-9 entirely on the kernel layout: consensus from
        stored copies, one Pallas codec pack, the payload tree through the
        wire (trimmed to its wire extent by ``rows_wire`` — dense payloads
        drop alignment padding, sparse payloads are already compact),
        error-compensation updates — no tree rematerialization."""
        assert plan is not None, "CPD-SGDM matrix comm needs the KernelPlan"
        cfg = self.config
        gamma = jnp.float32(cfg.gamma)
        interp = cfg.kernel_interpret
        xhat = mats["xhat"]

        # line 6: consensus — zero communication (stored copies / dense W).
        if isinstance(self.comm, ShardedComm):
            mixhat = jnp.float32(self.comm.self_weight()) * xhat
            for (ax, sh, w) in self.comm.nonself_shifts():
                mixhat = mixhat + jnp.float32(w) * mats["xhat_nbrs"][
                    self._key(ax, sh)]
        else:
            mixhat = self.comm.mix(xhat, r=r)
        x_new = x_mat + gamma * (mixhat - xhat)

        # lines 7-9: codec pack on the matrix, payload on the wire.
        payload = self.codec.rows_pack(x_new - xhat, counts=counts,
                                       interpret=interp, plan=plan)
        new_mats = dict(mats)
        new_mats["xhat"] = xhat + self.codec.rows_unpack(payload,
                                                         interpret=interp,
                                                         plan=plan)
        if isinstance(self.comm, ShardedComm):
            wire = self.codec.rows_wire(payload, plan)
            nbrs = dict(mats["xhat_nbrs"])
            for (ax, sh, _w) in self.comm.nonself_shifts():
                k = self._key(ax, sh)
                recv = self.codec.rows_unwire(
                    {name: self.comm._receive_from(arr, ax, sh)
                     for name, arr in wire.items()}, plan)
                nbrs[k] = nbrs[k] + self.codec.rows_unpack(recv,
                                                           interpret=interp,
                                                           plan=plan)
            new_mats["xhat_nbrs"] = nbrs
        return x_new, new_mats

    # -- comm-cost model --------------------------------------------------------------
    def bytes_per_comm_round(self, params, r: int = 0) -> int:
        """Per-worker wire bytes for communication round ``r``.

        Codec wire: the *exact* payload — per leaf, the summed ``nbytes``
        of the codec's wire arrays (padding blocks included, they really
        ship), × the round's topology degree.  Accounted ≡ shipped by
        construction; asserted against the traced ppermute payloads in
        ``tests/test_wire.py``.  ``packed_wire=False`` ships the
        full-precision f32 q, and is charged as such.

        Elastic membership: CPD's wire is the q payload, shipped only by
        *committing* sources (each to its full copy-holder set — all
        active, by the commit rule), so the multiplier is
        ``degree × committers / K`` instead of the active-edge count."""
        from repro.core.gossip import gossip_bytes_per_round
        frac = 1.0
        if self._commit_np is not None:
            cm = self._commit_np[r % self._commit_np.shape[0]]
            frac = float(cm.sum()) / cm.shape[0]
        if self.config.packed_wire and self.codec is not None:
            payload = sum(
                self.codec.wire_bytes(int(np.prod(l.shape, dtype=np.int64)))
                for l in jax.tree_util.tree_leaves(params))
            base = self.comm.topology_at(r).degree * payload
            return base if frac == 1.0 else base * frac
        bits = (32.0 if self.codec is not None
                else self.compressor.wire_bits_per_element(
                    jax.tree_util.tree_leaves(params)[0].dtype))
        if self._commit_np is not None:
            elems = sum(int(np.prod(l.shape, dtype=np.int64))
                        for l in jax.tree_util.tree_leaves(params))
            base = self.comm.topology_at(r).degree * elems * bits / 8.0
            return int(base) if frac == 1.0 else float(base * frac)
        return gossip_bytes_per_round(params, self.comm,
                                      bits_per_element=bits, r=r)
