"""Topology sweep: static ring vs time-varying schedules, equal bytes.

A heterogeneous consensus-optimization problem over K=16 workers: worker k
minimizes ``0.5‖x − c_k‖²`` with worker-specific targets ``c_k`` (non-iid —
the regime where topology choice matters most, cf. "Momentum Tracking").
The global optimum is the mean of the targets, so decentralized progress
requires *mixing*: a topology that gossips poorly leaves workers parked at
their local targets with a large consensus distance.

All runs go through the fused round engine (SimTrainer / ``opt.round``).
For each topology we report the final global loss (loss of the worker
average at the true optimum-centred objective), the consensus distance
``mean_k ‖x_k − x̄‖``, the cumulative comm MB from the per-round degree
accounting, and the schedule's cycle spectral gap.

Equal-bytes comparison: a ring round sends 2 payloads/worker, a one-peer
exponential round sends 1 — so at the same step count one-peer uses *half*
the bytes.  The ``equal_bytes`` row therefore compares static ring at S
steps vs one-peer exp at 2·S steps (same cumulative MB on the wire) —
the regime where degree-1 schedules with hypercube-quality cycle mixing
shine.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import PDSGDM, PDSGDMConfig
from repro.core.gossip import DenseComm
from repro.core.topology import (alternating_axes_schedule,
                                 one_peer_exponential_schedule,
                                 random_matching_schedule, ring,
                                 static_schedule)
from repro.train.trainer import SimTrainer

K, D, P = 16, 64, 4
STEPS = 96          # 24 rounds (8 one-peer cycles)


def _targets():
    """Worker-specific quadratic targets: shared signal + worker offset."""
    base = jax.random.normal(jax.random.PRNGKey(3), (D,))
    offs = jax.random.normal(jax.random.PRNGKey(4), (K, D)) * 3.0
    return base[None, :] + offs


def loss_fn(params, batch):
    return 0.5 * jnp.mean((params["x"] - batch) ** 2), {}


def _run(comm, steps):
    targets = _targets()
    opt = PDSGDM(PDSGDMConfig(eta=0.2, mu=0.9, p=P), comm)
    trainer = SimTrainer(loss_fn, opt, rounds_per_log=steps // P)
    params0 = {"x": jnp.zeros((K, D))}
    t0 = time.time()
    params, _, hist = trainer.train(params0, lambda t: targets,
                                    steps, log_every=steps)
    wall = time.time() - t0
    x = np.asarray(params["x"], np.float64)
    xbar = x.mean(0)
    consensus = float(np.mean(np.linalg.norm(x - xbar, axis=1)))
    # global objective at the worker average: how close is x̄ to mean(c)?
    global_loss = float(0.5 * np.mean((xbar - np.asarray(targets).mean(0)) ** 2))
    return {"consensus": consensus, "global_loss": global_loss,
            "comm_mb": hist.comm_mb[-1], "wall_us": wall / steps * 1e6}


def main():
    sweeps = [
        ("static_ring", DenseComm(static_schedule(ring(K)))),
        ("one_peer_exp", DenseComm(one_peer_exponential_schedule(K))),
        ("alt_axes_4x4", DenseComm(alternating_axes_schedule((4, 4)))),
        ("random_matching", DenseComm(random_matching_schedule(K, 4, seed=0))),
    ]
    results = {}
    for name, comm in sweeps:
        r = _run(comm, STEPS)
        results[name] = r
        rho = (comm.schedule.cycle_rho if comm.schedule is not None
               else comm.topology.rho)
        csv_row(f"topology_sweep/{name}", r["wall_us"],
                f"global_loss={r['global_loss']:.5f};"
                f"consensus={r['consensus']:.4f};"
                f"comm_mb={r['comm_mb']:.3f};cycle_rho={rho:.4f}")

    # equal bytes-on-wire: ring degree 2 @ S steps == one-peer degree 1 @ 2S
    one_peer_2s = _run(DenseComm(one_peer_exponential_schedule(K)), 2 * STEPS)
    ring_r = results["static_ring"]
    assert abs(one_peer_2s["comm_mb"] - ring_r["comm_mb"]) < 1e-9, (
        one_peer_2s["comm_mb"], ring_r["comm_mb"])
    csv_row("topology_sweep/equal_bytes_one_peer_exp", one_peer_2s["wall_us"],
            f"comm_mb={one_peer_2s['comm_mb']:.3f};"
            f"consensus={one_peer_2s['consensus']:.4f};"
            f"consensus_ring_same_mb={ring_r['consensus']:.4f};"
            f"global_loss={one_peer_2s['global_loss']:.5f}")
    return results


if __name__ == "__main__":
    main()
