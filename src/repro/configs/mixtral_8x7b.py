"""mixtral-8x7b — Mixtral of Experts [arXiv:2401.04088].

32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 32000,
MoE 8 experts top-2, sliding-window attention (4096).
"""
from repro.configs.base import LayerSpec, ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="mixtral-8x7b", arch_type="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        n_experts=8, top_k=2, window=4096,
        pattern=(LayerSpec("attn", "moe"),),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2401.04088",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="B"),
                  optim=OptimCfg())
