"""Pallas kernels vs pure-jnp oracles: shape/dtype/hyper-param sweeps,
the flatten-once ``KernelPlan`` layout, and round-level equivalence of the
kernel execution path against the jnp round."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import default_interpret, ops, ref
from repro.kernels.gossip_mix import BLOCK_ROWS as GBR
from repro.kernels.gossip_mix import gossip_mix
from repro.kernels.momentum import BLOCK_ROWS as MBR
from repro.kernels.momentum import momentum_update
from repro.kernels.sign_compress import BLOCK_ROWS as SBR
from repro.kernels.sign_compress import sign_pack_pallas, sign_unpack_pallas


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("rows", [MBR, 2 * MBR, 4 * MBR])
@pytest.mark.parametrize("mu,wd,nesterov", [
    (0.0, 0.0, False), (0.9, 0.0, False), (0.9, 1e-4, False),
    (0.99, 1e-2, False), (0.9, 1e-4, True),
])
def test_momentum_kernel_sweep(rows, mu, wd, nesterov):
    k = jax.random.PRNGKey(rows + int(mu * 100))
    x = _rand(k, (rows, 1024))
    m = _rand(jax.random.fold_in(k, 1), (rows, 1024))
    g = _rand(jax.random.fold_in(k, 2), (rows, 1024))
    lr = 0.05
    xn, mn = momentum_update(x, m, g, lr, mu=mu, wd=wd, nesterov=nesterov)
    xr, mr = ref.momentum_update_ref(x, m, g, lr, mu=mu, wd=wd,
                                     nesterov=nesterov)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(mr), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows", [SBR, 3 * SBR])
def test_sign_pack_kernel_sweep(rows, dtype):
    x = _rand(jax.random.PRNGKey(rows), (rows, 1024), dtype)
    pk, sl = sign_pack_pallas(x.astype(jnp.float32))
    pr, sr = ref.sign_pack_ref(x.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(sl[:, 0]), np.asarray(sr),
                               rtol=1e-6)
    un = sign_unpack_pallas(pk, sl[:, 0])
    ur = np.asarray(ref.sign_unpack_ref(pr, sr)).reshape(rows, 1024)
    np.testing.assert_allclose(np.asarray(un), ur, rtol=1e-6)


def test_sign_kernel_matches_core_compressor():
    """Kernel semantics == repro.core.compression.SignCompressor exactly."""
    from repro.core.compression import SignCompressor
    rows = SBR
    x = _rand(jax.random.PRNGKey(0), (rows, 1024))
    pk, sl = ops.sign_pack(x)
    q = ops.sign_unpack(pk, sl[:, 0]).reshape(-1)
    q_ref = SignCompressor(block=1024).apply(x.reshape(-1))
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref), rtol=1e-6)


@pytest.mark.parametrize("n_nbrs", [1, 2, 4])
def test_gossip_mix_kernel(n_nbrs):
    k = jax.random.PRNGKey(n_nbrs)
    tensors = tuple(_rand(jax.random.fold_in(k, i), (GBR, 1024))
                    for i in range(n_nbrs + 1))
    w = tuple(1.0 / (n_nbrs + 1) for _ in range(n_nbrs + 1))
    out = gossip_mix(tensors, weights=w)
    want = ref.gossip_mix_ref(tensors, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_momentum_tree_wrapper_ragged_shapes():
    """Wrapper must round-trip padding across odd-shaped pytrees."""
    key = jax.random.PRNGKey(7)
    params = {
        "a": _rand(key, (13, 17)),
        "b": {"c": _rand(jax.random.fold_in(key, 1), (3,)),
              "d": _rand(jax.random.fold_in(key, 2), (2, 5, 7))},
    }
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    g = jax.tree_util.tree_map(lambda x: 0.3 * x, params)
    xn, mn = ops.momentum_update_tree(params, m, g, mu=0.9, lr=0.1,
                                      weight_decay=1e-3)
    def want(x, mm, gg):
        return ref.momentum_update_ref(x, mm, gg, 0.1, mu=0.9, wd=1e-3)[0]
    for ka, a in jax.tree_util.tree_leaves_with_path(params):
        pass
    wref = jax.tree_util.tree_map(want, params, m, g)
    for a, b in zip(jax.tree_util.tree_leaves(xn),
                    jax.tree_util.tree_leaves(wref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        assert a.shape == b.shape


def test_pdsgdm_use_kernel_matches_jnp_path():
    """PD-SGDM with use_kernel=True is numerically identical to the jnp path."""
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    K = 4
    params = {"w": _rand(jax.random.PRNGKey(0), (K, 33, 65))}
    grads = {"w": _rand(jax.random.PRNGKey(1), (K, 33, 65))}
    outs = []
    for use_kernel in (False, True):
        opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=4, weight_decay=1e-4,
                                  use_kernel=use_kernel), DenseComm(ring(K)))
        st = opt.init(params)
        p1, s1 = opt.local_step(st, params, grads)
        p2, _ = opt.local_step(s1, p1, grads)
        outs.append(p2["w"])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5)


# ---------------------------------------------------------------- KernelPlan
def _odd_trees():
    """Oddly-shaped, mixed-dtype pytrees (scalar, ragged, >1-row leaves)."""
    key = jax.random.PRNGKey(11)
    yield {"a": _rand(key, (13, 17)),
           "b": {"c": _rand(jax.random.fold_in(key, 1), (3,), jnp.bfloat16),
                 "d": _rand(jax.random.fold_in(key, 2), (2, 5, 7))},
           "e": jnp.float32(3.5)}
    yield [_rand(key, (1024,)), _rand(jax.random.fold_in(key, 3), (1025,)),
           _rand(jax.random.fold_in(key, 4), (300, 11), jnp.bfloat16)]
    yield {"one": _rand(key, (2, 3, 5, 7, 2))}


@pytest.mark.parametrize("i", range(3))
def test_kernel_plan_roundtrip_property(i):
    """flatten ∘ unflatten == identity (shapes, dtypes, values) for mixed
    f32/bf16 and oddly-shaped leaves, with and without a worker dim."""
    tree = list(_odd_trees())[i]
    plan = ops.KernelPlan.for_tree(tree)
    mat = plan.flatten(tree)
    assert mat.shape == (plan.rows, 1024) and mat.dtype == jnp.float32
    assert plan.rows % ops.PLAN_BLOCK_ROWS == 0
    back = plan.unflatten(mat)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # stacked-worker variant: same per-worker layout, leading dim preserved
    K = 3
    wtree = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (K,) + jnp.shape(x)), tree)
    wplan = ops.KernelPlan.for_tree(wtree, worker_dim=True)
    wmat = wplan.flatten(wtree)
    assert wmat.shape == (K, wplan.rows, 1024)
    np.testing.assert_array_equal(np.asarray(wmat[0]), np.asarray(mat))
    for a, b in zip(jax.tree_util.tree_leaves(wtree),
                    jax.tree_util.tree_leaves(wplan.unflatten(wmat))):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_kernel_plan_row_counts():
    """Per-leaf row alignment: every leaf starts a fresh row; counts carry
    the tail lengths and zero out pure alignment padding."""
    tree = {"a": jnp.zeros((1500,)), "b": jnp.zeros((4,)),
            "c": jnp.zeros((2048,))}
    plan = ops.KernelPlan.for_tree(tree)
    counts = np.asarray(plan.row_counts()).reshape(-1)
    # a: rows 0-1 (1024, 476); b: row 2 (4); c: rows 3-4 (1024, 1024)
    assert list(counts[:5]) == [1024.0, 476.0, 4.0, 1024.0, 1024.0]
    assert (counts[5:] == 0).all()
    assert plan.n_valid == 1500 + 4 + 2048


# ----------------------------------------------------- wire codec kernels
@pytest.mark.parametrize("fraction", [0.01, 0.05])
def test_topk_kernel_matches_rows_oracle_bit_exact(fraction):
    """Pallas top-k select/scatter == the jnp rows oracle (lax.top_k based)
    bit-exactly, including tie ordering, active-slot masking from counts,
    and pure-padding rows."""
    from repro.kernels.topk_select import BLOCK_ROWS as TBR
    rows = 2 * TBR
    x = _rand(jax.random.PRNGKey(1), (rows, 1024))
    x = x.at[3].set(0.0)                       # all-zero row: tie cascade
    counts = jnp.full((rows,), 1024.0).at[5].set(300.0).at[7].set(0.0)
    x = x.at[5, 300:].set(0.0).at[7].set(0.0)  # padding is zero by contract
    idx_k, val_k = ops.topk_pack(x, counts=counts, fraction=fraction,
                                 interpret=True)
    idx_r, val_r = ref.topk_rows_ref(x, counts, fraction=fraction)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    np.testing.assert_array_equal(np.asarray(val_k), np.asarray(val_r))
    assert (np.asarray(val_k[7]) == 0).all()   # padding row: placeholders
    un_k = ops.topk_unpack(idx_k, val_k, interpret=True)
    un_r = ref.topk_rows_unpack_ref(idx_r, val_r, 1024)
    np.testing.assert_array_equal(np.asarray(un_k), np.asarray(un_r))


@pytest.mark.parametrize("levels", [1, 7, 16])
def test_qsgd_kernel_matches_rows_oracle_bit_exact(levels):
    """Pallas QSGD quantize/dequantize == the jnp rows oracle bit-exactly
    for 2/4/8-bit packings, zero rows included."""
    from repro.kernels.qsgd_quant import BLOCK_ROWS as QBR
    rows = QBR
    x = _rand(jax.random.PRNGKey(2), (rows, 1024)) * 3.0
    x = x.at[0].set(0.0)                       # norm-0 row
    pk_k, nm_k = ops.qsgd_pack(x, levels=levels, interpret=True)
    pk_r, nm_r = ref.qsgd_rows_ref(x, levels=levels)
    np.testing.assert_array_equal(np.asarray(pk_k), np.asarray(pk_r))
    np.testing.assert_array_equal(np.asarray(nm_k[:, 0]), np.asarray(nm_r))
    un_k = ops.qsgd_unpack(pk_k, nm_k, levels=levels, interpret=True)
    un_r = ref.qsgd_rows_unpack_ref(pk_r, nm_r, levels=levels, block=1024)
    np.testing.assert_array_equal(np.asarray(un_k), np.asarray(un_r))
    assert (np.asarray(un_k[0]) == 0).all()


def test_codec_kernel_roundtrip_equals_compressor_apply():
    """Kernel-path pack∘unpack on the flatten-once layout == the per-leaf
    compressor semantics, bit-exactly, through the KernelPlan (ragged
    leaves, padded tails)."""
    from repro.core import QSGDCompressor, TopKCompressor
    from repro.core.wire import make_codec
    x = _rand(jax.random.PRNGKey(3), (2 * 1024 + 300,))
    plan = ops.KernelPlan.for_tree({"w": x})
    mat = plan.flatten({"w": x})
    for comp in [TopKCompressor(fraction=0.01), QSGDCompressor(levels=7)]:
        codec = make_codec(comp)
        payload = codec.rows_pack(mat, counts=plan.row_counts(),
                                  interpret=True)
        q = plan.unflatten(codec.rows_unpack(payload, interpret=True))["w"]
        np.testing.assert_array_equal(np.asarray(q),
                                      np.asarray(comp.apply(x)))


# ------------------------------------------------- padding-scale regression
def test_sign_pack_padded_tail_matches_oracle_bit_exact():
    """Regression: the kernel's tail-block scale must equal the padding-
    masked jnp oracle *bit-exactly* (it used to be deflated by
    n_valid/1024 because the kernel averaged over the full row)."""
    from repro.core import compression
    n = 2 * 1024 + 300                       # not a multiple of 1024
    x = _rand(jax.random.PRNGKey(3), (n,))
    plan = ops.KernelPlan.for_tree({"w": x})
    mat = plan.flatten({"w": x})
    pk, sl = ops.sign_pack(mat, counts=plan.row_counts())
    pr, sr = compression.sign_pack(x, 1024)  # the per-leaf oracle
    np.testing.assert_array_equal(np.asarray(pk[:3]), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(sl[:3, 0]), np.asarray(sr))
    assert (np.asarray(sl[3:]) == 0).all()   # alignment rows: scale 0
    # and the full quantized value round-trips identically
    q = plan.unflatten(ops.sign_unpack(pk, sl))["w"]
    q_ref = compression.sign_unpack(pr, sr, n, (n,), jnp.float32, 1024)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    # counts-aware matrix oracle agrees with the kernel everywhere
    pk2, sl2 = ref.sign_pack_rows_ref(mat, plan.row_counts())
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pk2))
    np.testing.assert_array_equal(np.asarray(sl), np.asarray(sl2))


def test_interpret_is_lazy_and_overridable():
    """INTERPRET is no longer pinned at import: the default is a function
    of the *current* backend, and every wrapper takes an override."""
    assert default_interpret() == (jax.default_backend() != "tpu")
    params = {"w": _rand(jax.random.PRNGKey(0), (9, 5))}
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    g = jax.tree_util.tree_map(lambda x: 0.1 * x, params)
    xa, _ = ops.momentum_update_tree(params, m, g, mu=0.9, lr=0.1,
                                     interpret=True)
    xb, _ = ops.momentum_update_tree(params, m, g, mu=0.9, lr=0.1,
                                     interpret=None)
    np.testing.assert_allclose(np.asarray(xa["w"]), np.asarray(xb["w"]),
                               atol=1e-7)
    out = ops.gossip_mix_tree((params, g), (0.5, 0.5), interpret=True)
    want = jax.tree_util.tree_map(lambda a, b: 0.5 * a + 0.5 * b, params, g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want["w"]),
                               atol=1e-6)


# --------------------------------------------------- round-level equivalence
def _run_rounds(opt, K=4, P=4):
    """Drive 2 fused rounds of ``opt`` on a fixed problem; return
    (params, state, losses)."""
    key = jax.random.PRNGKey(0)
    params = {"w1": _rand(key, (K, 33, 65)),
              "w2": _rand(jax.random.fold_in(key, 1), (K, 7)),
              "w3": _rand(jax.random.fold_in(key, 2), (K, 2, 5, 11))}

    def loss_fn(pp, b):
        return 0.5 * sum(jnp.sum((l - b[0, 0]) ** 2)
                         for l in jax.tree_util.tree_leaves(pp))

    grad = jax.vmap(jax.value_and_grad(loss_fn))

    def grads_fn(params, batch):
        losses, grads = grad(params, batch)
        return losses.mean(), grads

    batches = jnp.stack([
        _rand(jax.random.fold_in(jax.random.PRNGKey(9), t), (K, 2, 3))
        for t in range(P)])
    state = opt.init(params)
    roundj = jax.jit(lambda s, pp, bs: opt.round(s, pp, grads_fn, bs))
    for _ in range(2):
        params, state, losses = roundj(state, params, batches)
    return params, state, losses


def _assert_round_outputs_close(a, b, tol):
    """tol=0.0 demands bitwise equality; otherwise allclose(atol=tol)."""
    (pa, sa, la), (pb, sb, lb) = a, b
    leaves_a = jax.tree_util.tree_leaves((pa, sa["m"], la))
    leaves_b = jax.tree_util.tree_leaves((pb, sb["m"], lb))
    if "xhat" in sa:
        leaves_a += jax.tree_util.tree_leaves(sa["xhat"])
        leaves_b += jax.tree_util.tree_leaves(sb["xhat"])
    for x, y in zip(leaves_a, leaves_b):
        if tol == 0.0:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=tol)


def _round_equiv(opt_factory, tol):
    """use_kernel=True fused round == jnp fused round over 2 rounds."""
    K, P = 4, 4
    outs = [_run_rounds(opt_factory(K, P, uk), K, P) for uk in (False, True)]
    assert int(outs[1][1]["step"]) == 2 * P
    _assert_round_outputs_close(outs[0], outs[1], tol)


def test_kernel_round_equals_jnp_round_dense_pdsgdm():
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    _round_equiv(
        lambda K, P, uk: PDSGDM(
            PDSGDMConfig(eta=0.05, mu=0.9, p=P, weight_decay=1e-4,
                         use_kernel=uk), DenseComm(ring(K))),
        tol=2e-5)


@pytest.mark.parametrize("comp_name", ["sign", "topk", "qsgd", "sparse",
                                       "sparse+sign"])
def test_kernel_round_equals_perleaf_oracle_dense_cpdsgdm(comp_name):
    """CPD-SGDM with every kernel-wire codec: the Pallas pack on the
    flatten-once layout must reproduce the per-leaf jnp codec — per-leaf
    row alignment makes the blocks identical, so xhat trajectories
    coincide.  Three drivers of the same 2 rounds:

      (a) use_kernel=True   — matrix-domain kernel round;
      (b) use_kernel=False  — tree round, kernel-wire comm;
      (c) use_kernel=False with the kernel wire disabled — the *per-leaf
          jnp codec oracle* path.

    (b) ≡ (c) bit-exactly for sign and top-k (same jnp momentum, codec
    pack proven bit-equal to the kernel pack; sign's ±1·scale product and
    top-k's scatter are exact, so even fma contraction cannot move them).
    QSGD's decoded q ends in a true multiply, which XLA-CPU may contract
    into the consumer's x̂ + q add (an LLVM-level fma that no HLO-level
    barrier blocks) — its payload and every materialized value are still
    bit-exact (asserted at codec level elsewhere), so the round-level
    comparison allows ≤1 ulp.  (a) ≈ (b) to kernel-momentum tolerance.
    """
    from repro.core import (CPDSGDM, CPDSGDMConfig, QSGDCompressor,
                            SignCompressor, TopKCompressor)
    from repro.core.compression import SparseRowsCompressor
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    comp = {"sign": SignCompressor(),
            "topk": TopKCompressor(fraction=0.02),
            "qsgd": QSGDCompressor(levels=7),
            # max_rows=2 < the 3-row leaf: real selection, not pass-through
            "sparse": SparseRowsCompressor(max_rows=2),
            "sparse+sign": SparseRowsCompressor(max_rows=2,
                                                inner="sign")}[comp_name]
    K, P = 4, 4

    def make(uk):
        return CPDSGDM(
            CPDSGDMConfig(eta=0.05, mu=0.9, p=P, gamma=0.4,
                          weight_decay=1e-4, use_kernel=uk),
            DenseComm(ring(K)), comp)

    opt_mat, opt_tree, opt_leaf = make(True), make(False), make(False)
    assert opt_mat.kernel_comm_supported
    opt_leaf._kernel_wire = lambda: False      # force the per-leaf oracle
    out_mat = _run_rounds(opt_mat, K, P)
    out_tree = _run_rounds(opt_tree, K, P)
    out_leaf = _run_rounds(opt_leaf, K, P)
    # sparse wires stay at 0.0 too: the kernels only move rows, the inner
    # codec is the same jnp in both domains (sign ends in an exact ±1·scale
    # product); only qsgd's decode ends in a contractable multiply
    oracle_tol = 0.0 if comp_name != "qsgd" else 6e-7   # ≤1 ulp (fma)
    _assert_round_outputs_close(out_tree, out_leaf, tol=oracle_tol)
    _assert_round_outputs_close(out_mat, out_tree, tol=2e-5)


def test_kernel_round_csgdm_and_fallback_compressor():
    """The baselines ride the kernel round too: C-SGDM (grad all-reduce on
    the matrix, identity comm) and CPD with a non-kernel compressor (tree
    comm fallback at the round boundary) both match their jnp rounds."""
    from repro.core import (CPDSGDM, CPDSGDMConfig, SignCompressor,
                            make_optimizer)
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    K = 4
    params = {"w": _rand(jax.random.PRNGKey(0), (K, 130))}

    def grads_fn(pp, b):
        return jnp.float32(0.0), jax.tree_util.tree_map(lambda x: 0.3 * x, pp)

    outs = []
    for uk in (False, True):
        opt = make_optimizer("c_sgdm", DenseComm(ring(K)), eta=0.05, mu=0.9,
                             use_kernel=uk)
        st = opt.init(params)
        p1, _, _ = jax.jit(lambda s, pp, bs: opt.round(
            s, pp, grads_fn, bs))(st, params, jnp.zeros((1, 1)))
        outs.append(np.asarray(p1["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)

    outs = []
    for uk in (False, True):
        opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4,
                                    use_kernel=uk),
                      DenseComm(ring(K)), SignCompressor(block=64))
        assert not opt.kernel_comm_supported
        st = opt.init(params)
        p1, s1, _ = jax.jit(lambda s, pp, bs: opt.round(
            s, pp, grads_fn, bs))(st, params, jnp.zeros((2, 1)))
        outs.append((np.asarray(p1["w"]), np.asarray(s1["xhat"]["w"])))
    np.testing.assert_allclose(outs[0][0], outs[1][0], atol=1e-5)
    np.testing.assert_allclose(outs[0][1], outs[1][1], atol=1e-5)


def test_kernel_round_tail_no_gossip():
    """gossip=False (the trainer's fused tail) skips comm on the kernel
    path exactly as the jnp path does."""
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import ring
    K = 4
    params = {"w": _rand(jax.random.PRNGKey(0), (K, 33, 5))}

    def grads_fn(pp, b):
        return jnp.float32(0.0), jax.tree_util.tree_map(lambda x: 0.3 * x, pp)

    batches = jnp.zeros((2, 1))
    outs = []
    for uk in (False, True):
        opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=4, use_kernel=uk),
                     DenseComm(ring(K)))
        st = opt.init(params)
        p1, s1, _ = jax.jit(lambda s, pp, bs: opt.round(
            s, pp, grads_fn, bs, gossip=False))(st, params, batches)
        assert int(s1["step"]) == 2
        outs.append(p1["w"])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5)


_SCRIPT_SHARDED_KERNEL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg
    from repro.configs.shapes import InputShape, train_batch_arrays
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.runtime import build_train

    mcfg = ModelCfg(name="tiny", arch_type="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=128)
    # tp=1 mesh: the kernel layout's codec blocks (full per-worker leaves)
    # coincide with the per-device tree blocks, so the equivalence is tight
    # for every compressed wire (sign / top-k / QSGD), not just sign.
    for opt_name, comp in [("pd_sgdm", "sign"), ("cpd_sgdm", "sign"),
                           ("cpd_sgdm", "topk"), ("cpd_sgdm", "qsgd"),
                           ("cpd_sgdm", "sparse")]:
        finals = []
        for uk in (False, True):
            run = RunCfg(model=mcfg,
                         parallel=ParallelCfg(profile="A", remat="none"),
                         optim=OptimCfg(name=opt_name, eta=0.05, mu=0.9, p=3,
                                        weight_decay=1e-4, use_kernel=uk,
                                        compressor=comp,
                                        compressor_fraction=0.01,
                                        compressor_levels=7,
                                        compressor_rows=2))
            mesh = make_debug_mesh(8, 1)
            pack = build_train(run, mesh, InputShape("t", 16, 8, "train"))
            K = pack.layout.n_workers
            batches = [train_batch_arrays(mcfg, K, 1, 16,
                       jax.random.fold_in(jax.random.PRNGKey(1), t))
                       for t in range(3)]
            params, state = pack.init_fn(jax.random.PRNGKey(0))
            rb = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)
            for _ in range(2):
                params, state, losses = pack.train_round(params, state, rb)
            finals.append(jax.tree_util.tree_map(np.asarray, (params, state)))
        for a, b in zip(jax.tree_util.tree_leaves(finals[0]),
                        jax.tree_util.tree_leaves(finals[1])):
            np.testing.assert_allclose(a, b, rtol=2e-6, atol=2e-6)
        print("KERNEL_ROUND_EQ_OK", opt_name, comp)
""")


def _run_sub(script, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_kernel_round_equals_jnp_round_sharded():
    """use_kernel=True TrainPack.train_round == the jnp tree round on the
    ShardedComm backend (ppermute gossip, CPD's packed kernel wire) for
    each kernel-wire codec."""
    out = _run_sub(_SCRIPT_SHARDED_KERNEL)
    assert "KERNEL_ROUND_EQ_OK pd_sgdm sign" in out
    assert "KERNEL_ROUND_EQ_OK cpd_sgdm sign" in out
    assert "KERNEL_ROUND_EQ_OK cpd_sgdm topk" in out
    assert "KERNEL_ROUND_EQ_OK cpd_sgdm qsgd" in out
    assert "KERNEL_ROUND_EQ_OK cpd_sgdm sparse" in out
