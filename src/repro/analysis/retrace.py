"""Retrace guard: schedules must not recompile the fused round.

PR 2's topology schedules promise that every round of a time-varying
gossip graph runs from **one** compiled executable (``lax.switch`` over
precomputed ppermute programs / stacked-W indexing on the traced round
index).  :class:`CompileCounter` turns that promise into a checked
property: it counts XLA compilations while a full schedule sweep plus a
mid-cycle resume executes, and the round function must compile exactly
once.
"""
from __future__ import annotations

import logging
from typing import List

import jax
import jax.numpy as jnp

__all__ = ["CompileCounter", "check_schedule_no_retrace"]

# jax_log_compiles emits on these loggers ("Compiling <name> ..." /
# "Finished XLA compilation of <name> ...") — we listen on both so the
# count survives jax moving the message between them.
_COMPILE_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class _Capture(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.records: List[str] = []

    def emit(self, record):
        msg = record.getMessage()
        if "Compiling" in msg or "compilation" in msg:
            self.records.append(msg)


class CompileCounter:
    """Count XLA compilations inside a ``with`` block.

    >>> with CompileCounter() as cc:
    ...     run_full_schedule_sweep()
    >>> assert cc.count("train_round") == 1
    """

    def __enter__(self):
        self._handler = _Capture()
        self._loggers = []
        for name in _COMPILE_LOGGERS:
            lg = logging.getLogger(name)
            self._loggers.append((lg, lg.level, lg.propagate))
            lg.addHandler(self._handler)
            lg.setLevel(logging.DEBUG)
            lg.propagate = False     # capture silently, don't spam stderr
        self._prev = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        return self

    def __exit__(self, *exc):
        jax.config.update("jax_log_compiles", self._prev)
        for lg, lvl, prop in self._loggers:
            lg.removeHandler(self._handler)
            lg.setLevel(lvl)
            lg.propagate = prop
        return False

    @property
    def records(self) -> List[str]:
        return list(self._handler.records)

    def count(self, name_substr: str = "") -> int:
        """Number of "Compiling ..." events mentioning ``name_substr``.

        Each compilation logs on more than one logger, so events are
        deduplicated by the compiled-computation name line.
        """
        starts = [m for m in self._handler.records
                  if m.startswith("Compiling") and name_substr in m]
        return len(starts)


def check_schedule_no_retrace(make_round=None, *, n_workers: int = 8,
                              schedule: str = "one_peer_exp",
                              p: int = 2) -> List[str]:
    """Sweep a full schedule cycle + a mid-cycle resume under the counter.

    ``make_round()`` may supply a custom ``(round_fn, params, state,
    batches, period)``; the default builds PD-SGDM on DenseComm with the
    named schedule (single device, no mesh needed) — the same stacked-W
    round-index selection the sharded backend's ``lax.switch`` mirrors.
    Returns violation strings (empty = one compilation total).
    """
    if make_round is None:
        make_round = lambda: _default_round(n_workers, schedule, p)
    round_fn, params, state, batches, period = make_round()

    with CompileCounter() as cc:
        # full cycle sweep: every round index of the schedule
        for _ in range(period + 1):
            params, state, _losses = round_fn(params, state, batches)
        # mid-cycle resume: fresh state with the step counter mid-cycle —
        # exactly what checkpoint restore does
        state2 = dict(state)
        state2["step"] = jnp.asarray((period // 2 + 1) * p, jnp.int32)
        round_fn(params, state2, batches)
    n = cc.count()
    if n != 1:
        return [f"schedule sweep + mid-cycle resume compiled {n}× "
                f"(expected exactly 1); events:\n  " +
                "\n  ".join(cc.records[:10])]
    return []


def _default_round(n_workers: int, schedule: str, p: int):
    from repro.core import PDSGDM, PDSGDMConfig
    from repro.core.gossip import DenseComm
    from repro.core.topology import make_schedule
    from repro.analysis.jaxpr_check import toy_grads_fn, toy_params

    sched = make_schedule(schedule, (n_workers,))
    opt = PDSGDM(PDSGDMConfig(eta=0.05, mu=0.9, p=p), DenseComm(sched))
    params = toy_params(n_workers)
    state = opt.init(params)
    batches = jnp.zeros((p, n_workers, 4), jnp.float32)

    @jax.jit
    def round_fn(params, state, batches):
        return opt.round(state, params, toy_grads_fn, batches)

    return round_fn, params, state, batches, sched.period
