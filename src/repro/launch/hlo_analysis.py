"""Post-SPMD HLO analysis: collective bytes, roofline terms.

The HLO text parser itself lives in :mod:`repro.analysis.hlo_parse` — it is
shared with the static round-contract checks (``repro.analysis.hlo_check``)
— and is re-exported here for the roofline/dryrun path.  This module keeps
the hardware-model side: roofline terms and the training-FLOPs rule.
"""
from __future__ import annotations

from typing import Dict

from repro.analysis.hlo_parse import (  # noqa: F401  (re-exported API)
    CollectiveCall, CollectiveStats, computation_loop_depths,
    donated_aliases, parse_collectives)
from repro.analysis.hlo_parse import (  # noqa: F401  (legacy private names)
    _COLL_RE, _COMP_DEF_RE, _computation_loop_depths, _DTYPE_BYTES,
    _group_size, _type_bytes)
from repro.launch.mesh import HW

__all__ = ["CollectiveCall", "CollectiveStats", "parse_collectives",
           "computation_loop_depths", "donated_aliases", "roofline_terms",
           "model_flops"]


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (per compiled call)."""
    compute = flops_per_device / HW.PEAK_FLOPS_BF16
    memory = bytes_per_device / HW.HBM_BW
    collective = wire_bytes_per_device / HW.ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dom}


def model_flops(n_active_params: float, tokens: float, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active_params * tokens
