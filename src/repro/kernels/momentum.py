"""Fused SGDM update kernel:  m ← μ·m + (g + λ·x);  x ← x − η·d.

This is the memory-bound hot loop of PD-SGDM's local step (executed p times
per communication round on every worker).  Fusing the momentum read-modify-
write with the parameter update reads each of (x, m, g) exactly once from
HBM and writes (x, m) once — 5 streams instead of the 8+ of the unfused
jnp version (m read twice, x read twice, intermediates materialized).

Layout: the wrapper flattens/pads each leaf to (rows, LANE) with LANE=1024
(8 × 128-lane vregs) and tiles rows in blocks of BLOCK_ROWS — each block's
working set is 5 × BLOCK_ROWS × 1024 × 4 B ≈ 2.6 MB in VMEM, comfortably
under the ~16 MB/core budget while deep enough to stream HBM at full rate.

η (the learning rate) is a runtime scalar (schedules change it per step), so
it is passed as a (1, 1) operand rather than baked into the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import LANE, default_interpret

__all__ = ["momentum_update", "LANE", "BLOCK_ROWS"]

BLOCK_ROWS = 128


def _kernel(x_ref, m_ref, g_ref, lr_ref, x_out, m_out, *, mu, wd, nesterov):
    x = x_ref[...]
    m = m_ref[...]
    g = g_ref[...] + wd * x
    lr = lr_ref[0, 0]
    m_new = mu * m + g
    d = (g + mu * m_new) if nesterov else m_new
    x_out[...] = x - lr * d
    m_out[...] = m_new


@functools.partial(jax.jit, static_argnames=("mu", "wd", "nesterov",
                                             "interpret"))
def momentum_update(x, m, g, lr, *, mu: float, wd: float = 0.0,
                    nesterov: bool = False, interpret: bool | None = None):
    """x, m, g: (rows, LANE) float32; lr: scalar.  Returns (x_new, m_new)."""
    if interpret is None:
        interpret = default_interpret()
    rows, lane = x.shape
    assert lane == LANE and rows % BLOCK_ROWS == 0, (rows, lane)
    grid = (rows // BLOCK_ROWS,)
    blk = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, mu=float(mu), wd=float(wd),
                          nesterov=bool(nesterov)),
        grid=grid,
        in_specs=[blk, blk, blk, scalar],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.float32),
                   jax.ShapeDtypeStruct(m.shape, jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), m.astype(jnp.float32),
      g.astype(jnp.float32), lr2)
