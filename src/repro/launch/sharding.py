"""Sharding rules: map every param / batch / cache leaf to a PartitionSpec.

Two training profiles (see DESIGN.md §4):

* **A** — replica-per-worker: the decentralized worker axis is
  ``("pod","data")``; inside a worker only tensor parallelism over "model".
* **B** — FSDP-inside-worker (≳45 B params): worker axis ``("pod",)``;
  params are FSDP-sharded over "data" and tensor-parallel over "model".

Every axis assignment is divisibility-checked (``_fit``) and silently
dropped when the dim doesn't divide — e.g. minicpm3's vocab 73448 is not
16-divisible, so its embedding stays vocab-unsharded instead of crashing
the whole (arch × shape) grid.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelCfg

__all__ = ["Layout", "make_layout", "param_pspec", "param_spec_tree",
           "batch_spec_tree", "cache_spec_tree", "to_shardings"]


@dataclasses.dataclass(frozen=True)
class Layout:
    """Resolved axis roles for a (profile, mesh) pair."""
    mesh: object
    profile: str
    worker_axes: Tuple[str, ...]   # decentralized gossip axes
    fsdp_axis: Optional[str]       # params sharded here inside a worker
    tp_axis: Optional[str]
    batch_axes: Tuple[str, ...]    # serving batch axes
    inner_axis: Optional[str] = None  # within-worker data parallelism (A-dp)

    @property
    def worker_sizes(self) -> Tuple[int, ...]:
        return tuple(self.mesh.shape[a] for a in self.worker_axes)

    @property
    def n_workers(self) -> int:
        return int(math.prod(self.worker_sizes)) if self.worker_axes else 1

    def axis_size(self, name: Optional[str]) -> int:
        return self.mesh.shape[name] if name else 1


def make_layout(parallel: ParallelCfg, mesh, *, serving: bool = False) -> Layout:
    names = mesh.axis_names
    has_pod = "pod" in names
    if serving:
        return Layout(mesh, parallel.profile, (),
                      "data" if parallel.profile == "B" else None,
                      "model" if "model" in names else None,
                      tuple(a for a in ("pod", "data") if a in names))
    if parallel.profile == "A":
        waxes = tuple(a for a in ("pod", "data") if a in names)
        if parallel.inner == "worker":
            # decentralize over the FULL mesh: one gossip worker per chip,
            # torus topology over all axes — zero per-step collectives,
            # only the periodic neighbour permutes (beyond-paper §Perf).
            waxes = tuple(names)
            return Layout(mesh, "A", waxes, None, None, waxes)
        if parallel.inner == "dp" and "model" in names:
            # within-worker data parallelism: params replicated inside a
            # worker, the "model" axis shards the per-worker batch (small
            # models: gradient all-reduce ≪ per-layer activation psums)
            return Layout(mesh, "A", waxes, None, None, waxes,
                          inner_axis="model")
        return Layout(mesh, "A", waxes, None,
                      "model" if "model" in names else None,
                      waxes)
    waxes = ("pod",) if has_pod else ()
    return Layout(mesh, "B", waxes,
                  "data" if "data" in names else None,
                  "model" if "model" in names else None,
                  tuple(a for a in ("pod", "data") if a in names))


# --------------------------------------------------------------------------- params
def _fit(shape, dim: int, axis: Optional[str], layout: Layout,
         taken) -> Optional[str]:
    """Assign axis to dim if divisible and not already used on this leaf."""
    if axis is None or axis in taken:
        return None
    if dim >= len(shape) or shape[dim] % layout.axis_size(axis) != 0:
        return None
    taken.add(axis)
    return axis


def param_pspec(path: str, shape, layout: Layout,
                stacked_worker: bool) -> P:
    """PartitionSpec for one param leaf.

    ``path`` is the '/'-joined key path *without* the worker dim;
    ``shape`` likewise.  ``stacked_worker`` prepends the worker-axes spec.
    """
    tp, fsdp = layout.tp_axis, layout.fsdp_axis
    nd = len(shape)
    spec = [None] * nd
    taken: set = set()

    def last2(a_for_m2, a_for_m1):
        spec[nd - 2] = _fit(shape, nd - 2, a_for_m2, layout, taken)
        spec[nd - 1] = _fit(shape, nd - 1, a_for_m1, layout, taken)

    leaf = path.split("/")[-1]
    ctx = path
    if "embed/table" in ctx:
        last2(tp, fsdp)            # vocab over model, d over data
    elif "lm_head" in ctx and leaf == "w":
        last2(fsdp, tp)
    elif "moe" in ctx and leaf in ("wi", "wg"):
        # (E, d, f): experts over fsdp axis, f over model
        spec[nd - 3] = _fit(shape, nd - 3, fsdp, layout, taken)
        spec[nd - 1] = _fit(shape, nd - 1, tp, layout, taken)
        if spec[nd - 3] is None:
            spec[nd - 2] = _fit(shape, nd - 2, fsdp, layout, taken)
    elif "moe" in ctx and leaf == "wo":
        # (E, f, d)
        spec[nd - 3] = _fit(shape, nd - 3, fsdp, layout, taken)
        spec[nd - 2] = _fit(shape, nd - 2, tp, layout, taken)
        if spec[nd - 3] is None:
            spec[nd - 1] = _fit(shape, nd - 1, fsdp, layout, taken)
    elif "router" in ctx:
        pass                        # tiny, replicated
    elif leaf == "w" and any(k in ctx for k in (
            "wo/", "out_proj")) :
        last2(tp, fsdp)             # row-parallel
    elif leaf == "w" and any(k in ctx for k in (
            "wq", "wk", "wv", "wi", "wg", "wdq", "wuq", "wdkv", "wuk",
            "wuv", "in_proj")):
        last2(fsdp, tp)             # column-parallel
    elif leaf == "w":               # e.g. wkr (tiny)
        last2(fsdp, None)
    elif leaf == "b" and nd >= 1:
        spec[nd - 1] = _fit(shape, nd - 1, tp, layout, taken)
    elif leaf == "conv_w":
        spec[nd - 1] = _fit(shape, nd - 1, tp, layout, taken)
    # norms / scalars / A_log / D etc: replicated

    if stacked_worker:
        w = layout.worker_axes if layout.worker_axes else None
        return P(w, *spec)
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec_tree(params_struct, layout: Layout, *,
                    stacked_worker: bool, skip_leading: int = 0):
    """PartitionSpec tree for a params (or grads/momentum) struct.

    ``skip_leading``: number of leading dims that are NOT part of the base
    param shape (e.g. the stacked worker dim = 1, or worker+repeats = 2 —
    the n_repeats scan dim is found automatically from 'blocks/' paths).
    """

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        lead = skip_leading
        if stacked_worker:
            lead += 1               # worker dim
        if "blocks/" in ps:
            lead += 1               # n_repeats scan dim
        base = shape[lead:]
        spec = param_pspec(ps, base, layout, stacked_worker=False)
        pad = [None] * (lead - (1 if stacked_worker else 0))
        w = (layout.worker_axes or None) if stacked_worker else None
        if stacked_worker:
            return P(w, *pad, *spec)
        return P(*pad, *spec)

    return jax.tree_util.tree_map_with_path(one, params_struct)


# --------------------------------------------------------------------------- batch
def batch_spec_tree(batch_struct, layout: Layout):
    """Train batch leaves: (n_workers, per_batch, seq[, d])."""
    w = layout.worker_axes or None

    def one(path, leaf):
        spec = [None] * (len(leaf.shape) - 1)
        inner = layout.fsdp_axis or layout.inner_axis
        if inner and leaf.shape[1] % layout.axis_size(inner) == 0:
            spec[0] = inner              # data-parallel inside the worker
        return P(w, *spec)

    return jax.tree_util.tree_map_with_path(one, batch_struct)


# --------------------------------------------------------------------------- cache
def cache_spec_tree(cache_struct, layout: Layout, batch: int):
    """Serve-time cache: batch over batch_axes when divisible, else context/
    state parallel (slots over data, heads/latent over model)."""
    baxes = layout.batch_axes
    bsize = int(math.prod(layout.axis_size(a) for a in baxes)) if baxes else 1
    batch_ok = baxes and batch % bsize == 0
    data = "data" if "data" in layout.mesh.axis_names else None
    tp = layout.tp_axis

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        # find batch dim: caches are stacked (n_repeats, b, ...)
        spec = [None] * nd
        bdim = 1
        taken: set = set()
        if batch_ok:
            spec[bdim] = baxes
            taken.update(baxes)
        if ps.endswith("k") or ps.endswith("v"):
            # (rep, b, slots, kv, hd)
            if not batch_ok:
                spec[2] = _fit(shape, 2, data, layout, taken)
            spec[3] = _fit(shape, 3, tp, layout, taken)
            if spec[3] is None:
                spec[4] = _fit(shape, 4, tp, layout, taken)
        elif ps.endswith("ckv"):
            # (rep, b, slots, r)
            if not batch_ok:
                spec[2] = _fit(shape, 2, data, layout, taken)
            spec[3] = _fit(shape, 3, tp, layout, taken)
        elif ps.endswith("krope"):
            if not batch_ok:
                spec[2] = _fit(shape, 2, data, layout, taken)
        elif ps.endswith("ssm"):
            # (rep, b, h, n, p)
            spec[2] = _fit(shape, 2, tp, layout, taken)
            if not batch_ok:
                spec[3] = _fit(shape, 3, data, layout, taken)
        elif ps.endswith("conv"):
            # (rep, b, k-1, conv_dim)
            spec[3] = _fit(shape, 3, tp, layout, taken)
        elif ps.endswith("pos"):
            if not batch_ok:
                spec[2] = _fit(shape, 2, data, layout, taken)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def to_shardings(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
