#!/usr/bin/env python
"""Markdown link check for the docs CI job (no network access needed).

Usage: python tools/check_md_links.py README.md docs [more files/dirs...]

Checks, for every ``[text](target)`` link in the given markdown files:
  * relative file targets resolve to an existing file/directory
    (anchors are stripped; ``#section`` anchors themselves are not
    validated — headings move too often for that to stay signal);
  * absolute ``http(s)://`` targets are syntactically sane (scheme+host);
  * bare ``/``-rooted targets are rejected — they break outside GitHub.

Exits non-zero listing every broken link.
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) — target without closing paren; images share the syntax
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_URL_RE = re.compile(r"^https?://[\w.-]+")


def md_files(args):
    for a in args:
        if os.path.isdir(a):
            for root, _dirs, files in os.walk(a):
                for f in sorted(files):
                    if f.endswith(".md"):
                        yield os.path.join(root, f)
        else:
            yield a


def check_file(path) -> list:
    errors = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        line = text[:m.start()].count("\n") + 1
        if target.startswith(("http://", "https://")):
            if not _URL_RE.match(target):
                errors.append((path, line, target, "malformed URL"))
            continue
        if target.startswith("mailto:"):
            continue
        if target.startswith("#"):
            continue  # in-page anchor: not validated
        if target.startswith("/"):
            errors.append((path, line, target,
                           "absolute path (breaks outside the repo root)"))
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append((path, line, target, f"missing file {resolved}"))
    return errors


def main(argv) -> int:
    if not argv:
        print("usage: check_md_links.py <file-or-dir>...", file=sys.stderr)
        return 2
    all_errors = []
    n = 0
    for path in md_files(argv):
        n += 1
        all_errors.extend(check_file(path))
    for (path, line, target, why) in all_errors:
        print(f"{path}:{line}: broken link ({target}): {why}")
    print(f"checked {n} markdown file(s): "
          f"{'OK' if not all_errors else f'{len(all_errors)} broken link(s)'}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
