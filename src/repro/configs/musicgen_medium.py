"""musicgen-medium — MusicGen [arXiv:2306.05284].

48L decoder-only over EnCodec tokens: d_model 1536, 24 heads (MHA kv=24),
d_ff 6144, vocab 2048.  The EnCodec frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings; the decoder and its
2048-way codec-token head are fully implemented.  (Single-codebook
simplification of MusicGen's 4-codebook interleaving — noted in DESIGN.md.)
"""
from repro.configs.base import ModelCfg, OptimCfg, ParallelCfg, RunCfg


def config() -> RunCfg:
    model = ModelCfg(
        name="musicgen-medium", arch_type="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab=2048, norm="layernorm", gated_mlp=False,
        input_mode="embeds",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        source="arXiv:2306.05284",
    )
    return RunCfg(model=model, parallel=ParallelCfg(profile="A"),
                  optim=OptimCfg())
