"""Model-family correctness: decode==forward, blockwise==full, SSD==recurrent."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelCfg
from repro.models import make_model
from repro.models.attention import (AttnCfg, attention_apply, attention_init)
from repro.models.layers import rope_freqs
from repro.models.mamba2 import (Mamba2Cfg, init_mamba_cache, mamba2_apply,
                                 mamba2_decode, mamba2_init)
from repro.models.moe import MoECfg, moe_apply, moe_init

V = 128


def _dense_cfg(**kw):
    base = dict(name="t", arch_type="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=V)
    base.update(kw)
    return ModelCfg(**base)


MODEL_CASES = {
    "dense": _dense_cfg(),
    "swa": _dense_cfg(window=8),
    "qkv_bias_ln": _dense_cfg(qkv_bias=True, norm="layernorm"),
    "nonparam": _dense_cfg(norm="nonparametric", n_kv_heads=4),
    "moe": _dense_cfg(arch_type="moe", n_experts=4,
                      pattern=(LayerSpec("attn", "moe"),)),
    "arctic_residual": _dense_cfg(arch_type="moe", n_experts=4,
                                  pattern=(LayerSpec("attn", "dense+moe"),)),
    "mla": _dense_cfg(use_mla=True, n_kv_heads=4, q_lora_rank=32,
                      kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16, pattern=(LayerSpec("mla", "dense"),)),
    "mamba": _dense_cfg(arch_type="ssm", d_ff=0, ssm_state=16,
                        ssm_headdim=16, ssm_chunk=4,
                        pattern=(LayerSpec("mamba", "none"),)),
    "hybrid": ModelCfg(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=V,
                       n_experts=4, ssm_state=16, ssm_headdim=16, ssm_chunk=4,
                       pattern=(LayerSpec("mamba", "dense"),
                                LayerSpec("mamba", "moe"),
                                LayerSpec("attn", "dense"),
                                LayerSpec("mamba", "moe"))),
}


@pytest.mark.parametrize("name", list(MODEL_CASES), ids=list(MODEL_CASES))
def test_decode_matches_forward(name):
    cfg = MODEL_CASES[name]
    m = make_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, V)
    full, _ = m.apply(p, {"tokens": tok})
    cache = m.init_cache(b, s)
    dstep = jax.jit(functools.partial(m.decode_step, max_positions=s))
    outs = []
    for i in range(s):
        lg, cache = dstep(p, cache, tok[:, i], jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3)


@pytest.mark.parametrize("name", list(MODEL_CASES), ids=list(MODEL_CASES))
def test_prefill_then_decode(name):
    cfg = MODEL_CASES[name]
    m = make_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b, s, prompt = 2, 16, 8
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, V)
    full, _ = m.apply(p, {"tokens": tok})
    lg, cache = jax.jit(functools.partial(m.prefill_fast, max_len=s))(
        p, {"tokens": tok[:, :prompt]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, prompt - 1]), atol=2e-3)
    dstep = jax.jit(functools.partial(m.decode_step, max_positions=s))
    for i in range(prompt, s):
        lg, cache = dstep(p, cache, tok[:, i], jnp.int32(i))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, i]), atol=2e-3)


@pytest.mark.parametrize("window", [None, 12])
@pytest.mark.parametrize("q_chunk", [4, 8, 16])
def test_blockwise_equals_full(window, q_chunk):
    cfg = AttnCfg(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                  q_chunk=q_chunk, window=window)
    p = attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    cos, sin = rope_freqs(16, 32)
    y_full = attention_apply(p, x, cfg, cos, sin, force_blockwise=False)
    y_blk = attention_apply(p, x, cfg, cos, sin, force_blockwise=True)
    np.testing.assert_allclose(np.asarray(y_blk), np.asarray(y_full),
                               atol=1e-5)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD (train path) == step-by-step recurrent decode."""
    cfg = Mamba2Cfg(d_model=32, d_state=8, headdim=8, expand=2, chunk=4)
    p = mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 16
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, 32)) * 0.5
    y_chunked = mamba2_apply(p, u, cfg)
    cache = init_mamba_cache(cfg, b, jnp.float32)
    ys = []
    for i in range(s):
        y, cache = mamba2_decode(p, u[:, i:i + 1], cache, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                               atol=2e-4)


def test_ssd_final_state_matches_decode_state():
    cfg = Mamba2Cfg(d_model=32, d_state=8, headdim=8, expand=2, chunk=4)
    p = mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32)) * 0.5
    _, st = mamba2_apply(p, u, cfg, return_state=True)
    cache = init_mamba_cache(cfg, 1, jnp.float32)
    for i in range(8):
        _, cache = mamba2_decode(p, u[:, i:i + 1], cache, cfg)
    np.testing.assert_allclose(np.asarray(st["ssm"]),
                               np.asarray(cache["ssm"]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st["conv"]),
                               np.asarray(cache["conv"]), atol=1e-5)


def test_moe_matches_dense_reference_at_high_capacity():
    """With capacity ≥ all tokens, sort-based dispatch must equal the dense
    weighted-sum-over-top-k reference exactly."""
    cfg = MoECfg(d_model=16, d_ff=32, n_experts=4, top_k=2,
                 capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_apply(p, x, cfg)

    # dense reference
    xf = x.reshape(-1, 16)
    logits = xf @ p["router"]["w"]
    gates = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(gates, 2)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    outs = []
    for e in range(4):
        h = xf @ p["wi"][e]
        g = xf @ p["wg"][e]
        h = jax.nn.silu(g) * h
        outs.append(h @ p["wo"][e])
    outs = jnp.stack(outs, 1)        # (N, E, d)
    want = jnp.zeros_like(xf)
    for j in range(2):
        want += top_w[:, j:j + 1] * jnp.take_along_axis(
            outs, top_e[:, j][:, None, None].repeat(16, -1), 1)[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 16)),
                               np.asarray(want), atol=1e-4)
    assert 0.0 < float(aux) < 1.0


def test_moe_drops_overflow_tokens():
    cfg = MoECfg(d_model=8, d_ff=16, n_experts=2, top_k=1,
                 capacity_factor=0.5)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    y, _ = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_vlm_label_alignment():
    cfg = _dense_cfg(arch_type="vlm", input_mode="vlm", n_patches=8)
    m = make_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b, st = 2, 8
    batch = {
        "patch_embeds": jax.random.normal(jax.random.PRNGKey(1), (b, 8, 64)),
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (b, st), 0, V),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (b, st), 0, V),
    }
    loss, met = m.loss(p, batch)
    assert bool(jnp.isfinite(loss))
    # masked prefix: ce computed over text positions only
    assert float(met["ce"]) > 0
