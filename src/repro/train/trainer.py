"""Round-based training loops — the canonical execution model.

Both trainers execute whole *rounds* (p local momentum steps + exactly one
gossip round) as a single jitted unit, matching the paper's communication
structure: the periodicity that buys the algorithm its communication savings
also buys us dispatch/fusion savings, because XLA sees the full round (and
``rounds_per_log`` of them at once in the simulator) instead of one step at
a time with a host sync on every loss read.

``SimTrainer`` — single-process decentralized simulation (DenseComm, worker
dim stacked).  The hot path is a jitted ``lax.scan`` over whole rounds
(scan body = ``opt.round``: p local steps + one unconditional
``opt.comm_round``); per-step losses accumulate on device and are fetched
with one host sync per log block.  A run whose length is not a multiple of
p ends with a fused tail of local steps (no gossip), reproducing the
per-step schedule ``mod(t+1, p) == 0`` exactly.

``ShardedTrainer`` — drives the production ``TrainPack`` built by
``repro.launch.runtime`` through ``TrainPack.train_round`` (mesh-sharded,
ppermute gossip, donated buffers).  Losses stay on device between log
points (``jax.block_until_ready`` only when flushing), communication MB are
accounted per round from the optimizer's cost model, and checkpoints carry
the *full* optimizer state (including CPD-SGDM's ``xhat``/``xhat_nbrs``
error-compensation trees) so a restore resumes bit-identically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pdsgdm import PDSGDM

__all__ = ["SimTrainer", "History", "ShardedTrainer"]

# cap on the *derived* SimTrainer block size (rounds per jitted call):
# batches for a whole block are staged on device before the scan runs
_MAX_BLOCK_ROUNDS = 16


@dataclasses.dataclass
class History:
    steps: List[int] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)
    eval_metric: List[float] = dataclasses.field(default_factory=list)

    def rows(self):
        for i, s in enumerate(self.steps):
            yield {"step": s, "loss": self.loss[i],
                   "comm_mb": self.comm_mb[i],
                   "eval": self.eval_metric[i] if self.eval_metric else None}


def _stack_batches(batches, extra_dims=()):
    """Stack a list of batch pytrees into one with a leading scan dim."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs).reshape(extra_dims + xs[0].shape)
        if extra_dims else jnp.stack(xs), *batches)


def _should_log(t, steps, log_every):
    return t % log_every == 0 or t == steps - 1


def _bytes_through(n_rounds: int, per_round_bytes) -> float:
    """Cumulative bytes after ``n_rounds`` gossip rounds.

    ``per_round_bytes`` is a scalar (static topology) or the per-round
    cycle from ``opt.bytes_per_round_cycle`` (time-varying schedule, where
    the degree — and hence the bytes — differs round to round)."""
    if isinstance(per_round_bytes, (int, float)):
        return n_rounds * per_round_bytes
    T = len(per_round_bytes)
    full, rem = divmod(n_rounds, T)
    return full * sum(per_round_bytes) + sum(per_round_bytes[:rem])


def _log_chunk(hist, losses, t0, *, steps, log_every, p, per_round_bytes,
               on_log=None):
    """Append History entries for the log points inside one executed chunk.

    ``losses`` holds the per-step losses starting at global step ``t0``.
    Comm accounting: ``(t+1) // p`` gossip rounds completed through step t
    (the schedule is mod(t+1, p) == 0), costed round-by-round through
    ``per_round_bytes`` (scalar or per-round cycle).
    """
    for i, lv in enumerate(np.asarray(losses).reshape(-1)):
        t = t0 + i
        if not _should_log(t, steps, log_every):
            continue
        hist.steps.append(t)
        hist.loss.append(float(lv))
        hist.comm_mb.append(
            _bytes_through((t + 1) // p, per_round_bytes) / 2 ** 20)
        if on_log is not None:
            on_log(t, float(lv), hist.comm_mb[-1])


class SimTrainer:
    """Decentralized training simulation over K stacked workers.

    Executes ``rounds_per_log`` whole rounds per jitted call; the device is
    only synced when a log block is flushed.
    """

    def __init__(self, loss_fn: Callable, opt: PDSGDM,
                 rounds_per_log: Optional[int] = None):
        self.loss_fn = loss_fn
        self.opt = opt
        self.rounds_per_log = rounds_per_log
        self._grad = jax.vmap(jax.value_and_grad(
            lambda p, b: loss_fn(p, b)[0]))

        def grads_fn(params, batch):
            losses, grads = self._grad(params, batch)
            return losses.mean(), grads

        def block_fn(params, state, batches):
            """batches: [n_rounds, p, ...] — scan of fused rounds."""
            def round_body(carry, round_batches):
                params, state = carry
                params, state, losses = opt.round(
                    state, params, grads_fn, round_batches)
                return (params, state), losses

            (params, state), losses = jax.lax.scan(
                round_body, (params, state), batches)
            return params, state, losses.reshape(-1)

        def tail_fn(params, state, batches):
            """Trailing steps past the last full round: local steps only
            (``gossip=False`` keeps the kernel flatten-once path eligible)."""
            params, state, losses = opt.round(
                state, params, grads_fn, batches, gossip=False)
            return params, state, losses

        self._block = jax.jit(block_fn)
        self._tail = jax.jit(tail_fn)

    def bytes_per_round(self, params) -> int:
        return self.opt.bytes_per_comm_round(
            jax.tree_util.tree_map(lambda x: x[0], params))

    def bytes_per_round_cycle(self, params) -> tuple:
        return self.opt.bytes_per_round_cycle(
            jax.tree_util.tree_map(lambda x: x[0], params))

    def train(self, params, batch_fn: Callable[[int], dict], steps: int,
              log_every: int = 10,
              eval_fn: Optional[Callable] = None,
              verbose: bool = False,
              rounds_per_log: Optional[int] = None) -> tuple:
        opt = self.opt
        state = opt.init(params)
        hist = History()
        per_round = self.bytes_per_round_cycle(params)
        p = opt.config.p
        n_rounds, tail = divmod(steps, p)
        explicit = rounds_per_log or self.rounds_per_log
        if eval_fn is not None:
            # the round engine never materializes mid-round params, so the
            # eval hook sees the end of the round containing the log step
            # (≤ p-1 steps later); larger blocks would pair log steps with
            # evals taken a whole block later — refuse rather than distort
            if explicit not in (None, 1):
                raise ValueError(
                    "eval_fn needs rounds_per_log=1: params only exist at "
                    "block boundaries, so a larger block would mis-pair "
                    "eval values with log steps")
            block = 1
        elif explicit:
            block = explicit       # caller's choice: batch staging is
            #                        theirs to bound
        else:
            # a whole block's batches are staged on device before the scan,
            # so cap the derived size independently of log_every
            block = min(_MAX_BLOCK_ROUNDS, max(1, -(-log_every // p)))

        def flush(losses, t0, params):
            ev_cache = []

            def on_log(t, lv, mb):
                if eval_fn is not None:
                    if not ev_cache:
                        # worker average at the end of this round/tail
                        avg = jax.tree_util.tree_map(
                            lambda x: x.mean(0, keepdims=True).repeat(
                                x.shape[0], 0), params)
                        ev_cache.append(float(eval_fn(avg)))
                    hist.eval_metric.append(ev_cache[0])
                if verbose:
                    print(f"step {t:5d} loss {lv:.4f} comm {mb:.1f} MB")

            # np.asarray inside _log_chunk = one host sync per block
            _log_chunk(hist, losses, t0, steps=steps, log_every=log_every,
                       p=p, per_round_bytes=per_round, on_log=on_log)

        done = 0                                   # steps completed
        while done < n_rounds * p:
            r = min(block, n_rounds - done // p)
            flat = [batch_fn(done + i) for i in range(r * p)]
            batches = _stack_batches(flat, extra_dims=(r, p))
            params, state, losses = self._block(params, state, batches)
            flush(losses, done, params)
            done += r * p
        if tail:
            flat = [batch_fn(done + i) for i in range(tail)]
            params, state, losses = self._tail(
                params, state, _stack_batches(flat))
            flush(losses, done, params)
        return params, state, hist


class ShardedTrainer:
    """Production loop over a ``TrainPack`` — fused rounds, full checkpoints.

    * hot path: ``pack.train_round`` (p local steps + one gossip per jitted
      call, donated carry buffers — the returned params/state are fresh
      arrays, so the Python-level carry stays donation-safe);
    * losses stay on device between log points; ``jax.block_until_ready``
      runs only when a log block is flushed;
    * comm MB per round comes from the optimizer's wire-cost model
      (degree × payload bytes, honouring compression);
    * checkpoints store params and the *complete* optimizer state; with
      ``resume=True`` training continues bit-identically from a
      round-boundary checkpoint (an off-boundary one continues on the
      schedule-correct per-step path until the next boundary).
    """

    def __init__(self, pack, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 0):
        self.pack = pack
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every

    def bytes_per_round(self) -> int:
        per_worker = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            self.pack.params_struct)
        return self.pack.opt.bytes_per_comm_round(per_worker)

    def bytes_per_round_cycle(self) -> tuple:
        per_worker = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            self.pack.params_struct)
        return self.pack.opt.bytes_per_round_cycle(per_worker)

    def train(self, key, batch_fn: Callable[[int], dict], steps: int,
              log_every: int = 10, verbose: bool = True,
              resume: bool = False) -> Dict:
        from repro.checkpoint import checkpoint as ckpt
        pack = self.pack
        p = pack.opt.config.p
        params = state = None
        start = 0
        if resume and not self.ckpt_dir:
            raise ValueError(
                "resume=True needs a checkpoint directory (ckpt_dir)")
        if resume and self.ckpt_dir:
            last = ckpt.latest_step(self.ckpt_dir)
            if last is not None:
                # elastic restore: same fleet size → exact checkpoint.restore
                # (bit-identical for every worker at the round boundary);
                # K→K' → survivors keep their shards, joiners warm-start
                # params + full optimizer state from a live donor's shard
                from repro.checkpoint import elastic
                restored = elastic.restore_elastic(
                    self.ckpt_dir, last,
                    params_template=pack.params_struct,
                    state_template=pack.state_struct,
                    comm=pack.opt.comm)
                params = jax.device_put(restored["params"],
                                        pack.params_sharding)
                state = jax.device_put(restored["opt_state"],
                                       pack.state_sharding)
                start = last
        if params is None:       # fresh start: init only when not restored
            params, state = pack.init_fn(key)
        if start >= steps and verbose:
            print(f"resume: checkpoint step {start} >= steps {steps}, "
                  "nothing to run")
        hist = History()
        per_round_bytes = self.bytes_per_round_cycle()
        wall0 = time.time()
        pending: list = []         # [(first step idx, device losses)]

        def on_log(t, lv, mb):
            if verbose:
                print(f"step {t:5d} loss {lv:.4f} comm {mb:.1f} MB "
                      f"({time.time()-wall0:.1f}s)")

        def flush():
            if not pending:
                return
            jax.block_until_ready(pending[-1][1])   # the only device sync
            for t_start, losses in pending:
                _log_chunk(hist, losses, t_start, steps=steps,
                           log_every=log_every, p=p,
                           per_round_bytes=per_round_bytes, on_log=on_log)
            pending.clear()

        t = start
        while t < steps:
            if t % p == 0 and steps - t >= p:
                rb = _stack_batches([batch_fn(t + i) for i in range(p)])
                params, state, losses = pack.train_round(params, state, rb)
                n = p
            else:
                # off a round boundary (resume from a tail checkpoint) or a
                # tail shorter than a round: per-step path — its gossip cond
                # keys on the restored step counter, keeping the schedule
                params, state, losses = pack.train_step(
                    params, state, batch_fn(t))
                n = 1
            pending.append((t, losses))
            t += n
            if t >= steps or any(_should_log(tt, steps, log_every)
                                 for tt in range(t - n, t)):
                flush()
            if (self.ckpt_dir and self.ckpt_every
                    and t // self.ckpt_every > (t - n) // self.ckpt_every):
                ckpt.save(self.ckpt_dir, t, params=params, opt_state=state)
        flush()
        return {"params": params, "state": state, "history": hist,
                "steps_run": t - start}
