"""Analytic FLOP / HBM-traffic model for the roofline terms.

XLA's ``cost_analysis`` counts ``while``-loop bodies once, so with the layer
scan (n_repeats trips) and the train-round scan (p trips) it under-reports
by orders of magnitude.  The roofline therefore uses the standard analytic
accounting below (the same formulas MFU reports use), with the raw XLA
numbers kept in the artifact for reference.

All numbers are *per compiled call* (train_round = p steps + 1 gossip
round; prefill = one prompt batch; decode = one token per sequence).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import LayerSpec, ModelCfg
from repro.configs.shapes import InputShape

__all__ = ["analytic_cost"]


def _attn_flops_per_token(m: ModelCfg, s_eff: float) -> float:
    d, h, kv = m.d_model, m.n_heads, m.n_kv_heads
    hd = m.resolved_head_dim
    proj = 2 * d * hd * (h + 2 * kv) + 2 * h * hd * d
    core = 4 * h * hd * s_eff
    return proj + core


def _mla_flops_per_token(m: ModelCfg, s_eff: float) -> float:
    d, h = m.d_model, m.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    proj = 2 * (d * m.q_lora_rank + m.q_lora_rank * h * qk
                + d * m.kv_lora_rank + d * m.qk_rope_dim
                + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                + h * m.v_head_dim * d)
    core = 2 * h * qk * s_eff + 2 * h * m.v_head_dim * s_eff
    return proj + core


def _mamba_flops_per_token(m: ModelCfg, decode: bool) -> float:
    d = m.d_model
    di = m.ssm_expand * d
    h = di // m.ssm_headdim
    n, p, Q = m.ssm_state, m.ssm_headdim, m.ssm_chunk
    conv_dim = di + 2 * n
    ipd = di + conv_dim + h
    proj = 2 * d * ipd + 2 * di * d
    conv = 2 * 4 * conv_dim
    if decode:
        ssd = 2 * h * (2 * n * p + n)          # state update + readout
    else:
        # intra-chunk quadratic + chunk-state accumulate + inter readout
        ssd = 2 * h * (Q * n + Q * p + 4 * n * p)
    return proj + conv + ssd


def _ffn_flops_per_token(m: ModelCfg, spec: LayerSpec) -> float:
    mats = 3 if m.gated_mlp else 2
    f = 0.0
    if spec.ffn in ("dense", "dense+moe"):
        f += 2 * m.d_model * m.d_ff * mats
    if spec.ffn in ("moe", "dense+moe"):
        f += 2 * m.d_model * m.n_experts          # router
        f += m.top_k * 2 * m.d_model * m.d_ff * mats
    return f


def _fwd_flops_per_token(m: ModelCfg, s_eff: float, decode: bool) -> float:
    total = 2 * m.d_model * m.vocab               # lm head
    for spec in m.pattern:
        n = m.n_repeats
        if spec.mixer == "attn":
            f = _attn_flops_per_token(m, s_eff)
        elif spec.mixer == "mla":
            f = _mla_flops_per_token(m, s_eff)
        else:
            f = _mamba_flops_per_token(m, decode)
        total += n * (f + _ffn_flops_per_token(m, spec))
    return total


def _param_bytes(m: ModelCfg) -> float:
    import numpy as np
    return m.params_count() * np.dtype(m.param_dtype).itemsize


def _cache_bytes_per_seq(m: ModelCfg, s: int) -> float:
    """Decode-cache bytes per sequence (what one decode step must read)."""
    import numpy as np
    dt = np.dtype(m.compute_dtype).itemsize
    total = 0.0
    for spec in m.pattern:
        n = m.n_repeats
        if spec.mixer == "attn":
            slots = min(m.window, s) if m.window else s
            total += n * 2 * slots * m.n_kv_heads * m.resolved_head_dim * dt
        elif spec.mixer == "mla":
            total += n * s * (m.kv_lora_rank + m.qk_rope_dim) * dt
        else:
            di = m.ssm_expand * m.d_model
            h = di // m.ssm_headdim
            total += n * (h * m.ssm_state * m.ssm_headdim * 4
                          + 3 * (di + 2 * m.ssm_state) * dt)
    return total


def analytic_cost(m: ModelCfg, shape: InputShape, kind: str, p: int,
                  n_chips: int, n_workers: int, remat: str) -> Dict[str, float]:
    """Per-device flops and HBM bytes for one compiled call."""
    import numpy as np
    s = shape.seq_len
    gb = shape.global_batch
    dt = np.dtype(m.compute_dtype).itemsize

    if kind == "decode":
        s_eff = float(min(m.window, s)) if m.window else float(s)
        tokens = gb                      # one token per sequence
    else:
        s_eff = min(s / 2.0, float(m.window)) if m.window else s / 2.0
        tokens = gb * s

    fwd = _fwd_flops_per_token(m, s_eff, kind == "decode")
    if kind == "train":
        mult = 3.0 + (1.0 if remat == "full" else 0.0)   # fwd+bwd (+remat fwd)
        flops_total = fwd * tokens * mult * p
    else:
        flops_total = fwd * tokens
    flops_dev = flops_total / n_chips

    # ---- HBM traffic (per device)
    pb_local = _param_bytes(m) * n_workers / n_chips   # replicated per worker
    tokens_dev = tokens / n_chips * (p if kind == "train" else 1)
    act_unit = m.n_layers * m.d_model * dt
    if kind == "train":
        # fwd+bwd activation RW (~16 streams/layer) + params fwd/bwd/opt
        act = tokens_dev * act_unit * 16
        params_traffic = pb_local * (2 * p + 3 * p + 4)  # fwd/bwd reads + opt + gossip
        bytes_dev = act + params_traffic
    elif kind == "prefill":
        act = tokens_dev * act_unit * 6
        bytes_dev = act + pb_local
    else:
        cache = _cache_bytes_per_seq(m, s) * gb / n_chips
        bytes_dev = 2 * cache + pb_local + tokens_dev * act_unit * 6
    return {"flops_per_device": flops_dev,
            "flops_total": flops_total,
            "bytes_per_device": bytes_dev,
            "tokens": tokens * (p if kind == "train" else 1)}
