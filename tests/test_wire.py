"""Wire codec subsystem: payload round-trips, accounted ≡ shipped bytes,
and the rand-k shared-key zero-communication-indices property."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPDSGDM, CPDSGDMConfig, IdentityCompressor,
                        QSGDCompressor, RandKCompressor, SignCompressor,
                        TopKCompressor, make_codec)
from repro.core.compression import SparseRowsCompressor
from repro.core.gossip import DenseComm
from repro.core.topology import ring
from repro.core.wire import payload_nbytes

COMPRESSORS = [
    IdentityCompressor(),
    SignCompressor(),
    SignCompressor(block=64),
    TopKCompressor(fraction=0.01),
    TopKCompressor(fraction=0.3),
    RandKCompressor(fraction=0.05),
    QSGDCompressor(levels=7),
    QSGDCompressor(levels=16),
    QSGDCompressor(levels=1),
    SparseRowsCompressor(max_rows=2),
    SparseRowsCompressor(max_rows=2, inner="sign"),
    SparseRowsCompressor(max_rows=3, inner="qsgd"),
]


def _ids(c):
    if c.name in ("sign", "qsgd"):
        return f"{c.name}-{getattr(c, 'block', getattr(c, 'levels', ''))}"
    if c.name in ("topk", "randk"):
        return f"{c.name}-{c.fraction}"
    if c.name == "sparse_rows":
        return f"{c.name}-{c.max_rows}-{c.inner}"
    return c.name


@pytest.mark.parametrize("comp", COMPRESSORS, ids=_ids)
@pytest.mark.parametrize("n", [1, 7, 1024, 2348])
def test_codec_roundtrip_equals_apply(comp, n):
    """Q = unpack ∘ pack by construction: the codec round-trip must equal
    ``Compressor.apply`` bit-exactly, for every operator and shape."""
    codec = make_codec(comp)
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n,)) * 2.5
    payload = codec.pack(x, key)
    q = codec.unpack(payload, n, x.shape, x.dtype, key=key)
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(comp.apply(x, key)))
    assert q.shape == x.shape and q.dtype == x.dtype


@pytest.mark.parametrize("comp", COMPRESSORS, ids=_ids)
@pytest.mark.parametrize("n", [1, 7, 1024, 2348, 100 * 1024 + 300])
def test_accounted_bytes_equal_shipped_bytes_dense(comp, n):
    """``wire_bytes`` must equal the summed nbytes of the wire payload's
    actual arrays (dense-simulated: the payload a worker would ship,
    materialized abstractly), and ``bytes_per_comm_round`` must be exactly
    degree × Σ-leaf payload — no per-element approximation anywhere."""
    codec = make_codec(comp)
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    wire = jax.eval_shape(
        lambda a: codec.wire(codec.pack(a, jax.random.PRNGKey(0))), x)
    assert payload_nbytes(wire) == codec.wire_bytes(n), comp
    # optimizer-level accounting: degree × Σ leaf payloads
    K = 8
    opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=4, gamma=0.4),
                  DenseComm(ring(K)), comp)
    params = {"a": jnp.zeros((n,)), "b": jnp.zeros((33, 65))}
    got = opt.bytes_per_comm_round(params)
    want = ring(K).degree * (codec.wire_bytes(n) + codec.wire_bytes(33 * 65))
    assert got == want, comp


def test_compressed_wire_under_half_bf16_baseline():
    """Acceptance: every non-identity codec at its default wire config
    ships < 50% of the bf16 full-precision baseline on a realistically-
    sized leaf.  (A deliberately coarse top-k — 8-byte slots × a large
    fraction — can exceed bf16; that is a configuration choice the exact
    accounting now makes visible instead of hiding.)"""
    n = 1 << 20
    baseline = 2 * n                     # bf16 full-precision gossip
    for comp in [SignCompressor(), SignCompressor(block=64),
                 TopKCompressor(fraction=0.01), RandKCompressor(),
                 RandKCompressor(fraction=0.05), QSGDCompressor(),
                 SparseRowsCompressor(),                # 64 of 1024 rows
                 SparseRowsCompressor(inner="sign")]:
        ratio = make_codec(comp).wire_bytes(n) / baseline
        assert ratio < 0.5, (comp, ratio)
    # an 8-bit qsgd wire is definitionally ~half of bf16 (plus norms):
    # the exact accounting reports it honestly instead of rounding down
    assert make_codec(QSGDCompressor(levels=16)).wire_bytes(n) / baseline \
        == pytest.approx(0.5, abs=5e-3)


def test_randk_shared_key_reconstructs_indices():
    """Rand-k's satellite property: the wire carries *only* values; sender
    and receiver derive identical indices from the shared key — zero extra
    communication — and different rounds draw different coordinates."""
    comp = RandKCompressor(fraction=0.1)
    codec = make_codec(comp)
    n = 3000
    x = jax.random.normal(jax.random.PRNGKey(1), (n,))
    key = jax.random.PRNGKey(42)
    payload = codec.pack(x, key)
    wire = codec.wire(payload)
    assert set(wire) == {"vals"}                       # indices never ship
    assert payload_nbytes(wire) == codec.wire_bytes(n) == codec.k(n) * 4
    # receiver-side: same key → same indices → identical reconstruction
    idx_sender = codec.derive_idx(key, n)
    idx_receiver = codec.derive_idx(key, n)
    np.testing.assert_array_equal(np.asarray(idx_sender),
                                  np.asarray(idx_receiver))
    q_full = codec.unpack(payload, n, x.shape, x.dtype, key=key)
    q_wire = codec.unpack(wire, n, x.shape, x.dtype, key=key)
    np.testing.assert_array_equal(np.asarray(q_full), np.asarray(q_wire))
    # the kept set really is k distinct coordinates of x
    kept = np.asarray(idx_sender)
    assert len(set(kept.tolist())) == codec.k(n)
    np.testing.assert_array_equal(np.asarray(q_wire)[kept],
                                  np.asarray(x)[kept])
    # a different round key draws a different coordinate set
    idx2 = np.asarray(codec.derive_idx(jax.random.PRNGKey(43), n))
    assert set(idx2.tolist()) != set(kept.tolist())


def test_dense_payload_wire_matches_legacy_apply_path():
    """The dense backend's payload-wire comm round (packs/unpacks the
    simulated wire) must equal the legacy apply-only path bitwise — the
    wire format is a refactor of the math, not a change to it."""
    K = 4
    for comp in [SignCompressor(block=64), TopKCompressor(fraction=0.1),
                 RandKCompressor(fraction=0.2), QSGDCompressor(levels=7)]:
        outs = []
        for packed in (True, False):
            opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4,
                                        packed_wire=packed),
                          DenseComm(ring(K)), comp)
            params = {"w": jax.random.normal(jax.random.PRNGKey(3),
                                             (K, 130))}
            state = opt.init(params)
            state["step"] = jnp.int32(opt.config.p)
            p_new, s_new = opt.comm_round(state, params)
            outs.append((np.asarray(p_new["w"]),
                         np.asarray(s_new["xhat"]["w"])))
        np.testing.assert_array_equal(outs[0][0], outs[1][0])
        np.testing.assert_array_equal(outs[0][1], outs[1][1])


_SCRIPT_SHARDED_SHIPPED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import (CPDSGDM, CPDSGDMConfig, IdentityCompressor,
                            QSGDCompressor, RandKCompressor, SignCompressor,
                            TopKCompressor)
    from repro.core.compression import SparseRowsCompressor
    from repro.core.gossip import ShardedComm
    from repro.core.topology import ring
    from repro.launch.mesh import make_mesh
    from repro.launch.runtime import _smap

    mesh = make_mesh((8,), ("w",))
    comm = ShardedComm(ring(8), axis_names=("w",))
    smap = _smap(mesh)

    shipped = []
    orig = ShardedComm._receive_from
    def tallied(self, x, axis, shift):
        shipped.append(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize)
        return orig(self, x, axis, shift)
    ShardedComm._receive_from = tallied

    cases = [IdentityCompressor(), SignCompressor(), SignCompressor(block=64),
             TopKCompressor(fraction=0.01), RandKCompressor(fraction=0.05),
             QSGDCompressor(levels=7),
             SparseRowsCompressor(max_rows=2, inner="sign")]
    params = {"a": jnp.zeros((8, 1500)), "b": jnp.zeros((8, 33, 65))}
    bf16_baseline = ring(8).degree * (1500 + 33 * 65) * 2
    for comp in cases:
        opt = CPDSGDM(CPDSGDMConfig(eta=0.05, mu=0.9, p=2, gamma=0.4),
                      comm, comp)

        def one_round(p):
            st = opt.init(p)
            st["step"] = jnp.int32(opt.config.p)
            p_new, _ = opt.comm_round(st, p)
            return p_new

        shipped.clear()
        jax.eval_shape(smap(one_round, in_specs=(P("w"),),
                            out_specs=P("w")), params)
        got = sum(shipped)
        want = opt.bytes_per_comm_round(
            {"a": jax.ShapeDtypeStruct((1500,), jnp.float32),
             "b": jax.ShapeDtypeStruct((33, 65), jnp.float32)})
        assert got == want, (comp.name, got, want)
        if comp.name != "identity":
            assert got < 0.5 * bf16_baseline, (comp.name, got, bf16_baseline)
        print("SHIPPED_OK", comp.name, got)
    print("ALL_SHIPPED_OK")
""")


def _run_sub(script, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_accounted_bytes_equal_shipped_bytes_sharded():
    """Accounted ≡ shipped on the production backend: tally the tensors
    actually handed to ``ppermute`` while tracing one sharded CPD comm
    round, per codec — the sum must equal ``bytes_per_comm_round``
    exactly, and every non-identity codec must ship < 50% of the bf16
    full-precision baseline."""
    out = _run_sub(_SCRIPT_SHARDED_SHIPPED)
    assert "ALL_SHIPPED_OK" in out
    for name in ["identity", "sign", "topk", "randk", "qsgd", "sparse_rows"]:
        assert f"SHIPPED_OK {name}" in out
