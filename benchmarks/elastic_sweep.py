"""Elastic-membership sweep: survivor loss and wire bytes vs. churn rate.

The chaos harness (``repro.testing.chaos``) drives the fused round engine
through seeded kill / revive / straggle scripts at increasing churn rates
on the K = 8 ring, for the four dense optimizer families (PD-SGDM,
CPD-SGDM + sign wire, MT-DSGDm, QG-DSGDm) on the heterogeneous
per-worker quadratic (deterministic: every row is exactly reproducible,
so the claim rows gate at tight tolerances).  Rows carry

* ``final_loss`` — loss of the live-worker-averaged model after the run,
* ``loss_ratio`` — final / initial loss (< 1 ⇔ survivors still train),
* ``max_consensus`` — peak RMS disagreement among live workers,
* ``mb_total`` — fleet wire MB actually accounted over the run,
* ``bytes_saved_frac`` — 1 − accounted/full-fleet bytes (dead edges ship
  zero, so churn must save exactly the masked edge fraction).

Claim rows, gated by ``tools/bench_compare.py``:

* ``elastic/claim_survivors`` — ``survivors_bounded`` = 1 iff *every*
  (rate, optimizer) cell keeps its averaged-model loss within 2× and its
  peak consensus distance within 5× of the same optimizer's churn-free
  run; the committed baseline pins 1 (``min_frac`` 1.0 — divergence
  under churn fails the gate).  Strict descent is *not* required at the
  highest rate: with most edges masked the fleet gossips rarely and
  workers drift toward their local optima, which raises the averaged
  model's global loss — bounded, not monotone, is the contract.
* ``elastic/claim_bytes`` — ``bytes_saved_frac`` of PD-SGDM at the
  highest churn rate: pure accounting arithmetic, identical on any host
  (``rel_tol`` 0.02).

Standalone runs write ``benchmarks/BENCH_elastic.json``; under
``python -m benchmarks.run elastic`` the rows land in the main
``BENCH_<tag>.json``.  ``ELASTIC_ROUNDS`` trims the horizon for smoke
runs (default 16 communication rounds per cell).
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import make_compressor, make_optimizer
from repro.core.gossip import DenseComm
from repro.core.topology import full_membership, ring
from repro.testing import chaos_script, membership_for, run_dense_chaos

K, D, P = 8, 64, 2
ROUNDS = int(os.environ.get("ELASTIC_ROUNDS", "16"))
SEED = 7
RATES = [0.0, 0.1, 0.25]
OPTIMIZERS = [
    ("pd_sgdm", {}),
    ("cpd_sgdm", {"gamma": 0.5, "compressor": make_compressor("sign")}),
    ("mt_dsgdm", {}),
    ("qg_dsgdm", {}),
]


def _quadratic():
    b = 2.0 * jax.random.normal(jax.random.PRNGKey(3), (K, D))

    def grads_fn(params, batch):
        g = {"w": params["w"] - b}
        return 0.5 * jnp.sum((params["w"] - b) ** 2, axis=-1).mean(), g

    return grads_fn


def _params0():
    x0 = jax.random.normal(jax.random.PRNGKey(0), (1, D))
    return {"w": jnp.broadcast_to(x0, (K, D))}


def _membership(rate):
    if rate == 0.0:
        return [], full_membership(K)
    events = chaos_script(K, ROUNDS, seed=SEED, kill_prob=rate,
                          straggle_prob=rate)
    return events, membership_for(K, ROUNDS, events)


def main():
    grads_fn = _quadratic()
    results = {}
    for rate in RATES:
        events, ms = _membership(rate)
        for name, kw in OPTIMIZERS:
            opt = make_optimizer(name, DenseComm(ring(K), membership=ms),
                                 eta=0.05, mu=0.9, p=P, **kw)
            t0 = time.time()
            run = run_dense_chaos(opt, events, _params0(), grads_fn,
                                  ROUNDS)
            dt = time.time() - t0
            total = float(run.accounted_bytes.sum())
            # full-fleet bytes for THIS optimizer at rate 0 (cell order
            # guarantees the rate-0 row ran first)
            base = results.get((0.0, name), {}).get("mb_total",
                                                    total / 1e6) * 1e6
            saved = 1.0 - total / base if base else 0.0
            ratio = float(run.avg_loss[-1] / run.avg_loss[0])
            results[(rate, name)] = {
                "final_loss": float(run.avg_loss[-1]),
                "loss_ratio": ratio,
                "max_consensus": float(run.consensus.max()),
                "mb_total": total / 1e6,
                "bytes_saved_frac": saved,
            }
            csv_row(
                f"elastic/{name}_c{rate:g}", dt / ROUNDS * 1e6,
                f"final_loss={run.avg_loss[-1]:.4f};loss_ratio={ratio:.4f};"
                f"max_consensus={run.consensus.max():.4f};"
                f"mb_total={total / 1e6:.4f};bytes_saved_frac={saved:.4f}")

    bounded = int(all(
        v["final_loss"] <= 2.0 * results[(0.0, name)]["final_loss"]
        and v["max_consensus"] <= 5.0 * results[(0.0, name)]["max_consensus"]
        for (rate, name), v in results.items() if rate > 0.0))
    csv_row("elastic/claim_survivors", 0.0,
            f"survivors_bounded={bounded};cells={len(results)}")
    top_rate = max(RATES)
    csv_row("elastic/claim_bytes", 0.0,
            f"bytes_saved_frac="
            f"{results[(top_rate, 'pd_sgdm')]['bytes_saved_frac']:.4f};"
            f"rate={top_rate:g}")
    return results


def _write_json(results) -> str:
    from benchmarks.common import collected_rows
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_elastic.json")
    rows = [r for r in collected_rows() if r["name"].startswith("elastic/")]
    doc = {
        "schema": 1,
        "created_unix": int(time.time()),
        "sections": ["elastic"],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rounds": ROUNDS,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = main()
    print(f"bench_json,0.0,path={os.path.relpath(_write_json(res))}")
