"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Dispatch is *sort-based* (argsort tokens by expert, scatter into a per-expert
capacity buffer) rather than the one-hot ``(N, E, C)`` einsum — the one-hot
dispatch tensor is O(N²) at large N and would dominate memory for Arctic's
128 experts.  With sorting, peak extra memory is the (E, C, d) buffer ≈
``k·capacity_factor`` token copies, matching Megablocks-style systems.

Sharding: the capacity buffer's expert dim is annotated with the logical axis
``"expert"``; the runtime maps it to a mesh axis (expert parallelism) or
leaves it unsharded.  Tokens above capacity are dropped (standard Switch
semantics); the load-balance auxiliary loss keeps routing uniform.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, truncated_normal_init

__all__ = ["MoECfg", "moe_init", "moe_apply"]

Shd = Callable  # shd(x, *logical_axes) -> x (sharding-constraint hook)


def _noshd(x, *names):
    return x


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    gated: bool = True
    # dispatch groups: 1 = one global sort (baseline; SPMD must replicate
    # the sort => giant all-reduces).  >1 = per-group local sort + an
    # expert-major transpose (lowers to all-to-all) — set to the data-axis
    # size so each shard sorts only its own tokens (§Perf iteration).
    n_groups: int = 1


def moe_init(key, cfg: MoECfg, dtype):
    kr, ki, kg, ko = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(kr, d, E, jnp.float32),
        "wi": truncated_normal_init(ki, (E, d, f), dtype, scale=d ** -0.5),
        "wo": truncated_normal_init(ko, (E, f, d), dtype, scale=f ** -0.5),
    }
    if cfg.gated:
        p["wg"] = truncated_normal_init(kg, (E, d, f), dtype, scale=d ** -0.5)
    return p


def _capacity(n_tokens: int, cfg: MoECfg) -> int:
    c = math.ceil(cfg.top_k * n_tokens / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch(xf, gates, C: int, cfg: MoECfg):
    """Sort-based dispatch of one token group.

    xf: (N, d); gates: (N, E) f32.  Returns the (E, C, d) capacity buffer
    plus the combine metadata (slot order, ranks, weights, keep mask).
    """
    N, d = xf.shape
    k, E = cfg.top_k, cfg.n_experts
    top_w, top_e = jax.lax.top_k(gates, k)                            # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(N * k)
    order = jnp.argsort(flat_e, stable=True)                          # (N·k,)
    sorted_e = flat_e[order]
    token_of_slot = order // k
    w_of_slot = top_w.reshape(N * k)[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(N * k) - starts[sorted_e]
    keep = rank < C
    rank_c = jnp.where(keep, rank, C)  # C = out-of-range -> dropped by mode

    buf = jnp.zeros((E, C, d), xf.dtype)
    buf = buf.at[sorted_e, rank_c].set(xf[token_of_slot], mode="drop")
    meta = (sorted_e, rank_c, token_of_slot, w_of_slot, keep)
    return buf, meta


def _combine(out_buf, meta, N: int, d: int):
    sorted_e, rank_c, token_of_slot, w_of_slot, keep = meta
    slot_out = out_buf[sorted_e, rank_c]                 # gather; C row OOB
    slot_out = jnp.where(keep[:, None], slot_out, 0.0)
    slot_out = slot_out.astype(jnp.float32) * w_of_slot[:, None]
    return jnp.zeros((N, d), jnp.float32).at[token_of_slot].add(slot_out)


def _expert_ffn(params, buf, cfg: MoECfg, shd: Shd):
    """buf: (E, C, d) -> (E, C, d); gated SiLU, f32 accumulation."""
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"],
                   preferred_element_type=jnp.float32)
    if "wg" in params:
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"],
                       preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shd(h.astype(buf.dtype), "expert", None, "mlp")
    out = jnp.einsum("ecf,efd->ecd", h, params["wo"],
                     preferred_element_type=jnp.float32)
    return out.astype(buf.dtype)


def moe_apply(params, x, cfg: MoECfg, shd: Shd = _noshd):
    """x: (b, s, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    N = b * s
    E = cfg.n_experts
    G = cfg.n_groups if N % max(cfg.n_groups, 1) == 0 else 1
    xf = shd(x.reshape(N, d), "tokens", "embed")

    # ---- router (f32 throughout for numerical stability)
    logits = dense(params["router"], xf.astype(jnp.float32))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)       # (N, E)

    # ---- load-balance auxiliary loss (Switch:  E · Σ_e f_e · P_e)
    P_e = gates.mean(axis=0)
    _, top_e = jax.lax.top_k(gates, cfg.top_k)
    ones = jnp.zeros((N, E), jnp.float32).at[
        jnp.arange(N)[:, None], top_e].add(1.0)
    f_e = ones.mean(axis=0) / cfg.top_k
    aux = cfg.router_aux_weight * E * jnp.sum(P_e * f_e)

    if G == 1:
        # single global sort (baseline): simple but the SPMD partitioner
        # must replicate the sort/scatter — fine on few chips, pathological
        # at mesh scale (see EXPERIMENTS.md §Perf).
        C = _capacity(N, cfg)
        buf, meta = _dispatch(xf, gates, C, cfg)
        buf = shd(buf, "expert", None, "embed")
        out_buf = shd(_expert_ffn(params, buf, cfg, shd),
                      "expert", None, "embed")
        y = _combine(out_buf, meta, N, d)
    else:
        # grouped dispatch: every group sorts only its own tokens (group
        # dim sharded over the data axis => local sorts), then the buffer
        # is transposed to expert-major (lowers to all-to-all) for the
        # expert-sharded FFN.
        Cg = _capacity(N // G, cfg)
        xg = shd(xf.reshape(G, N // G, d), "group", None, "embed")
        gg = gates.reshape(G, N // G, E)
        buf, meta = jax.vmap(
            lambda xx, gt: _dispatch(xx, gt, Cg, cfg))(xg, gg)
        buf = shd(buf, "group", None, None, "embed")       # (G, E, Cg, d)
        ebuf = jnp.swapaxes(buf, 0, 1)                     # (E, G, Cg, d)
        ebuf = shd(ebuf, "expert", None, None, "embed")    # <- all-to-all
        ebuf = ebuf.reshape(E, G * Cg, d)
        out = _expert_ffn(params, ebuf, cfg, shd)
        out = shd(out.reshape(E, G, Cg, d), "expert", None, None, "embed")
        out_g = shd(jnp.swapaxes(out, 0, 1),               # back: a2a
                    "group", None, None, "embed")
        yg = jax.vmap(lambda ob, mt: _combine(ob, mt, N // G, d))(
            out_g, meta)
        y = yg.reshape(N, d)
    y = shd(y, "tokens", "embed")
    return y.reshape(b, s, d).astype(x.dtype), aux
