"""repro — PD-SGDM / CPD-SGDM decentralized training on JAX.

Package-level invariant: sharding-invariant RNG.  With the legacy
(non-partitionable) threefry lowering, GSPMD partitioning changes the
values drawn inside jitted functions with ``out_shardings`` — so
``TrainPack.init_fn`` on the mesh and the dense single-process simulation
would start from *different* x₀ and every dense-vs-sharded equivalence
contract would silently fail.  Flip the flag once, before anything traces,
so both backends draw identical randoms regardless of partitioning.
(JAX enables this by default in later releases.)
"""
import jax as _jax

_jax.config.update("jax_threefry_partitionable", True)
