"""Public jit'd wrappers around the Pallas kernels.

Handles pytree flatten → single fused kernel call → unflatten, padding to
the (rows, 1024) kernel layout.  ``interpret`` defaults to True off-TPU
(this container is CPU-only: TPU is the *target*, interpret mode is the
correctness harness).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gossip_mix as gm
from repro.kernels import momentum as mom
from repro.kernels import sign_compress as sc

__all__ = ["INTERPRET", "momentum_update_tree", "sign_pack", "sign_unpack",
           "gossip_mix_tree", "flatten_for_kernel", "unflatten_from_kernel"]

INTERPRET = jax.default_backend() != "tpu"

_ROW = mom.LANE  # 1024


def _padded_rows(n_elems: int, block_rows: int) -> int:
    rows = -(-n_elems // _ROW)
    return -(-rows // block_rows) * block_rows


def flatten_for_kernel(tree, block_rows: int) -> Tuple[jnp.ndarray, list]:
    """Concatenate all leaves into one zero-padded (rows, 1024) f32 matrix."""
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    rows = _padded_rows(n, block_rows)
    flat = jnp.pad(flat, (0, rows * _ROW - n))
    meta = [(l.shape, l.dtype) for l in leaves]
    return flat.reshape(rows, _ROW), meta


def unflatten_from_kernel(mat, tree_like, meta):
    flat = mat.reshape(-1)
    leaves = []
    off = 0
    for shape, dtype in meta:
        size = int(np.prod(shape))
        leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def momentum_update_tree(params, m, grads, *, mu: float, lr,
                         weight_decay: float = 0.0, nesterov: bool = False,
                         interpret: bool | None = None):
    """Fused SGDM over a whole pytree (one kernel launch)."""
    interpret = INTERPRET if interpret is None else interpret
    x_mat, meta = flatten_for_kernel(params, mom.BLOCK_ROWS)
    m_mat, _ = flatten_for_kernel(m, mom.BLOCK_ROWS)
    g_mat, _ = flatten_for_kernel(grads, mom.BLOCK_ROWS)
    x_new, m_new = mom.momentum_update(
        x_mat, m_mat, g_mat, lr, mu=mu, wd=weight_decay,
        nesterov=nesterov, interpret=interpret)
    new_params = unflatten_from_kernel(x_new, params, meta)
    meta_m = [(s, jnp.float32) for (s, _d) in meta]
    new_m = unflatten_from_kernel(m_new, m, meta_m)
    return new_params, new_m


def sign_pack(x_mat, *, interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return sc.sign_pack_pallas(x_mat, interpret=interpret)


def sign_unpack(packed, scales, *, interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return sc.sign_unpack_pallas(packed, scales, interpret=interpret)


def gossip_mix_tree(trees, weights, *, interpret: bool | None = None):
    """Fused W-row mixing of n aligned pytrees (self + neighbours)."""
    interpret = INTERPRET if interpret is None else interpret
    mats = []
    meta = None
    for t in trees:
        mat, mt = flatten_for_kernel(t, gm.BLOCK_ROWS)
        mats.append(mat)
        meta = mt
    out = gm.gossip_mix(tuple(mats), weights=tuple(weights),
                        interpret=interpret)
    return unflatten_from_kernel(out, trees[0], meta)
